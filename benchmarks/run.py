"""Benchmark harness: one function per paper table/figure.

Output: ``name,us_per_call,derived`` CSV rows on stdout, plus a
machine-readable ``BENCH_run.json`` (every row) written next to the repo
root so the perf trajectory is tracked across PRs.
``bench_serving_throughput`` additionally persists ``BENCH_serving.json``
(chunked-vs-runtime tokens/s, trace counts).

  Table 6/7  -> bench_lifecycle_create / bench_lifecycle_monitor
  Eq.1/4.4.4 -> bench_hpa_formula
  4.4.5      -> bench_hpa_scaling
  Tables 8/9 -> bench_queue_16 / bench_queue_32 (M/M/1 sim vs Calc.Lq)
  Fig. 8     -> bench_dbn_tracking
  Fig. 9     -> bench_dbn_control
  5.1        -> bench_deployment_40
  4.5.4      -> bench_control_plane_churn (drain -> reschedule loop)
  §1/§4      -> bench_federation_churn (full-site kill, cross-site failover)
  QoS        -> bench_priority_spike (twin (replicas, priority) writes,
                batch preemption + resume, quota books balance)
  chaos      -> bench_chaos_soak (seeded fault storm vs fault-free
                oracle: zero loss, token-identical recovery, epoch
                fencing, balanced books every tick)
  serving    -> bench_serving_throughput (slot-slab runtime vs chunked)
             -> bench_paged_decode (paged KV slab vs dense slab)
             -> bench_prefix_reuse (prefix-sharing admission + spec decode)
  kernels    -> bench_kernel_* (interpret-mode Pallas vs jnp oracle)
  dry-run    -> bench_roofline (reads experiments/dryrun)

CLI: ``--only SUBSTR`` runs matching benches, ``--fast`` shrinks the
serving workload for CI smoke, ``--json-dir DIR`` relocates the JSONs.
"""
from __future__ import annotations

import json
import math
import pathlib
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS: list = []
FAST = False
JSON_DIR = ROOT


def _timeit(fn, n=100, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # us


def row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    metrics = {}
    for part in str(derived).split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                metrics[k] = float(v)
            except ValueError:
                metrics[k] = v
    RESULTS.append({"name": name, "us": round(us, 1), "derived": metrics})


def write_serving(key, report):
    """Merge one serving-bench report into BENCH_serving.json (a dict of
    bench-name -> report, so serving_throughput and paged_decode coexist
    and an --only run does not clobber the other's numbers)."""
    path = JSON_DIR / "BENCH_serving.json"
    merged = {}
    if path.exists():
        try:
            merged = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            merged = {}
    if "name" in merged:          # pre-PR-4 layout: one bare report
        merged = {merged["name"]: merged}
    merged[key] = report
    path.write_text(json.dumps(merged, indent=2) + "\n")


# ---------------------------------------------------------- Tables 6 & 7

def bench_lifecycle_create():
    from repro.core.state_machine import Container, create_pod_container

    def one():
        create_pod_container(Container("c"), 0.0)

    us = _timeit(one, n=2000)
    row("lifecycle_create_table6", us, f"pods_per_s={1e6 / us:.0f}")


def bench_lifecycle_monitor():
    from repro.core.jrm import start_vk
    from repro.core.state_machine import Container, Pod
    node = start_vk("vk", now=0.0)
    tol = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]
    for i in range(100):
        node.create_pod(Pod(f"p{i}", [Container("c")], tolerations=tol), 0.0)
    us = _timeit(lambda: node.get_pods(1.0), n=200)
    row("lifecycle_monitor_table7", us,
        f"pods_per_loop=100;loops_per_s={1e6 / us:.0f}")


# --------------------------------------------------------------- HPA

def bench_hpa_formula():
    from repro.core.hpa import desired_replicas
    us = _timeit(lambda: desired_replicas(4, 90, 50), n=10000)
    row("hpa_formula_eq1", us,
        f"example_4x90/50={desired_replicas(4, 90, 50)}")


def bench_hpa_scaling():
    """§4.4.5: load ramp up -> pods scale up; load drop -> scale down after
    the stabilization interval."""
    from repro.core.hpa import HPA, HPAConfig, MetricSample
    from repro.core.state_machine import Container, Pod, create_pod_container

    def mkpods(n, now):
        out = []
        for i in range(n):
            p = Pod(f"p{i}", [Container("c")])
            create_pod_container(p.containers[0], now)
            p.set_conditions_create(now)
            out.append(p)
        return out

    def scenario():
        hpa = HPA(HPAConfig(target=30.0, max_replicas=10,
                            cpu_initialization_period=0.0,
                            scale_down_stabilization=300.0))
        n, ups, downs = 1, 0, 0
        for t in range(0, 1200, 60):
            load = 90.0 if t < 400 else 10.0
            pods = mkpods(n, now=-1e4)
            samples = {p.name: MetricSample(load, timestamp=float(t))
                       for p in pods}
            d = hpa.evaluate(pods, samples, now=float(t))
            ups += d > n
            downs += d < n
            n = d
        return ups, downs, n

    us = _timeit(scenario, n=20)
    ups, downs, final = scenario()
    row("hpa_scaling_4.4.5", us,
        f"scale_ups={ups};scale_downs={downs};final={final}")


# --------------------------------------------------------- Tables 8 & 9

def _lindley_lq(lam, mu, n=400_000, seed=0):
    """M/M/1 L_q via Lindley recursion + Little's law."""
    rng = np.random.default_rng(seed)
    a = rng.exponential(1.0 / lam, n)     # interarrivals
    s = rng.exponential(1.0 / mu, n)      # services
    w = 0.0
    tot = 0.0
    for i in range(1, n):
        w = max(w + s[i - 1] - a[i], 0.0)
        tot += w
    return lam * tot / (n - 1)


def _bench_queue(threads):
    from repro.core.digital_twin.queue_model import MU_EXACT, table_for
    tab = table_for(threads)
    mu = MU_EXACT[threads]
    errs = []
    t0 = time.perf_counter()
    for state, lam, _m, _u, obs, calc in tab:
        sim = _lindley_lq(lam, mu, seed=int(state))
        errs.append(abs(sim - calc) / calc)
    us = (time.perf_counter() - t0) / len(tab) * 1e6
    row(f"queue_mm1_table{8 if threads == 16 else 9}", us,
        f"max_rel_err_vs_calc_lq={max(errs):.2f}")


def bench_queue_16():
    _bench_queue(16)


def bench_queue_32():
    _bench_queue(32)


# ------------------------------------------------------------ Figs 8 & 9

def _run_twin():
    from repro.core.digital_twin.control import ControlPolicy
    from repro.core.digital_twin.dbn import DigitalTwin
    from repro.core.digital_twin.queue_model import ground_truth, observe
    gt = ground_truth(80)
    twin, policy = DigitalTwin(), ControlPolicy()
    rng = np.random.default_rng(0)
    control, est, ctrl = 16, [], []
    for t, s in enumerate(gt):
        twin.assimilate(observe(s, control, rng), control)
        est.append(twin.estimate())
        control = policy.recommend(twin, control, t)
        ctrl.append(control)
    return gt, np.array(est), np.array(ctrl)


def bench_dbn_tracking():
    from repro.core.digital_twin.dbn import DigitalTwin
    twin = DigitalTwin()
    us = _timeit(lambda: twin.assimilate(50.0, 16), n=200)
    gt, est, _ = _run_twin()
    row("dbn_tracking_fig8", us,
        f"state_mae={np.abs(est - gt).mean():.3f}")


def bench_dbn_control():
    gt, _, ctrl = _run_twin()
    # predicted-vs-estimated agreement proxy: correct regime selection
    hi = np.mean(ctrl[gt >= 3.0] == 32)
    lo = np.mean(ctrl[gt <= 0.5] == 16)
    t0 = time.perf_counter()
    _run_twin()
    us = (time.perf_counter() - t0) * 1e6 / 80
    row("dbn_control_fig9", us,
        f"escalation_acc={hi:.2f};deescalation_acc={lo:.2f}")


# ------------------------------------------------------------------ §5.1

def bench_deployment_40():
    """§5.1 through the declarative control plane: 40 nodes registered in
    the Cluster store, a 40-replica Deployment declared, controllers +
    queue scheduler converge it in one reconcile step."""
    from repro.core.cluster import Cluster, Deployment, PodTemplate
    from repro.core.controllers import ControlPlane
    from repro.core.jcs import CentralService
    from repro.core.jfe import FrontEnd
    from repro.core.jfm import FacilityManager
    from repro.core.jrm import SliceSpec

    def scenario():
        fe = FrontEnd()
        wf = fe.add_wf("vk-nersc", 40, walltime=10800.0)
        jcs = CentralService(fe)
        jcs.launch_pilot(wf, now=0.0, slice_spec=SliceSpec(chips=4))
        cluster = Cluster()
        for n in jcs.node_list():
            cluster.register_node(n, 0.0)
            cluster.heartbeat(n.name, 120.0)
        FacilityManager().feed(cluster, 120.0)
        cluster.apply_deployment(Deployment(
            "ersap", 40, template=PodTemplate(
                tolerations=[{"key": "virtual-kubelet.io/provider",
                              "value": "mock"}],
                request_chips=4, request_hbm_bytes=8 << 30,
                expected_duration=3600.0)), 120.0)
        plane = ControlPlane(cluster)
        plane.step(120.0)
        bound = sum(1 for r in cluster.pods.values() if r.bound)
        return len(cluster.nodes), bound

    us = _timeit(scenario, n=5)
    nodes, bound = scenario()
    row("deployment_40node_5.1", us,
        f"nodes={nodes};pods_bound={bound};nodes_per_s={nodes / (us / 1e6):.0f}")


def bench_control_plane_churn():
    """Drain -> checkpoint -> evict -> reschedule loop (§4.5.4): half the
    nodes on short leases; the NodeLifecycleController drains them and the
    scheduler re-places every displaced replica on surviving nodes."""
    from repro.core.cluster import Cluster, Deployment, PodTemplate
    from repro.core.controllers import ControlPlane
    from repro.core.jrm import SliceSpec, start_vk

    def scenario():
        cluster = Cluster()
        for i in range(8):
            wall = 200.0 if i % 2 == 0 else 0.0     # half drain mid-run
            cluster.register_node(
                start_vk(f"n{i}", walltime=wall, now=0.0,
                         slice_spec=SliceSpec(chips=8)), 0.0)
        cluster.apply_deployment(Deployment(
            "ersap", 16, template=PodTemplate(
                tolerations=[{"key": "virtual-kubelet.io/provider",
                              "value": "mock"}],
                request_chips=2)), 0.0)
        plane = ControlPlane(cluster)
        moved = 0
        for t in range(0, 300, 20):
            now = float(t)
            for name in cluster.nodes:
                cluster.heartbeat(name, now)
            plane.step(now)
        moved = sum(1 for r in cluster.pods.values()
                    if r.restored_from is not None and r.bound)
        bound = sum(1 for r in cluster.pods.values() if r.bound)
        return bound, moved, len(cluster.events)

    us = _timeit(scenario, n=5)
    bound, moved, events = scenario()
    row("control_plane_churn_4.5.4", us,
        f"replicas_bound={bound};rescheduled={moved};events={events}")


def bench_federation_churn():
    """Full-site kill mid-stream (the §1/§4 cross-facility claim): serving
    replicas spread across two facilities by the site-aware scheduler;
    halfway through the stream the whole jlab pilot allocation is
    batch-drained in one checkpoint/evict wave (ControlPlane.drain_site)
    and its replicas reschedule at the surviving site with their slot
    tables restored. Asserts zero request loss and cross-site failover."""
    import jax
    from repro.configs.base import get_config
    from repro.core.cluster import Cluster
    from repro.core.controllers import ControlPlane
    from repro.core.elastic import ElasticServing
    from repro.core.jcs import CentralService
    from repro.core.jfe import FrontEnd
    from repro.core.jrm import SliceSpec
    from repro.core.scheduler import Scheduler, SiteTopology
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)

    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = Cluster()
    # open-ended leases: the facility outage is the explicit drain_site
    # wave below, not a walltime expiry
    wfs = fe.add_multi_wf("fed-", {"jlab": 2, "nersc": 2}, nodetype="tpu",
                          walltime=0.0)
    jcs.launch_multi(wfs, now=0.0, slice_spec=SliceSpec(chips=2),
                     cluster=cluster)
    topo = SiteTopology.parse("jlab:nersc:40")
    plane = ControlPlane(cluster, scheduler=Scheduler(cluster, topology=topo))
    eng = StreamEngine(cfg, serving, jcs.node_list(), service_rate=6.0,
                       max_batch=4, cluster=cluster, plane=plane)
    eng.deploy(0.0)
    cluster.scale("ersap", 2, 0.0, source="bench")
    eng.reconcile(0.0)
    sites_before = sorted({cluster.nodes[p.node].site
                           for p in eng.pods.values()})
    assert sites_before == ["jlab", "nersc"], "site spread failed"

    dt = 10.0
    ticks = 8 if FAST else 16
    kill_at = ticks // 2
    t0 = time.perf_counter()
    for t in range(ticks + 6):
        now = t * dt
        if t == kill_at:
            plane.drain_site("jlab", now)     # facility gone, one wave
        for name, node in cluster.nodes.items():
            if node.site != "jlab" or t < kill_at:
                cluster.heartbeat(name, now)
        eng.reconcile(now)
        eng.tick(now, dt, lam=1.0 if t < ticks else 0.0)
    s = time.perf_counter() - t0

    lost = eng.source.rid - len(eng.completed)
    moved = sum(1 for r in cluster.pods_of("ersap")
                if r.restored_from is not None and r.bound)
    sites_after = sorted({cluster.nodes[p.node].site
                          for p in eng.pods.values()})
    assert lost == 0, f"{lost} requests lost across the site kill"
    assert sites_after == ["nersc"], "replicas did not fail over cross-site"
    assert moved >= 1
    row("federation_churn", s / (ticks + 6) * 1e6,
        f"requests={eng.source.rid};completed={len(eng.completed)};"
        f"lost={lost};rescheduled_cross_site={moved};"
        f"sites_before={'+'.join(sites_before)};"
        f"sites_after={'+'.join(sites_after)}")


def bench_priority_spike():
    """QoS under a mixed-tenant pressure spike: a preemptible batch
    tenant saturates the cluster's chips next to one serving replica;
    mid-run the arrival rate spikes past one replica's capacity. The
    digital twin escalates the serving Deployment along the (replicas,
    priority) action space — ``standard`` -> ``latency-critical`` plus a
    2x replica write — and the scale-up replica *preempts* a batch pod
    (checkpoint -> requeue, §4.5.4 path). When the spike passes, serving
    de-escalates and the preempted batch pod reschedules and resumes
    from its checkpointed progress.

    Asserts (the QoS acceptance criteria): zero serving-request loss;
    serving p99 latency bounded; batch state round-trips identically
    through preempt -> requeue -> resume; only batch (never serving,
    never equal-or-higher priority) is preempted; and the fair-share
    quota books balance (used + free == capacity, per-owner sums match
    the node truth) on every tick."""
    import jax
    from repro.configs.base import get_config
    from repro.core.cluster import Cluster
    from repro.core.controllers import ControlPlane
    from repro.core.digital_twin.control import ControlPolicy
    from repro.core.elastic import ElasticServing
    from repro.core.jrm import SliceSpec, start_vk
    from repro.core.qos import BatchTenant, Quota
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)

    cluster = Cluster()
    for i in range(4):
        cluster.register_node(
            start_vk(f"n{i}", nodetype="tpu", now=0.0,
                     slice_spec=SliceSpec(chips=2)), 0.0)
        cluster.heartbeat(f"n{i}", 0.0)
    cluster.apply_quota(Quota(owner="ersap", chips=4), 0.0)
    cluster.apply_quota(Quota(owner="batch", chips=7), 0.0)
    plane = ControlPlane(cluster)

    eng = StreamEngine(cfg, serving, list(cluster.nodes.values()),
                       service_rate=2.0, max_batch=4,
                       cluster=cluster, plane=plane)
    # paper control regions (Tables 8/9 put E[Lq|16] between ~34 and 248:
    # the spike must push the queue into the state-3 regime to escalate,
    # and the post-spike drain back under lq_low de-escalates)
    eng.policy = ControlPolicy(lq_high=55.0, lq_low=40.0)
    eng.deploy(0.0)
    assert len(eng.pods) == 1

    # batch tenant: both tenants start at *standard* — preemption is only
    # possible after the twin's priority write, which is the point
    batch = BatchTenant(cluster, 7, priority_class="standard")
    eng.reconcile(0.0)
    assert batch.bound == 7
    cluster.ledger.assert_balanced()

    arrivals = {}
    real_arrivals = eng.source.arrivals

    def tracked(now, dt, lam, **kw):
        out = real_arrivals(now, dt, lam, **kw)
        for r in out:
            arrivals[r.rid] = r.arrival
        return out

    eng.source.arrivals = tracked

    dt = 10.0
    ticks = 16 if FAST else 24
    spike = range(ticks // 4, ticks // 2)        # §6.2-style pressure spike
    t0 = time.perf_counter()
    for t in range(ticks + 8):                   # +8 drain ticks (lam=0)
        now = t * dt
        lam = 0.0 if t >= ticks else (4.5 if t in spike else 0.6)
        for name in cluster.nodes:
            cluster.heartbeat(name, now)
        eng.reconcile(now)
        batch.advance()                          # batch work progresses
        eng.tick(now, dt, lam)
        if t % 2 == 1:
            eng.control_step(now)
        cluster.ledger.assert_balanced()         # quota books, every tick
    elapsed = time.perf_counter() - t0

    # zero serving-request loss across escalate -> preempt -> de-escalate
    lost = eng.source.rid - len(eng.completed)
    assert lost == 0, f"{lost} serving requests lost"
    assert len(eng.queue) == 0
    lat = np.asarray([done - arrivals[rid] for rid, done in eng.completed])
    p99 = float(np.percentile(lat, 99))
    assert p99 <= 12 * dt, f"serving p99 {p99:.0f}s unbounded under spike"
    # the twin's priority write landed and enabled preemption of batch only
    reasons = cluster.event_reasons()
    assert "PriorityChanged" in reasons
    preempted = [ev for ev in cluster.events if ev.reason == "Preempted"]
    assert preempted, "pressure spike never triggered preemption"
    assert all(ev.name.startswith("batch") for ev in preempted), \
        "a non-batch (equal-or-higher priority) pod was preempted"
    # preempted batch pods resumed with state identical to the checkpoint
    # (each resume validated against its own eviction's snapshot)
    assert batch.resumed, "no preempted batch pod resumed"
    assert not batch.mismatches, \
        f"resume/checkpoint state mismatches: {batch.mismatches}"
    escalated = sum(1 for ev in cluster.events
                    if ev.reason == "PriorityChanged")
    row("priority_spike", elapsed / (ticks + 8) * 1e6,
        f"requests={eng.source.rid};lost={lost};p99_s={p99:.1f};"
        f"preempted={len(preempted)};batch_resumed={len(batch.resumed)};"
        f"priority_writes={escalated};quota_balanced=1")


# ------------------------------------------------------------ chaos soak

def bench_chaos_soak():
    """Serving + batch mix under a seeded fault storm (flap, straggler,
    partition, checkpoint corruption, walltime cut, crash — composed
    with a flash-crowd surge through the RequestSource seam) vs a
    fault-free oracle run over the identical workload.

    Asserts the robustness acceptance criteria: zero request loss and
    exactly-once completion; every token any replica incarnation emitted
    is a prefix of the oracle's stream for that rid (deterministic prompt
    replay — no divergence, no double emission); the partitioned node's
    stale replica is epoch-fenced on rejoin; quota-ledger, page-allocator
    and rid books balance on *every* tick (InvariantAuditor); batch
    progress rolls back at most the background-checkpoint interval; and
    service recovery latency after any fault stays bounded. The chaos
    side runs under two storm seeds so wildcard targeting cannot
    overfit one lucky draw."""
    import tempfile

    import jax
    from repro.configs.base import get_config
    from repro.core.chaos import FaultInjector, FaultSpec, InvariantAuditor
    from repro.core.cluster import Cluster
    from repro.core.controllers import ControlPlane
    from repro.core.elastic import ElasticServing
    from repro.core.jrm import SliceSpec, start_vk
    from repro.core.qos import BatchTenant
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine
    from repro.streaming.runtime import RuntimeConfig

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)

    dt = 10.0
    ticks = 36 if FAST else 56
    drain = 10
    recovery_bound_s = 60.0          # stale_after (30) + detection slack
    rollback_bound = 6               # progress units vs bg interval of 1

    def run_side(schedule, seed):
        cluster = Cluster()
        for i in range(5):
            cluster.register_node(
                start_vk(f"n{i}", nodetype="tpu", now=0.0,
                         slice_spec=SliceSpec(chips=2)), 0.0)
            cluster.heartbeat(f"n{i}", 0.0)
        plane = ControlPlane(cluster)
        ckpt_root = tempfile.mkdtemp(prefix="chaos-soak-")
        plane.nodes.ckpt_dir = ckpt_root
        plane.nodes.bg_checkpoint_every = dt
        plane.nodes.drain_pods_per_tick = 1
        eng = StreamEngine(cfg, serving, list(cluster.nodes.values()),
                           service_rate=4.0, max_batch=4,
                           cluster=cluster, plane=plane, record_tokens=True,
                           runtime_cfg=RuntimeConfig(max_batch=4,
                                                     admit_tail=0))
        eng.deploy(0.0)
        cluster.scale("ersap", 2, 0.0, source="bench")
        eng.reconcile(0.0)
        assert len(eng.pods) == 2
        batch = BatchTenant(cluster, 3, priority_class="batch")
        eng.reconcile(0.0)
        assert batch.bound == 3
        # the partition must sever a live serving replica so the
        # fence path is exercised, not just the wildcard lottery
        victim = sorted(p.node for p in eng.pods.values())[0]
        # flash crowd composed with the fault storm: the surge fires on
        # BOTH sides (load is not a failure), so the oracle sees the
        # identical arrival stream and rid accounting stays comparable
        surge = FaultSpec("surge", 150.0, "ersap", duration=80.0,
                          magnitude=2.0)
        inj = FaultInjector(
            [FaultSpec("partition", 100.0, victim, duration=100.0), surge]
            + list(schedule), seed=seed, ckpt_dir=ckpt_root
        ) if schedule is not None else FaultInjector([surge], seed=seed)
        aud = InvariantAuditor(cluster, engine=eng)
        seen_rts, gap, worst_gap = {}, 0, 0
        for t in range(ticks + drain):
            now = t * dt
            inj.apply(cluster, now)
            eng.source.surge = inj.surge_factor("ersap")
            eng.reconcile(now)
            batch.advance()
            eng.tick(now, dt, lam=0.8 if t < ticks else 0.0)
            for rt in eng.runtimes.values():
                seen_rts[id(rt)] = rt
            aud.audit(now)
            healthy = sum(
                1 for p in eng.pods.values()
                if cluster.node_status[p.node].reachable
                and cluster.node_status[p.node].ready)
            gap = gap + 1 if healthy < 2 else 0
            worst_gap = max(worst_gap, gap)
        return eng, batch, aud, seen_rts, worst_gap * dt

    storm = ["flap:*@40+20", "straggler:*@60+40x6", "ckpt_corrupt:*@230",
             "walltime_cut:*@240x10", "crash:*@300"]

    # fault-free oracle: the reference token streams + workload totals
    oracle, _, _, o_rts, _ = run_side(None, seed=0)
    assert len(oracle.completed) == oracle.source.rid > 0
    o_logs = {}
    for rt in o_rts.values():
        for rid, log in rt.token_log.items():
            o_logs[rid] = list(log)

    t0 = time.perf_counter()
    worst_recovery, fenced_total, restored_total, compared = 0.0, 0, 0, 0
    max_rollback = 0
    for seed in (0, 1):
        eng, batch, aud, rts, recovery_s = run_side(storm, seed)
        cluster = eng.cluster
        # zero loss, exactly-once (the auditor also checked every tick)
        assert eng.source.rid == oracle.source.rid
        done = [rid for rid, _ in eng.completed]
        lost = eng.source.rid - len(done)
        assert lost == 0, f"seed {seed}: {lost} requests lost"
        assert len(set(done)) == len(done), f"seed {seed}: duplicates"
        assert not eng.queue
        assert aud.checks == ticks + drain
        # epoch fence: severed replica fenced on rejoin, floor consumed
        fenced = [e for e in cluster.events if e.reason == "Fenced"]
        assert fenced, f"seed {seed}: partition rejoin never fenced"
        assert cluster.fence_epochs == {}
        fenced_total += len(fenced)
        restored_total += sum(1 for e in cluster.events
                              if e.reason == "CrashRestored")
        # token identity vs the oracle (prefix replay, never divergence)
        for rt in rts.values():
            for rid, log in rt.token_log.items():
                assert rid in o_logs
                assert list(log) == o_logs[rid][:len(log)], \
                    f"seed {seed}: rid {rid} diverged from oracle"
                compared += 1
        # batch survived the storm; rollback bounded by the bg interval
        assert batch.bound == 3, f"seed {seed}: batch pods lost"
        for name, got, exp in batch.mismatches:
            assert 0 <= exp - got <= rollback_bound, \
                f"seed {seed}: {name} rolled back {exp - got} (> bound)"
            max_rollback = max(max_rollback, exp - got)
        assert recovery_s <= recovery_bound_s, \
            f"seed {seed}: recovery took {recovery_s:.0f}s"
        worst_recovery = max(worst_recovery, recovery_s)
    elapsed = time.perf_counter() - t0

    assert compared > 0
    row("chaos_soak", elapsed / (2 * (ticks + drain)) * 1e6,
        f"requests={oracle.source.rid};lost=0;duplicates=0;"
        f"token_prefix_checked={compared};fenced={fenced_total};"
        f"crash_restored={restored_total};max_rollback={max_rollback};"
        f"recovery_worst_s={worst_recovery:.0f};"
        f"recovery_bound_s={recovery_bound_s:.0f};"
        f"audit_ticks={2 * (ticks + drain)};seeds=2")


def bench_overload_brownout():
    """Overload protection & graceful degradation (ISSUE-9 capstone):
    a flash crowd 10x past aggregate capacity hits a deadline-stamped,
    tiered request mix — run three ways over the *identical* arrival
    stream: (1) the protected stack (bounded queue with lowest-tier-first
    rejection, retry budgets, brownout watermarks shedding batch ->
    standard while capping output length and disabling speculative
    decode, replica breaker armed), (2) an unprotected baseline (same
    capacity, no protection), and (3) an unloaded oracle (ample
    capacity) for the reference token streams. A second scenario kills
    a whole site at the surge peak and pays the cost-modeled checkpoint
    transfer window while serving degraded.

    Assertion gates (this bench is part of ``--check``): the protected
    run completes every latency-critical request within the SLO with
    zero LC sheds and a shed fraction under the declared bound, while
    the unprotected baseline demonstrably violates the LC SLO; every
    admitted request's output is token-identical (prefix under
    degradation caps) to the unloaded oracle; the queue stays bounded
    where the baseline's grows past it; nothing is lost — every request
    either completes exactly once or is an explicit shed with a reason;
    site loss at peak fires a SiteDrainTransfer window, replicas fail
    over cross-site, and LC protection holds throughout."""
    import jax
    from repro.configs.base import get_config
    from repro.core import qos
    from repro.core.chaos import FaultInjector, FaultSpec
    from repro.core.cluster import Cluster
    from repro.core.controllers import ControlPlane
    from repro.core.elastic import ElasticServing
    from repro.core.jrm import SliceSpec, start_vk
    from repro.core.scheduler import Scheduler, SiteTopology
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine
    from repro.streaming.runtime import RuntimeConfig

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)

    dt = 10.0
    ticks = 26 if FAST else 40
    drain = 16
    lam = 0.8                          # 8/tick base, 80/tick at peak
    slo = 6 * dt                       # latency-critical completion SLO
    shed_bound = 0.80                  # declared shed-fraction ceiling
    queue_cap = 240
    LC = qos.LATENCY_CRITICAL.value
    tiers = ((qos.BATCH.value, 0.45), (qos.STANDARD.value, 0.45),
             (LC, 0.10))
    # flash crowd through the chaos seam: 10x for 10 ticks vs a 2-replica
    # aggregate capacity of 40 req/tick — 2x past saturation at peak
    surge = FaultSpec("surge", 6 * dt, "ersap", duration=10 * dt,
                      magnitude=10.0)
    kill_tick = 10                     # scenario B: site loss at peak

    def run_side(protected, *, two_sites=False, kill_at=None,
                 service_rate=2.0):
        cluster = Cluster()
        topo = None
        if two_sites:
            # register jlab only, so both replicas deterministically bind
            # there; nersc comes up after placement (the failover target)
            topo = SiteTopology.parse("jlab:nersc:40", "",
                                      "jlab:nersc:1e-06")
            for i in range(2):
                cluster.register_node(
                    start_vk(f"j{i}", nodetype="tpu", site="jlab", now=0.0,
                             slice_spec=SliceSpec(chips=2)), 0.0)
                cluster.heartbeat(f"j{i}", 0.0)
            plane = ControlPlane(cluster,
                                 scheduler=Scheduler(cluster,
                                                     topology=topo))
        else:
            for i in range(4):
                cluster.register_node(
                    start_vk(f"n{i}", nodetype="tpu", now=0.0,
                             slice_spec=SliceSpec(chips=2)), 0.0)
                cluster.heartbeat(f"n{i}", 0.0)
            plane = ControlPlane(cluster)
        eng = StreamEngine(cfg, serving, list(cluster.nodes.values()),
                           service_rate=service_rate, max_batch=4,
                           cluster=cluster, plane=plane, record_tokens=True,
                           runtime_cfg=RuntimeConfig(max_batch=4,
                                                     admit_tail=0))
        eng.source.tiers = tiers
        if protected:
            eng.source.ttl = slo       # deadline-aware admission
            eng.queue_cap = queue_cap
            eng.brownout = qos.BrownoutController(
                delay_target_s=2 * dt, dwell_ticks=1, recover_ticks=2,
                degrade_max_new=4)
            eng.retry_budget = qos.RetryBudget(rate=0.5, burst=20.0)
            eng.breaker = qos.ReplicaBreaker(probe_after_s=3 * dt)
        eng.deploy(0.0)
        cluster.scale("ersap", 2, 0.0, source="bench")
        eng.reconcile(0.0)
        assert len(eng.pods) == 2
        if two_sites:
            assert all(cluster.nodes[p.node].site == "jlab"
                       for p in eng.pods.values())
            for i in range(2):
                cluster.register_node(
                    start_vk(f"c{i}", nodetype="tpu", site="nersc", now=0.0,
                             slice_spec=SliceSpec(chips=2)), 0.0)
                cluster.heartbeat(f"c{i}", 0.0)
        # track (arrival, priority) per rid for SLO accounting; deferred
        # re-releases keep their original stamp via setdefault
        meta = {}
        orig = eng.source.arrivals

        def tracked(t_now, t_dt, t_lam, **kw):
            out = orig(t_now, t_dt, t_lam, **kw)
            for r in out:
                meta.setdefault(r.rid, (r.arrival, r.priority))
            return out

        eng.source.arrivals = tracked
        inj = FaultInjector([surge], seed=0)
        rts, qmax = {}, 0
        for t in range(ticks + drain):
            now = t * dt
            inj.apply(cluster, now)
            eng.source.surge = inj.surge_factor("ersap")
            if kill_at is not None and t == kill_at:
                plane.drain_site("jlab", now)   # facility gone at peak
            for name, node in cluster.nodes.items():
                if kill_at is None or node.site != "jlab" or t < kill_at:
                    cluster.heartbeat(name, now)
            eng.reconcile(now)
            eng.tick(now, dt, lam=lam if t < ticks else 0.0)
            qmax = max(qmax, len(eng.queue))
            for rt in eng.runtimes.values():
                rts[id(rt)] = rt
        return eng, meta, rts, qmax

    def lc_violations(eng, meta):
        done = dict(eng.completed)
        viol = 0
        for rid, (arr, prio) in meta.items():
            if prio < LC:
                continue
            end = done.get(rid)
            if end is None or end - arr > slo:
                viol += 1
        return viol

    # unloaded oracle: same arrival stream, ample capacity — reference
    # token streams and the proof the workload itself is servable
    oracle, o_meta, o_rts, _ = run_side(False, service_rate=50.0)
    assert len(oracle.completed) == oracle.source.rid > 0
    o_logs = {}
    for rt in o_rts.values():
        for rid, log in rt.token_log.items():
            o_logs[rid] = list(log)

    t0 = time.perf_counter()
    prot, p_meta, p_rts, p_qmax = run_side(True)
    unprot, u_meta, _, u_qmax = run_side(False)
    fail, f_meta, _, _ = run_side(True, two_sites=True, kill_at=kill_tick)
    elapsed = time.perf_counter() - t0

    # identical arrival streams across all sides (protection knobs and
    # deferral never touch the RNG)
    assert prot.source.rid == oracle.source.rid == unprot.source.rid

    # exactly-once + explicit-shed accounting: nothing vanishes
    shed_rids = {rid for rid, _, _ in prot.shed}
    done = [rid for rid, _ in prot.completed]
    assert len(set(done)) == len(done), "duplicate completion"
    assert not (set(done) & shed_rids), "completed AND shed"
    assert len(done) + len(shed_rids) == prot.source.rid, "requests lost"
    assert not prot.queue and not prot.source._deferred

    # the headline gates: protected holds the LC SLO with zero sheds of
    # LC traffic and bounded shed fraction; unprotected collapses
    assert lc_violations(prot, p_meta) == 0, "protected run broke LC SLO"
    for rid, reason, _ in prot.shed:
        assert p_meta[rid][1] < LC, f"latency-critical rid {rid} shed"
    shed_frac = len(shed_rids) / prot.source.rid
    assert 0 < shed_frac <= shed_bound, f"shed_frac={shed_frac:.2f}"
    u_viol = lc_violations(unprot, u_meta)
    assert u_viol > 0, "baseline did not collapse — overload too weak"
    # brownout actually escalated (and staged back down), queue stayed
    # bounded where the baseline's grew past the cap
    assert any(new >= 2 for _, _, new, _ in prot.brownout.transitions)
    assert prot.brownout.level <= 1, "brownout never recovered"
    assert p_qmax <= queue_cap and u_qmax > queue_cap
    assert prot.rejected_total > 0 and prot.retried_total > 0

    # token identity: every admitted request's output is a prefix of the
    # unloaded oracle's stream (degradation caps length, never content)
    compared = 0
    for rt in p_rts.values():
        for rid, log in rt.token_log.items():
            assert rid in o_logs
            assert list(log) == o_logs[rid][:len(log)], \
                f"rid {rid} diverged from oracle under degradation"
            compared += 1
    assert compared > 0

    # scenario B: site loss at the surge peak — cost-modeled transfer
    # window fired, replicas failed over cross-site, LC protection held,
    # accounting stayed exact
    assert fail.transfer_windows >= 1 and fail.plane.last_transfer_s > 0
    assert any(e.reason == "SiteDrainTransfer"
               for e in fail.cluster.events)
    assert sorted({fail.cluster.nodes[p.node].site
                   for p in fail.pods.values()}) == ["nersc"]
    assert lc_violations(fail, f_meta) == 0, "LC SLO broke during failover"
    f_done = {rid for rid, _ in fail.completed}
    f_shed = {rid for rid, _, _ in fail.shed}
    assert len(f_done) + len(f_shed) == fail.source.rid

    row("overload_brownout", elapsed / (3 * (ticks + drain)) * 1e6,
        f"requests={prot.source.rid};lc_viol_protected=0;"
        f"lc_viol_baseline={u_viol};shed_frac={shed_frac:.2f};"
        f"shed_bound={shed_bound:.2f};retried={prot.retried_total};"
        f"rejected={prot.rejected_total};"
        f"shed_by={','.join(f'{k}:{v}' for k, v in sorted(prot.shed_counts.items()))};"
        f"qmax_protected={p_qmax};qmax_baseline={u_qmax};"
        f"brownout_transitions={len(prot.brownout.transitions)};"
        f"token_prefix_checked={compared};"
        f"failover_window_s={fail.plane.last_transfer_s:.1f}")


def bench_scale_bringup():
    """Event-driven control plane at 10k-node / 50k-pod scale (ISSUE-8):
    bring the fleet up, then run a churn phase — full heartbeat storms
    every tick plus evictions, walltime cuts and straggler flips — and
    report watch-bus throughput (deltas dispatched per second), per-tick
    reconcile latency, and a machine-independent polling-vs-event
    steady-state speedup measured head-to-head in one process.

    Internal assertion gates (this bench is part of ``--check``): every
    replica binds after bring-up AND after the churn settles, the
    incremental capacity index verifies against a from-scratch recompute
    at the end, bus throughput stays above an absolute floor set far
    below any healthy interpreter, and the event plane's steady-state
    tick beats polling by a comfortable margin (the point of the
    refactor: reconcile work scales with the *delta rate*, not the
    fleet size)."""
    from repro.core.cluster import Cluster, Deployment, PodTemplate
    from repro.core.controllers import ControlPlane
    from repro.core.jrm import SliceSpec, start_vk

    n_nodes = 2_000 if FAST else 10_000
    n_deps = 20 if FAST else 50
    per_dep = 500 if FAST else 1_000          # pods = n_deps * per_dep
    churn_ticks = 6 if FAST else 20
    # absolute churn-phase floor, set ~3-5x below healthy interpreter
    # rates (34k/s fast, 125k/s full on the dev box) so only a genuine
    # regression — not a slow CI runner — trips it
    events_floor = 10_000.0 if FAST else 25_000.0
    speedup_floor = 2.0                       # steady-state tick, evt vs poll
    sites = [f"site{i}" for i in range(8)]
    tol = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]
    n_pods = n_deps * per_dep

    t0 = time.perf_counter()
    cluster = Cluster()
    plane = ControlPlane(cluster)
    for i in range(n_nodes):
        cluster.register_node(
            start_vk(f"n{i}", site=sites[i % len(sites)],
                     slice_spec=SliceSpec(chips=8)), 0.0)
        cluster.heartbeat(f"n{i}", 0.0)
    for d in range(n_deps):
        cluster.apply_deployment(Deployment(
            f"d{d}", per_dep, template=PodTemplate(
                labels={"app": f"d{d}"}, tolerations=list(tol),
                request_chips=1)), 0.0)
    now = 0.0
    for _ in range(5):
        plane.step(now)
        now += 10.0
        if sum(1 for r in cluster.pods.values() if r.bound) == n_pods:
            break
    bound = sum(1 for r in cluster.pods.values() if r.bound)
    assert bound == n_pods, f"bring-up stalled at {bound}/{n_pods}"
    bringup_s = time.perf_counter() - t0

    # churn: every node heartbeats every tick (the bus load that made
    # polling necessary in the first place), plus evictions that should
    # wake parked work, walltime cuts that drain, straggler flips that
    # regroup the index
    names = list(cluster.nodes)
    tick_s = []
    churn_from = cluster.deltas_dispatched
    for t in range(churn_ticks):
        now += 10.0
        s = time.perf_counter()
        for n in names:
            cluster.heartbeat(n, now)
        pods = list(cluster.pods)
        stride = max(1, len(pods) // 50)
        for name in pods[(t * 37) % stride::stride][:50]:
            cluster.evict(name, now)
        cluster.cut_walltime(f"n{(t * 13 + 1) % n_nodes}", now, 30.0)
        for i in range(5):
            nd = f"n{(t * 101 + i * 7) % n_nodes}"
            st = cluster.node_status[nd]
            cluster.set_node_status(nd, now, ready=st.ready,
                                    straggler=not st.straggler)
        plane.step(now)
        tick_s.append(time.perf_counter() - s)
    # bus throughput over the churn phase only: bring-up wall time is
    # dominated by the 50k actual binds (pod objects, containers, the
    # ledger), which is placement work, not event pumping
    events_per_s = (cluster.deltas_dispatched - churn_from) / sum(tick_s)
    # settle: drained/evicted replicas must all re-bind
    for _ in range(30):
        now += 10.0
        for n in names:
            cluster.heartbeat(n, now)
        plane.step(now)
        if sum(1 for r in cluster.pods.values() if r.bound) == n_pods:
            break
    bound = sum(1 for r in cluster.pods.values() if r.bound)
    assert bound == n_pods, f"churn never settled: {bound}/{n_pods}"
    plane.scheduler._index.verify(now)
    elapsed = time.perf_counter() - t0
    assert events_per_s >= events_floor, \
        f"bus throughput {events_per_s:.0f}/s below floor {events_floor:.0f}"

    # head-to-head: identical steady-state cluster (all replicas bound,
    # every node heartbeating), one tick measured under each plane. The
    # polling tick scans the whole fleet; the event tick does O(deltas).
    def steady_tick_us(polling):
        c = Cluster()
        p = ControlPlane(c, polling=polling)
        small = 300 if FAST else 400
        for i in range(small):
            c.register_node(
                start_vk(f"m{i}", slice_spec=SliceSpec(chips=4)), 0.0)
            c.heartbeat(f"m{i}", 0.0)
        c.apply_deployment(Deployment("svc", small * 2, template=PodTemplate(
            labels={"app": "svc"}, tolerations=list(tol),
            request_chips=1)), 0.0)
        p.step(0.0)
        assert sum(1 for r in c.pods.values() if r.bound) == small * 2
        state = {"now": 0.0}

        def tick():
            state["now"] += 10.0
            for n in c.nodes:
                c.heartbeat(n, state["now"])
            p.step(state["now"])

        return _timeit(tick, n=20 if FAST else 40, warmup=3)

    poll_us = steady_tick_us(polling=True)
    evt_us = steady_tick_us(polling=False)
    steady_speedup = poll_us / evt_us
    assert steady_speedup >= speedup_floor, \
        f"steady-state speedup {steady_speedup:.1f}x < {speedup_floor}x"

    lat = sorted(tick_s)
    p50 = lat[len(lat) // 2] * 1e3
    p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3
    row("scale_bringup", sum(tick_s) / len(tick_s) * 1e6,
        f"nodes={n_nodes};pods={n_pods};bringup_s={bringup_s:.2f};"
        f"total_s={elapsed:.2f};"
        f"events_dispatched={cluster.deltas_dispatched};"
        f"events_per_s={events_per_s:.0f};events_floor={events_floor:.0f};"
        f"churn_tick_p50_ms={p50:.1f};churn_tick_p99_ms={p99:.1f};"
        f"steady_poll_us={poll_us:.0f};steady_event_us={evt_us:.0f};"
        f"steady_speedup={steady_speedup:.2f};"
        f"speedup_floor={speedup_floor};fast={FAST}")


# ------------------------------------------------------- serving runtime

def bench_serving_throughput():
    """Slot-slab continuous-batching runtime vs the pre-PR chunked path on
    qwen2-7b ``.reduced()``: same request set (randomized prompt_len /
    max_new), tokens/s of *useful* tokens (sum of max_new). Both paths get
    a warm-up pass so the headline number is steady-state; cold (compiling)
    pass time is reported alongside — retrace avoidance is most of the
    cold-path story. Persists BENCH_serving.json."""
    import jax
    from repro.configs.base import get_config
    from repro.core.elastic import ElasticServing
    from repro.core.jrm import SliceSpec, start_vk
    from repro.data.pipeline import RequestSource
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    n_req = 24 if FAST else 96

    def request_set():
        # mixed generation lengths: the workload where chunked serving
        # over-decodes every request to its chunk's max (and where the
        # runtime's per-slot accounting pays exact cost)
        src = RequestSource(seed=7, prompt_range=(8, 48),
                            max_new_range=(2, 32))
        return src.arrivals(0.0, 1.0, lam=float(n_req))

    def run_path(use_runtime):
        nodes = [start_vk("bench-n0", now=0.0,
                          slice_spec=SliceSpec(chips=4))]
        eng = StreamEngine(cfg, serving, nodes, service_rate=1e9,
                           max_batch=8, use_runtime=use_runtime)
        eng.deploy(0.0)

        def one_pass(now):
            eng.queue.extend(request_set())
            t0 = time.perf_counter()
            eng.tick(now, 1.0, lam=0.0)
            return time.perf_counter() - t0

        # fast mode feeds the --check guard: more warm samples tighten the
        # min against co-tenant noise on shared runners
        n_pass = 7 if FAST else 4
        cold = one_pass(0.0)
        warm = min(one_pass(float(t)) for t in range(1, n_pass))
        tokens = sum(r.max_new for r in request_set())
        out = {"cold_s": round(cold, 4), "s": round(warm, 4),
               "tok_per_s": round(tokens / warm, 1), "useful_tokens": tokens}
        if use_runtime and eng.runtimes:
            rt = next(iter(eng.runtimes.values()))
            out["traces"] = dict(rt.kernels.trace_counts)
            out["trace_bound"] = rt.kernels.max_traces
        assert len(eng.completed) == n_pass * len(request_set())
        return out

    chunked = run_path(False)
    runtime = run_path(True)
    speedup = chunked["s"] / runtime["s"]
    cold_speedup = chunked["cold_s"] / runtime["cold_s"]
    report = {"name": "serving_throughput", "arch": f"{cfg.name}.reduced",
              "requests": n_req, "fast": FAST, "chunked": chunked,
              "runtime": runtime, "speedup": round(speedup, 2),
              "cold_speedup": round(cold_speedup, 2)}
    write_serving("serving_throughput", report)
    row("serving_throughput", runtime["s"] * 1e6,
        f"runtime_tok_per_s={runtime['tok_per_s']};"
        f"chunked_tok_per_s={chunked['tok_per_s']};"
        f"speedup={speedup:.2f};cold_speedup={cold_speedup:.2f};"
        f"admit_traces={runtime['traces']['admit']};"
        f"decode_traces={runtime['traces']['decode']}")


def bench_paged_decode():
    """Length-proportional decode (paged KV slab) vs the PR-2 dense slab on
    a length-skewed, short-heavy request mix (varied ``max_new``) — the
    workload where the dense slab wastes the most: every row pays
    full-capacity attention/HBM no matter how short its request. Four
    runtimes, same model, same requests:

      dense       max_batch=8, per-slot capacity slab, plain full-width
                  attention — the PR-2 configuration (the baseline)
      dense_skip  same slab, jnp block-skip decode (this PR's dispatch
                  layer on the old layout: compute already tracks the
                  deepest live row, HBM still rows x capacity)
      paged       max_batch=8, paged pool — decode reads only the live
                  kv bucket, admission allocates per-request footprints
      paged_wide  equal-HBM configuration: the pool holds exactly the
                  dense slab's KV entries, but short-request footprints
                  let max_batch grow 3x — the PagedAttention batch story

    Steady-state tokens/s per path; ``speedup`` (headline, asserted >=1.5x
    by --check) is paged_wide vs dense at equal HBM. Persists into
    BENCH_serving.json."""
    import jax
    from repro.configs.base import get_config
    from repro.core.elastic import ElasticServing
    from repro.data.pipeline import Request
    from repro.models import model_api as MA
    from repro.streaming.runtime import DecodeRuntime, RuntimeConfig

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    n_req = 32 if FAST else 96

    def request_set():
        # short-heavy, max_new varied — against a slab *provisioned* for
        # 256-token prompts + 256 generated (the serving posture: admit up
        # to the configured max, observe mostly short). The dense slab
        # pays its provisioned capacity per decode step; paged pays only
        # what is live.
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(n_req):
            plen = int(rng.integers(4, 31))
            mnew = int(rng.integers(2, 33))
            reqs.append(Request(i + 1, 0.0, plen, mnew))
        return reqs

    shape = dict(max_batch=8, max_prompt_bucket=256, max_new_cap=256)
    dense_cfg = RuntimeConfig(paged=False, block_skip=0, **shape)
    dense_entries = (dense_cfg.max_batch + 1) * dense_cfg.capacity
    pool = dense_entries // 32                 # equal-HBM page budget
    variants = {
        "dense": dense_cfg,
        "dense_skip": RuntimeConfig(paged=False, **shape),
        "paged": RuntimeConfig(paged=True, page_size=32, **shape),
        "paged_wide": RuntimeConfig(paged=True, page_size=32,
                                    pool_pages=pool,
                                    **dict(shape, max_batch=24)),
    }
    tokens = sum(r.max_new for r in request_set())

    def run_variant(rcfg):
        rt = DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                           gen=serving.build_gen)

        def one_pass():
            rt.submit(request_set())
            t0 = time.perf_counter()
            done = rt.pump()
            assert len(done) == n_req
            return time.perf_counter() - t0

        cold = one_pass()
        warm = min(one_pass() for _ in range(5 if FAST else 3))
        out = {"cold_s": round(cold, 4), "s": round(warm, 4),
               "tok_per_s": round(tokens / warm, 1),
               "traces": dict(rt.kernels.trace_counts),
               "trace_bound": rt.kernels.max_traces}
        if rcfg.paged:
            out["pages_hwm"] = rt.pages_hwm
            out["kv_entries"] = rt.alloc.n_pages * rcfg.page_size
        else:
            out["kv_entries"] = dense_entries
        return out

    res = {k: run_variant(v) for k, v in variants.items()}
    speedup = res["dense"]["s"] / res["paged_wide"]["s"]
    same_slots = res["dense"]["s"] / res["paged"]["s"]
    skip_only = res["dense"]["s"] / res["dense_skip"]["s"]
    report = {"name": "paged_decode", "arch": f"{cfg.name}.reduced",
              "requests": n_req, "useful_tokens": tokens, "fast": FAST,
              **res, "speedup": round(speedup, 2),
              "same_slot_speedup": round(same_slots, 2),
              "block_skip_speedup": round(skip_only, 2)}
    write_serving("paged_decode", report)
    row("paged_decode", res["paged_wide"]["s"] * 1e6,
        f"dense_tok_per_s={res['dense']['tok_per_s']};"
        f"dense_skip_tok_per_s={res['dense_skip']['tok_per_s']};"
        f"paged_tok_per_s={res['paged']['tok_per_s']};"
        f"paged_wide_tok_per_s={res['paged_wide']['tok_per_s']};"
        f"speedup={speedup:.2f};same_slot_speedup={same_slots:.2f};"
        f"block_skip_speedup={skip_only:.2f};"
        f"pages_hwm={res['paged_wide']['pages_hwm']}")


def bench_prefix_reuse():
    """Prefix-sharing admission + multi-token speculative decode vs the
    PR-4 paged baseline, two phases on one model build:

    Phase 1 (admission): an 80%-shared request mix — four prompt template
    groups plus 20% unique prompts — against a warm prefix cache (one
    long-lived paver per group holds the interned pages live, the serving
    posture for system-prompt traffic). With ``prefix_cache`` on, every
    grouped admission is a splice (host page-table write + refcount++ +
    one device stamp, zero prefill FLOPs) and only the unique 20% prefill;
    off is PR-4: every admission prefills its full prompt.
    ``admit_speedup`` times the admission dispatch sequence alone (the
    slab holds the whole mix, so no decode blocks ride along);
    ``pages_hwm`` drops because grouped rows share their prompt pages.

    Phase 2 (speculative decode): replay traffic — one paver streams a
    prompt to completion, then a batch of identical requests is served
    again (greedy decode is deterministic, so the drafter replays the
    paver's stream near-perfectly). ``spec_speedup`` = k-token verify
    dispatches (spec_decode=k) vs the ISSUE baseline of one token per
    dispatch (decode_block=1). Tokens are byte-identical either way —
    the accept-prefix rule only changes dispatch count, never content.

    Both phases warm up with the *same request ids* they then measure:
    identical content => identical acceptance trajectories => identical
    dispatch shapes, so the measured pass is fully trace-cached.
    Persists into BENCH_serving.json; --check floors admit_speedup and
    spec_speedup."""
    import jax
    from repro.configs.base import get_config
    from repro.core.elastic import ElasticServing
    from repro.data.pipeline import Request
    from repro.kernels import ops as OPS
    from repro.models import model_api as MA
    from repro.streaming.runtime import DecodeRuntime, RuntimeConfig

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    n_req = 40 if FAST else 80
    plen, n_groups = 64, 4
    plen_a = 128                  # admission-phase prompts (prefill-heavy)

    def admit_set():
        # 80% of requests carry a template group's full prompt; i%5==0
        # stays unique. max_new=2 keeps the phase admission-dominated.
        return [Request(i + 1, 0.0, plen_a, 2,
                        prefix_group=0 if i % 5 == 0 else i % n_groups + 1,
                        prefix_len=0 if i % 5 == 0 else plen_a)
                for i in range(n_req)]

    def pavers():
        # one long-lived holder per template keeps its interned prompt
        # pages referenced (and so cached) across the measured admission
        return [Request(10_000 + g, 0.0, plen_a, 24,
                        prefix_group=g, prefix_len=plen_a)
                for g in range(1, n_groups + 1)]

    def run_admit(prefix_cache):
        # slab sized to hold the whole mix at once: the timed region is
        # the admission dispatch sequence alone (prefill vs splice), no
        # decode blocks riding in the measurement
        rcfg = RuntimeConfig(paged=True, page_size=16,
                             max_batch=n_req + n_groups,
                             max_prompt_bucket=plen_a,
                             admit_tail=0, prefix_cache=prefix_cache)
        rt = DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                           gen=serving.build_gen)

        def one_pass():
            rt.submit(pavers())
            rt.step()                      # admit the template holders
            rt.submit(admit_set())
            t0 = time.perf_counter()
            rt._admit_some()
            dt = time.perf_counter() - t0
            assert not rt.pending and rt.inflight == n_req + n_groups
            while rt.inflight:             # drain everything untimed
                rt.step()
            return dt

        cold = one_pass()
        warm = min(one_pass() for _ in range(5 if FAST else 3))
        return {"cold_s": round(cold, 4), "s": round(warm, 4),
                "admit_tok_per_s": round(n_req * plen_a / warm, 1),
                "pages_hwm": rt.pages_hwm,
                "prefix_hits": rt.prefix_hits,
                "prefix_lookups": rt.prefix_lookups,
                "traces": dict(rt.kernels.trace_counts),
                "trace_bound": rt.kernels.max_traces}

    def run_spec(k):
        rcfg = RuntimeConfig(paged=True, page_size=16, max_batch=8,
                             admit_tail=0, spec_decode=k,
                             decode_block=1 if k == 0 else 16)
        rt = DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                           gen=serving.build_gen)
        dep = 32 if FAST else 64

        def replay(rid0):
            return [Request(rid0 + j, 0.0, plen, dep,
                            prefix_group=1, prefix_len=plen)
                    for j in range(8)]

        rt.submit([Request(1, 0.0, plen, dep,
                           prefix_group=1, prefix_len=plen)])
        rt.pump()                          # pave the stream (untimed)
        rt.submit(replay(2))
        rt.pump()                          # warm: same rids as measured
        rt.submit(replay(2))
        t0 = time.perf_counter()
        done = rt.pump()
        dt = time.perf_counter() - t0
        assert len(done) == 8
        out = {"s": round(dt, 4),
               "tok_per_s": round(8 * dep / dt, 1),
               "traces": dict(rt.kernels.trace_counts)}
        if k:
            out["accept_rate"] = round(rt.spec_accept_rate, 3)
            out["rounds"] = rt.spec_rounds
        return out

    on, off = run_admit(True), run_admit(False)
    admit_speedup = off["s"] / on["s"]
    spec, base = run_spec(3), run_spec(0)
    spec_speedup = base["s"] / spec["s"]
    report = {"name": "prefix_reuse", "arch": f"{cfg.name}.reduced",
              "requests": n_req, "fast": FAST,
              "kernel_mode": OPS.resolved_mode(),
              "prefix_on": on, "prefix_off": off,
              "admit_speedup": round(admit_speedup, 2),
              "spec_k3": spec, "one_token": base,
              "spec_speedup": round(spec_speedup, 2)}
    write_serving("prefix_reuse", report)
    row("prefix_reuse", on["s"] * 1e6,
        f"admit_speedup={admit_speedup:.2f};"
        f"admit_tok_per_s={on['admit_tok_per_s']};"
        f"baseline_admit_tok_per_s={off['admit_tok_per_s']};"
        f"hit_rate={on['prefix_hits'] / max(on['prefix_lookups'], 1):.2f};"
        f"pages_hwm={on['pages_hwm']};baseline_pages_hwm={off['pages_hwm']};"
        f"spec_speedup={spec_speedup:.2f};"
        f"spec_accept_rate={spec['accept_rate']};"
        f"kernel_mode={report['kernel_mode']}")


# ---------------------------------------------------------------- kernels

def bench_kernel_flash_attention():
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    us_ref = _timeit(lambda: jax.block_until_ready(ref(q, k, v)), n=20)
    out_k = flash_attention(q, k, v, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref(q, k, v))))
    row("kernel_flash_attention", us_ref,
        f"jnp_oracle_us={us_ref:.0f};interpret_allclose_err={err:.1e}")


def bench_kernel_mlstm():
    import jax
    import jax.numpy as jnp
    from repro.models.xlstm import mlstm_chunkwise
    from repro.kernels.mlstm_scan import mlstm_chunkwise_kernel
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, dh = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh)) * dh ** -0.5
    v = jax.random.normal(ks[2], (B, S, H, dh))
    li = jax.random.normal(ks[3], (B, S, H))
    lf = jax.random.normal(ks[4], (B, S, H))
    jnp_fn = jax.jit(lambda *a: mlstm_chunkwise(*a)[0])
    us = _timeit(lambda: jax.block_until_ready(jnp_fn(q, k, v, li, lf)), n=20)
    hk, _ = mlstm_chunkwise_kernel(q, k, v, li, lf, interpret=True)
    err = float(jnp.max(jnp.abs(hk - jnp_fn(q, k, v, li, lf))))
    row("kernel_mlstm_chunkwise", us,
        f"jnp_chunkwise_us={us:.0f};interpret_allclose_err={err:.1e}")


def bench_kernel_ssm():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ref import ssm_ref
    from repro.kernels.ssm_scan import ssm_scan_kernel
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    B, S, di, N = 1, 256, 256, 16
    u = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, N)))
    Bs = jax.random.normal(ks[3], (B, S, N))
    Cs = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (di,))
    ref = jax.jit(lambda *a: ssm_ref(*a)[0])
    us = _timeit(lambda: jax.block_until_ready(ref(u, dt, A, Bs, Cs, D)), n=10)
    yk, _ = ssm_scan_kernel(u, dt, A, Bs, Cs, D, interpret=True)
    err = float(jnp.max(jnp.abs(yk - ref(u, dt, A, Bs, Cs, D))))
    row("kernel_ssm_scan", us,
        f"jnp_oracle_us={us:.0f};interpret_allclose_err={err:.1e}")


def bench_kernel_decode_attention():
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attention import decode_attention_kernel
    from repro.kernels.ref import decode_attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (4, 8, 64))
    kc = jax.random.normal(ks[1], (4, 1024, 2, 64))
    vc = jax.random.normal(ks[2], (4, 1024, 2, 64))
    lens = jnp.asarray([100, 512, 900, 1024], jnp.int32)
    ref = jax.jit(lambda q, k, v, l: decode_attention_ref(q, k, v, lengths=l))
    us = _timeit(lambda: jax.block_until_ready(ref(q, kc, vc, lens)), n=20)
    ok = decode_attention_kernel(q, kc, vc, lens, interpret=True)
    err = float(jnp.max(jnp.abs(ok - ref(q, kc, vc, lens))))
    row("kernel_decode_attention", us,
        f"jnp_oracle_us={us:.0f};interpret_allclose_err={err:.1e}")


# ----------------------------------------------------------------- roofline

def bench_roofline():
    """Summarize dry-run roofline artifacts. The dry-run is its own
    process (``python -m repro.launch.dryrun``, pre-jax device-count flag)
    and its artifacts are not committed — so when none exist this row says
    *why* it carries no signal instead of reporting a misleading
    ``cells_ok=0``."""
    base = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    cells = [f for mesh in ("pod", "multipod")
             for f in sorted((base / mesh).glob("*.json"))
             if (base / mesh).exists()]
    if not cells:
        row("roofline_dryrun_summary", 0.0,
            "status=skipped;reason=no dryrun artifacts under "
            "experiments/dryrun (generate: python -m repro.launch.dryrun"
            " --all)")
        return
    n_ok, n_err, worst = 0, 0, None
    for f in cells:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            n_err += 1
            continue
        n_ok += 1
        frac = r.get("useful_flops_ratio", 0.0)
        if f.parent.name == "pod" and (worst is None or frac < worst[1]):
            worst = (f"{r['arch']}x{r['shape']}", frac)
    from repro.kernels import ops as OPS
    derived = f"status=ok;cells_ok={n_ok};cells_err={n_err}"
    if worst:
        derived += f";worst_useful_flops={worst[0]}:{worst[1]:.3f}"
    # self-describing record: which kernel dispatch produced these numbers
    derived += f";kernel_mode={OPS.resolved_mode()}"
    row("roofline_dryrun_summary", 0.0, derived)


def bench_observability_overhead():
    """Observability cost floor: steady-state tokens/s with the full
    plane wired (lifecycle Tracer + FlightRecorder with burn-rate
    checks + TickProfiler) vs stock, same engine configuration and
    manufactured request set. Tracing must be cheap enough to leave on:
    ``--check`` floors ``tokens_ratio`` (on/off) at 0.95, i.e. <5%
    throughput cost. The on-path report carries the pump/tick phase
    breakdown so BENCH_serving.json records where the tick goes."""
    import jax
    from repro.configs.base import get_config
    from repro.core.elastic import ElasticServing
    from repro.core.jrm import SliceSpec, start_vk
    from repro.core.observability import FlightRecorder, SLOConfig, \
        TickProfiler
    from repro.core.tracing import Tracer
    from repro.data.pipeline import RequestSource
    from repro.models import model_api as MA
    from repro.streaming.engine import StreamEngine

    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    n_req = 24 if FAST else 96

    def request_set():
        src = RequestSource(seed=11, prompt_range=(8, 48),
                            max_new_range=(2, 32))
        return src.arrivals(0.0, 1.0, lam=float(n_req))

    def mk_engine(tag, obs):
        nodes = [start_vk(f"obs-{tag}", now=0.0,
                          slice_spec=SliceSpec(chips=4))]
        eng = StreamEngine(cfg, serving, nodes, service_rate=1e9,
                           max_batch=8, use_runtime=True)
        eng.deploy(0.0)
        if obs:
            tracer = Tracer()
            # a finite (never-tripping) SLO so check() pays the real
            # burn-rate evaluation cost every tick
            eng.enable_observability(
                tracer=tracer,
                recorder=FlightRecorder(tracer, slo=SLOConfig(lc_p99_s=1e9)),
                profiler=TickProfiler())
        return eng

    def one_pass(eng, now):
        eng.queue.extend(request_set())
        t0 = time.perf_counter()
        eng.tick(now, 1.0, lam=0.0)
        if eng.recorder is not None:
            eng.recorder.check(now)
        return time.perf_counter() - t0

    # interleaved min-of-many warm passes: the contrast is a few
    # percent, so a sustained noise window hitting only one path's
    # measurement run would swamp the signal the 0.95 --check floor
    # guards — alternating passes exposes both engines to the same
    # ambient conditions, and min-of-N converges each to its floor
    eng_off = mk_engine("off", False)
    eng_on = mk_engine("on", True)
    n_pass = 13 if FAST else 7
    cold = {"off": one_pass(eng_off, 0.0), "on": one_pass(eng_on, 0.0)}
    warm = {"off": math.inf, "on": math.inf}
    for t in range(1, n_pass):
        warm["off"] = min(warm["off"], one_pass(eng_off, float(t)))
        warm["on"] = min(warm["on"], one_pass(eng_on, float(t)))
    tokens = sum(r.max_new for r in request_set())

    def path_report(key, eng):
        out = {"cold_s": round(cold[key], 4), "s": round(warm[key], 4),
               "tok_per_s": round(tokens / warm[key], 1)}
        if eng.tracer is not None:
            assert eng.tracer.spans, "observability on but no spans"
            out["spans"] = len(eng.tracer.spans)
            out["profile"] = eng.profiler.summary()
        return out

    off = path_report("off", eng_off)
    on = path_report("on", eng_on)
    ratio = on["tok_per_s"] / off["tok_per_s"]
    report = {"name": "observability_overhead",
              "arch": f"{cfg.name}.reduced", "requests": n_req,
              "fast": FAST, "off": off, "on": on,
              "tokens_ratio": round(ratio, 3)}
    write_serving("observability_overhead", report)
    row("observability_overhead", on["s"] * 1e6,
        f"tokens_ratio={ratio:.3f};on_tok_per_s={on['tok_per_s']};"
        f"off_tok_per_s={off['tok_per_s']};spans={on['spans']}")


BENCHES = [
    bench_lifecycle_create, bench_lifecycle_monitor,
    bench_hpa_formula, bench_hpa_scaling,
    bench_queue_16, bench_queue_32,
    bench_dbn_tracking, bench_dbn_control,
    bench_deployment_40, bench_control_plane_churn, bench_federation_churn,
    bench_priority_spike, bench_chaos_soak, bench_overload_brownout,
    bench_scale_bringup,
    bench_serving_throughput, bench_paged_decode, bench_prefix_reuse,
    bench_observability_overhead,
    bench_kernel_flash_attention, bench_kernel_mlstm, bench_kernel_ssm,
    bench_kernel_decode_attention,
    bench_roofline,
]

# ratio metrics guarded by --check: machine-independent speedups measured
# within one process, so a CI runner's absolute speed does not matter.
# key -> (report name in BENCH_serving.json, metric field, description)
CHECK_METRICS = {
    "serving_throughput": ("serving_throughput", "speedup",
                           "slot-slab runtime vs chunked path"),
    "paged_decode": ("paged_decode", "speedup",
                     "paged KV slab vs dense slab (equal HBM)"),
    "prefix_admit": ("prefix_reuse", "admit_speedup",
                     "prefix-cache admission vs PR-4 paged admission"),
    "spec_decode": ("prefix_reuse", "spec_speedup",
                    "k-token speculative decode vs 1-token-per-dispatch"),
    "observability": ("observability_overhead", "tokens_ratio",
                      "full tracing/recorder/profiler plane on vs off"),
}


def _check_ratios(report):
    return {key: report[rkey][metric] for key, (rkey, metric, _) in
            CHECK_METRICS.items() if rkey in report}


def run_check(tol: float, record: bool) -> int:
    """Benchmark regression guard (CI: ``benchmarks/run.py --check``).

    Re-runs the serving benches in fast-smoke mode and compares their
    speedup ratios against the ``fast_baseline`` stanza committed in
    BENCH_serving.json; a ratio more than ``tol`` below baseline fails the
    job instead of silently uploading worse numbers. Also enforces the
    semantic floors (runtime beats chunked; paged clearly beats dense —
    the full >=1.5x claim lives in the committed full-run numbers) and
    the jit trace bound, and fast-smokes ``bench_priority_spike``,
    ``bench_chaos_soak``, ``bench_overload_brownout`` and
    ``bench_scale_bringup``, whose internal assertions (zero serving
    loss, bounded p99, exactly-once chaos recovery, zero
    latency-critical SLO violations under overload with bounded shed
    fraction, scale-floor throughput) fail the job directly. Noise posture on shared runners: the recorded
    baseline is the *min* of two smoke runs (the slowest healthy
    observation) while enforcement takes the *best* of up to two runs, so
    only a genuine regression trips the ``tol`` gap. ``record=True``
    refreshes the baseline stanza in-place (run after a deliberate perf
    change, commit the JSON)."""
    global FAST, JSON_DIR
    path = ROOT / "BENCH_serving.json"
    committed = json.loads(path.read_text()) if path.exists() else {}
    FAST = True
    if JSON_DIR == ROOT:
        # never clobber the committed full-run JSONs with smoke numbers —
        # the fresh fast report lands next to them instead
        JSON_DIR = ROOT / "bench_check"
        JSON_DIR.mkdir(exist_ok=True)
    # assertion-based gates first (cheap, no ratio to baseline): QoS
    # invariants, then the chaos soak's robustness floor (zero loss,
    # exactly-once, token-identical recovery, bounded recovery latency)
    bench_priority_spike()
    bench_chaos_soak()
    bench_overload_brownout()
    bench_scale_bringup()

    def smoke():
        bench_serving_throughput()
        bench_paged_decode()
        bench_prefix_reuse()
        bench_observability_overhead()
        return json.loads((JSON_DIR / "BENCH_serving.json").read_text())

    def evaluate(ratios, baseline):
        failures = []
        if ratios.get("serving_throughput", 0.0) <= 1.0:
            failures.append("slot-slab runtime slower than the chunked path")
        if ratios.get("paged_decode", 0.0) < 1.2:
            failures.append(f"paged decode speedup "
                            f"{ratios.get('paged_decode')} < 1.2x smoke floor")
        if ratios.get("prefix_admit", 0.0) < 3.0:
            failures.append(f"prefix-cache admission speedup "
                            f"{ratios.get('prefix_admit')} < 3.0x floor")
        if ratios.get("spec_decode", 0.0) < 1.3:
            failures.append(f"speculative decode speedup "
                            f"{ratios.get('spec_decode')} < 1.3x floor")
        if ratios.get("observability", 0.0) < 0.95:
            failures.append(f"observability plane costs >5% tokens/s "
                            f"(on/off ratio "
                            f"{ratios.get('observability')} < 0.95)")
        for key, got in sorted(ratios.items()):
            base = baseline.get(key)
            if base is not None and (base - got) / base > tol:
                failures.append(
                    f"{key}: speedup {got} regressed >"
                    f"{tol * 100:.0f}% from committed baseline {base} "
                    f"({CHECK_METRICS[key][2]})")
        return failures

    fresh = smoke()
    rt = fresh["serving_throughput"]["runtime"]
    trace_fail = ([f"jit trace count {rt['traces']} exceeds bound "
                   f"{rt['trace_bound']}"]
                  if rt["traces"]["admit"] + rt["traces"]["decode"]
                  > rt["trace_bound"] else [])
    ratios = _check_ratios(fresh)
    if record:
        second = _check_ratios(smoke())
        ratios = {k: round(min(v, second.get(k, v)), 2)
                  for k, v in ratios.items()}
        committed = committed or fresh
        committed["fast_baseline"] = ratios
        path.write_text(json.dumps(committed, indent=2) + "\n")
        print(f"[check] recorded fast_baseline={ratios} "
              f"(min of two smoke runs)")
    baseline = committed.get("fast_baseline", {})
    failures = evaluate(ratios, baseline)
    if failures and not record:
        print(f"[check] first run failed ({len(failures)} finding(s)) — "
              f"retrying once against smoke noise")
        second = _check_ratios(smoke())
        ratios = {k: max(v, second.get(k, v)) for k, v in ratios.items()}
        failures = evaluate(ratios, baseline)
    failures = trace_fail + failures
    for key, got in sorted(ratios.items()):
        base = baseline.get(key)
        verdict = ("no-baseline" if base is None else
                   f"baseline={base} drop={(base - got) / base * 100:+.0f}%")
        print(f"[check] {key}: speedup={got} ({verdict})")
    for f in failures:
        print(f"[check] FAIL: {f}")
    if not failures:
        print("[check] OK")
    return 1 if failures else 0


def main(argv=None) -> int:
    global FAST, JSON_DIR
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only benches whose name contains this")
    ap.add_argument("--fast", action="store_true",
                    help="shrink expensive workloads (CI smoke)")
    ap.add_argument("--json-dir", default=str(ROOT))
    ap.add_argument("--check", action="store_true",
                    help="fast-smoke the serving benches and fail on a"
                         " throughput regression vs the committed"
                         " BENCH_serving.json baselines")
    ap.add_argument("--check-tol", type=float, default=0.25,
                    help="allowed fractional speedup regression in --check")
    ap.add_argument("--record-check-baseline", action="store_true",
                    help="with --check: refresh the committed"
                         " fast_baseline stanza instead of enforcing it")
    args = ap.parse_args(argv)
    FAST = args.fast
    JSON_DIR = pathlib.Path(args.json_dir)
    JSON_DIR.mkdir(parents=True, exist_ok=True)
    if args.check:
        return run_check(args.check_tol, args.record_check_baseline)
    print("name,us_per_call,derived")
    for b in BENCHES:
        if args.only and args.only not in b.__name__:
            continue
        n0 = len(RESULTS)
        t0 = time.perf_counter()
        b()
        # stamp the bench's wall-clock (setup + all passes) on every row
        # it emitted, so BENCH_run.json tracks where the suite's time goes
        wall = round(time.perf_counter() - t0, 3)
        for r in RESULTS[n0:]:
            r["wall_s"] = wall
    (JSON_DIR / "BENCH_run.json").write_text(
        json.dumps(RESULTS, indent=2) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
