"""Quickstart: bring up the JIRIAF control plane across two facilities,
lease nodes, declare a model workload pod in the Cluster store, let the
site-aware scheduler place it, and run a real forward pass on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.jcs import CentralService
from repro.core.jfe import FrontEnd
from repro.core.jfm import FacilityManager
from repro.core.jrm import SliceSpec
from repro.core.scheduler import Scheduler, SiteTopology
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA

# 1. user files one workflow spanning two facilities (JFE)
fe = FrontEnd()
wfs = fe.add_multi_wf("vk-quick", {"jlab": 1, "perlmutter": 1},
                      nodetype="tpu", walltime=600.0)
for wf in wfs:
    print(f"[jfe] workflow {wf.wf_id} (group {wf.group}): "
          f"{wf.nnodes} x {wf.nodetype} @ {wf.site}")

# 2. central service launches one pilot per site (JCS -> JRM/VK) and
#    registers the nodes straight into the Cluster object store
jcs = CentralService(fe)
cluster = Cluster()
pilots = jcs.launch_multi(wfs, now=0.0, slice_spec=SliceSpec(chips=4),
                          cluster=cluster)
for pilot in pilots:
    print(f"[jcs] pilot up: {pilot.nodes} ({len(pilot.tunnels)} SSH tunnels)")

# 3. facility manager feeds node heartbeats into the store (JFM); the
#    store aggregates each facility into a SiteView
fm = FacilityManager()
fm.feed(cluster, 5.0)
for site, view in cluster.site_views(5.0).items():
    print(f"[site] {site}: {view.free_chips} free chips, "
          f"runway={view.remaining_walltime:.0f}s")

# 4. declare the pod; the reconciling scheduler binds it. The EJFAT input
#    stream lives at JLab, so data-locality scoring pins the pod there
#    even though both sites have room.
topo = SiteTopology(data_sites={"ejfat": "jlab"}).connect(
    "jlab", "perlmutter", 62.0)
cfg = get_config("qwen2-7b").reduced()
pod = Pod("qwen-serve", [Container("decode-worker")],
          tolerations=[{"key": "virtual-kubelet.io/provider", "value": "mock"}],
          affinity=[{"key": "jiriaf.nodetype", "operator": "In",
                     "values": ["tpu"]},
                    {"key": "jiriaf.alivetime", "operator": "Gt",
                     "values": ["60"]}],
          request_chips=2, request_hbm_bytes=1 << 30)
cluster.submit(pod, 5.0, expected_duration=120.0, data_stream="ejfat")
decisions = Scheduler(cluster, topology=topo).run_once(5.0)
node = cluster.nodes[decisions[0].node]
print(f"[scheduler] {decisions[0].pod} -> {decisions[0].node} "
      f"(site {node.site}); conditions="
      f"{[(c.type, c.status.value) for c in pod.conditions]}")
print(f"[events] {cluster.event_reasons('qwen-serve')}")

# 5. the pod's container actually runs the model
mod = MA.get_module(cfg)
params = mod.init(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
logits, cache = jax.jit(lambda p, t: mod.prefill(p, t, cfg))(params, toks)
print(f"[workload] prefill logits {logits.shape}, "
      f"next tokens {jnp.argmax(logits, -1).tolist()}")

# 6. lifecycle: monitor (Table 7 states), then complete via the public
#    terminate transition (no private-state poking)
node.get_pods(6.0)
print(f"[jrm] container state: {pod.containers[0].state.uid} "
      f"(index {pod.containers[0].state.uid_index})")
pod.containers[0].finish()
node.get_pods(7.0)
print(f"[jrm] final: {pod.containers[0].state.uid} -> pod {pod.phase.value}")
