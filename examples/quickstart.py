"""Quickstart: bring up the JIRIAF control plane, lease nodes, declare a
model workload pod in the Cluster store, let the scheduler place it, and
run a real forward pass on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.jcs import CentralService
from repro.core.jfe import FrontEnd
from repro.core.jfm import FacilityManager
from repro.core.jrm import SliceSpec
from repro.core.scheduler import Scheduler
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA

# 1. user files a workflow request (JFE)
fe = FrontEnd()
wf = fe.add_wf("vk-quick", nnodes=2, nodetype="tpu", site="local",
               walltime=600.0)
print(f"[jfe] workflow {wf.wf_id}: {wf.nnodes} x {wf.nodetype} @ {wf.site}")

# 2. central service launches pilot JRMs (JCS -> JRM/VK) and registers
#    them in the Cluster object store
jcs = CentralService(fe)
pilot = jcs.launch_pilot(wf, now=0.0, slice_spec=SliceSpec(chips=4))
cluster = Cluster()
for n in jcs.node_list():
    cluster.register_node(n, 0.0)
    cluster.heartbeat(n.name, 5.0)
print(f"[jcs] pilot up: {pilot.nodes} ({len(pilot.tunnels)} SSH tunnels)")

# 3. facility manager feeds node heartbeats into the store (JFM)
fm = FacilityManager()
fm.feed(cluster, 5.0)
print(f"[jfm] {fm.total_free_chips()} free chips")

# 4. declare the pod; the reconciling scheduler binds it
cfg = get_config("qwen2-7b").reduced()
pod = Pod("qwen-serve", [Container("decode-worker")],
          tolerations=[{"key": "virtual-kubelet.io/provider", "value": "mock"}],
          affinity=[{"key": "jiriaf.nodetype", "operator": "In",
                     "values": ["tpu"]},
                    {"key": "jiriaf.alivetime", "operator": "Gt",
                     "values": ["60"]}],
          request_chips=2, request_hbm_bytes=1 << 30)
cluster.submit(pod, 5.0, expected_duration=120.0)
decisions = Scheduler(cluster).run_once(5.0)
print(f"[scheduler] {decisions[0].pod} -> {decisions[0].node}; conditions="
      f"{[(c.type, c.status.value) for c in pod.conditions]}")
print(f"[events] {cluster.event_reasons('qwen-serve')}")

# 5. the pod's container actually runs the model
mod = MA.get_module(cfg)
params = mod.init(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
logits, cache = jax.jit(lambda p, t: mod.prefill(p, t, cfg))(params, toks)
print(f"[workload] prefill logits {logits.shape}, "
      f"next tokens {jnp.argmax(logits, -1).tolist()}")

# 6. lifecycle: monitor (Table 7 states), then complete via the public
#    terminate transition (no private-state poking)
node = cluster.nodes[pod.node]
node.get_pods(6.0)
print(f"[jrm] container state: {pod.containers[0].state.uid} "
      f"(index {pod.containers[0].state.uid_index})")
pod.containers[0].finish()
node.get_pods(7.0)
print(f"[jrm] final: {pod.containers[0].state.uid} -> pod {pod.phase.value}")
