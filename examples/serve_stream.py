"""End-to-end streaming-serving driver (the flagship example): JIRIAF
control plane + real batched prefill/decode + DBN digital-twin elastic
scaling under the paper's §6.2 pressure trajectory.

    PYTHONPATH=src python examples/serve_stream.py
(args forwarded to repro.launch.serve — e.g. --controller hpa)
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    serve.main(sys.argv[1:] or
               ["--devices", "8", "--tp", "2", "--nodes", "4",
                "--ticks", "80"])
