"""Fault-tolerant training scenario: a JRM walltime lease expires mid-run,
the trainer drains (checkpoints) inside the §4.5.4 margin, and a requeued
job resumes exactly where it left off.

    PYTHONPATH=src python examples/train_elastic.py
"""
import tempfile

from repro.launch import train

ckpt = tempfile.mkdtemp(prefix="jiriaf-ckpt-")
common = ["--arch", "qwen2-7b", "--reduced", "--steps", "60",
          "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
          "--ckpt-every", "10"]

print("=== lease 1: walltime 100s (drains at ~step 40) ===")
train.main(common + ["--walltime", "100", "--step-seconds", "1.0"])

print("\n=== lease 2: requeued job resumes from the drain checkpoint ===")
train.main(common)
print(f"\ncheckpoints in {ckpt}")
