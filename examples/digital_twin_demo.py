"""Reproduce the paper's §6 digital-twin study (Figs 8/9, Tables 8/9) as a
text report: M/M/1 theory vs tables, DBN state tracking of the piecewise
ground truth, and the control history.

    PYTHONPATH=src python examples/digital_twin_demo.py
"""
import numpy as np

from repro.core.digital_twin.control import ControlPolicy
from repro.core.digital_twin.dbn import DigitalTwin
from repro.core.digital_twin.queue_model import (MU_EXACT, TABLE_16,
                                                 TABLE_32, calc_lq,
                                                 ground_truth, observe)

print("== Eq.(3) vs Tables 8/9 Calc.Lq ==")
for threads, tab in ((16, TABLE_16), (32, TABLE_32)):
    mu = MU_EXACT[threads]
    for state, lam, _m, _u, obs, calc in tab:
        print(f"  {threads}thr state {int(state)}: lam={lam:.0f} "
              f"Lq_theory={calc_lq(lam, mu):7.2f}  table={calc:7.2f} "
              f"obs={obs:7.2f}")

print("\n== Fig. 8/9: DBN tracking + control history ==")
gt = ground_truth(80)
twin, policy = DigitalTwin(), ControlPolicy()
rng = np.random.default_rng(0)
control = 16
print(" t  truth  est  belief_max  obs_Lq  control")
for t, s in enumerate(gt):
    o = observe(s, control, rng)
    twin.assimilate(o, control)
    est = twin.estimate()
    control = policy.recommend(twin, control, t)
    if t % 4 == 0:
        print(f"{t:3d}  {s:5.1f} {est:5.2f}   state {twin.map_state()}   "
              f"{o:7.1f}   {control}")
hist = np.array([(h[0], h[1]) for h in policy.history])
switches = np.where(np.diff(hist[:, 1]) != 0)[0] + 1
print(f"\ncontrol switches at t={hist[switches, 0].astype(int).tolist()} "
      f"(paper: escalate during rising pressure, recover after)")
