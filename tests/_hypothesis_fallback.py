"""Minimal deterministic stand-in for ``hypothesis`` so the tier-1 suite
runs in clean environments (the CI workflow installs the real library and
exercises the full path).

Supports exactly what this repo's tests use: ``@settings(max_examples=N,
deadline=None)``, ``@given(**kwargs)`` with the strategies ``integers``,
``floats``, ``booleans``, ``sampled_from``, and ``data()`` with
``data.draw(...)``. Examples are drawn from a seeded PRNG, so failures
reproduce run-to-run. Example counts are capped (property sweeps stay
cheap without the real shrinker's value).
"""
from __future__ import annotations

import functools
import inspect
import random

_MAX_EXAMPLES_CAP = 25
_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example_from(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def data():
        return _Strategy(lambda rng: _Data(rng))


st = strategies


class _Data:
    """Stand-in for the interactive ``data()`` strategy object."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.example_from(self._rng)


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_EXAMPLES)
            for i in range(n):
                rng = random.Random(1_000_003 * (i + 1))
                drawn = {name: strat.example_from(rng)
                         for name, strat in strategy_kwargs.items()}
                fn(*args, **drawn, **kwargs)
        # hide strategy-filled params so pytest doesn't treat them as
        # fixtures (real hypothesis does the same)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs])
        return wrapper
    return deco
