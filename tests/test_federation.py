"""Multi-site federation: per-site pools + SiteView aggregates, site
filter/score stages (selector, anti-affinity, data locality,
latency-weighted spreading), batch drain of a whole pilot allocation with
zero request loss, and JCS proactive re-provisioning on walltime
shortfall."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.elastic import ElasticServing
from repro.core.jcs import CentralService
from repro.core.jfe import FrontEnd
from repro.core.jrm import SliceSpec, start_vk
from repro.core.scheduler import Scheduler, SiteTopology
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name="p", chips=1, hbm=0):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips, request_hbm_bytes=hbm)


def mkcluster(site_nodes, chips=4, walltime=0.0, now=0.0):
    """site_nodes: {site: n_nodes}; node names are <site><i>."""
    cluster = Cluster()
    for site, n in site_nodes.items():
        for i in range(n):
            cluster.register_node(
                start_vk(f"{site}{i}", site=site, walltime=walltime, now=now,
                         slice_spec=SliceSpec(chips=chips)), now)
            cluster.heartbeat(f"{site}{i}", now)
    return cluster


# ---------------------------------------------------------- site views

def test_site_views_aggregate_capacity_and_runway():
    cluster = mkcluster({"jlab": 2, "nersc": 1}, chips=4, walltime=300.0)
    views = cluster.site_views(0.0)
    assert set(views) == {"jlab", "nersc"}
    v = views["jlab"]
    assert v.nodes == 2 and v.ready_nodes == 2
    assert v.total_chips == 8 and v.free_chips == 8
    # runway = sum of (alive_left - drain_margin) = 2 * (300 - 60)
    assert v.remaining_walltime == pytest.approx(480.0)
    assert v.min_walltime == pytest.approx(300.0)
    # a bound pod consumes site capacity
    cluster.submit(mkpod("a", chips=3), 1.0)
    Scheduler(cluster).run_once(1.0)
    views = cluster.site_views(1.0)
    assert views["jlab"].free_chips + views["nersc"].free_chips == 9
    # infinite-lease sites report infinite runway
    infinite = mkcluster({"local": 1}, walltime=0.0)
    assert infinite.site_view("local", 0.0).remaining_walltime == float("inf")


def test_site_view_counts_draining_nodes():
    cluster = mkcluster({"jlab": 2}, walltime=100.0)
    view = cluster.site_view("jlab", 50.0)   # alive_left=50 < 60s margin
    assert view.draining_nodes == 2


# ------------------------------------------------------- filter stages

def test_site_selector_and_anti_affinity():
    cluster = mkcluster({"jlab": 1, "nersc": 1})
    sched = Scheduler(cluster)
    cluster.submit(mkpod("pinned"), 0.0, site_selector=("nersc",))
    cluster.submit(mkpod("averse"), 0.0, site_anti_affinity=("nersc",))
    sched.run_once(0.0)
    assert cluster.pods["pinned"].pod.node == "nersc0"
    assert cluster.pods["averse"].pod.node == "jlab0"
    # no site satisfies the selector -> FailedScheduling with a site reason
    rec = cluster.submit(mkpod("nowhere"), 0.0, site_selector=("ornl",))
    decisions = sched.run_once(0.0)
    assert decisions[-1].node is None and "site" in rec.last_reason


def test_preemption_requeue_keeps_site_spec():
    cluster = mkcluster({"jlab": 1, "nersc": 1}, chips=2)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("low", chips=2), 0.0, priority=0,
                   site_selector=("jlab",))
    sched.run_once(0.0)
    cluster.submit(mkpod("high", chips=2), 1.0, priority=10,
                   site_selector=("jlab",))
    sched.run_once(1.0)
    assert cluster.pods["high"].pod.node == "jlab0"
    victim = cluster.pods["low"]
    assert not victim.bound
    assert victim.site_selector == ("jlab",)   # spec survives the requeue
    sched.run_once(2.0)                        # nersc is free but off-limits
    assert not victim.bound


# -------------------------------------------------------- score stages

def test_data_locality_pins_to_stream_home_site():
    cluster = mkcluster({"jlab": 1, "nersc": 1})
    topo = SiteTopology(data_sites={"ejfat": "nersc"}).connect(
        "jlab", "nersc", 40.0)
    sched = Scheduler(cluster, topology=topo)
    # control: without a data stream the tie breaks to the first node
    cluster.submit(mkpod("free"), 0.0)
    # pinned: the ejfat stream lives at nersc -> locality dominates
    cluster.submit(mkpod("pinned"), 0.0, data_stream="ejfat")
    sched.run_once(0.0)
    assert cluster.pods["free"].pod.node == "jlab0"
    assert cluster.pods["pinned"].pod.node == "nersc0"


def test_latency_weighted_cross_site_spread():
    """Owner's first replica lands at jlab; jlab then fills up, and the
    spillover replica picks the *nearest* other site by the latency
    matrix (nersc at 10ms over ornl at 100ms)."""
    cluster = mkcluster({"jlab": 1, "nersc": 1, "ornl": 1}, chips=2)
    topo = (SiteTopology().connect("jlab", "nersc", 10.0)
            .connect("jlab", "ornl", 100.0).connect("nersc", "ornl", 50.0))
    sched = Scheduler(cluster, topology=topo)
    cluster.submit(mkpod("r0", chips=2), 0.0, owner="app")
    sched.run_once(0.0)
    assert cluster.pods["r0"].pod.node == "jlab0"
    cluster.submit(mkpod("r1", chips=2), 1.0, owner="app")
    sched.run_once(1.0)
    assert cluster.pods["r1"].pod.node == "nersc0"


def test_site_spread_beats_bestfit():
    """Replicas of one owner spread across sites even when the already-
    used site would be the tighter HBM fit."""
    cluster = Cluster()
    cluster.register_node(start_vk("jlab0", site="jlab", slice_spec=SliceSpec(
        chips=8, hbm_bytes_per_chip=1 << 30)), 0.0)
    cluster.register_node(start_vk("nersc0", site="nersc", slice_spec=SliceSpec(
        chips=8, hbm_bytes_per_chip=8 << 30)), 0.0)
    for name in cluster.nodes:
        cluster.heartbeat(name, 0.0)
    sched = Scheduler(cluster)
    for i in range(2):
        cluster.submit(mkpod(f"r{i}", chips=1, hbm=1 << 30), 0.0, owner="app")
    sched.run_once(0.0)
    sites = {cluster.nodes[cluster.pods[f"r{i}"].pod.node].site
             for i in range(2)}
    assert sites == {"jlab", "nersc"}


def test_topology_parse():
    topo = SiteTopology.parse("jlab:nersc:40,nersc:ornl:18", "ejfat=jlab")
    assert topo.latency("nersc", "jlab") == 40.0     # symmetric
    assert topo.latency("jlab", "jlab") == 0.0
    assert topo.latency("jlab", "ornl") == topo.default_latency_ms
    assert topo.data_sites == {"ejfat": "jlab"}


# ------------------------------------------- multi-facility workflows

def test_multi_site_workflow_targeting():
    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = Cluster()
    wfs = fe.add_multi_wf("vk-", {"jlab": 2, "nersc": 3}, nodetype="tpu",
                          walltime=600.0)
    assert len(wfs) == 2 and len({wf.group for wf in wfs}) == 1
    assert fe.group_wfs(wfs[0].group) == wfs
    pilots = jcs.launch_multi(wfs, now=0.0, cluster=cluster)
    assert len(pilots) == 2
    assert all(wf.state == "RUNNING" for wf in wfs)
    assert len(cluster.site_nodes("jlab")) == 2
    assert len(cluster.site_nodes("nersc")) == 3
    assert all(n.nodetype == "tpu" for n in cluster.nodes.values())


# ------------------------------------------- proactive re-provisioning

def test_jcs_reprovision_on_walltime_shortfall():
    """A site whose aggregate runway no longer covers its pods' remaining
    work gets a fresh pilot (sized by the shortfall, capped at 1:1 node
    replacement) *before* the drain wave; sites with enough runway are
    untouched; the top-up makes the next call a no-op."""
    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = mkcluster({"nersc": 2}, chips=4, walltime=300.0)
    # an infinite-lease site never triggers re-provisioning
    cluster.register_node(start_vk("local0", site="local"), 0.0)
    cluster.heartbeat("local0", 0.0)
    # two pods at nersc owing 600s each: demand 1200 > runway 480
    for i in range(2):
        cluster.submit(mkpod(f"w{i}", chips=1), 0.0, expected_duration=600.0)
        cluster.assign(f"w{i}", f"nersc{i}", 0.0)
    pilots = jcs.reprovision(cluster, 0.0, horizon=600.0, walltime=3600.0)
    assert len(pilots) == 1
    new = [n for n in cluster.site_nodes("nersc") if n.name not in
           ("nersc0", "nersc1")]
    # one 3600s lease covers the 720s shortfall (capped at the 2 expiring)
    assert len(new) == 1
    assert all(n.walltime == pytest.approx(3540.0) for n in new)
    assert all(n.slice_spec.chips == 4 for n in new)
    wf = fe.table[pilots[0].wf_id]
    assert wf.site == "nersc" and wf.state == "RUNNING"
    # supply now covers demand -> self-limiting
    assert jcs.reprovision(cluster, 1.0, horizon=600.0) == []
    # the scheduler can immediately use the fresh lease for long work
    # (the original nersc nodes' 240s runway could never hold 2000s)
    rec = cluster.submit(mkpod("long", chips=1), 5.0,
                         expected_duration=2000.0, site_selector=("nersc",))
    Scheduler(cluster).run_once(5.0)
    assert rec.bound and cluster.nodes[rec.pod.node] in new


def test_jcs_reprovision_sizes_from_starved_chip_concurrency():
    """PR-3 follow-up: pilots are also sized by the chip demand of
    capacity-starved pending pods — including fragmentation (aggregate
    free chips cannot host a pod no single node fits) — while
    quota-blocked pods never trigger one (a fair-share cap is not
    helped by more nodes, even though its reject message names chips)."""
    from repro.core.qos import Quota
    fe = FrontEnd()
    jcs = CentralService(fe)
    # open-ended leases: walltime shortfall is never the trigger here
    cluster = mkcluster({"nersc": 2}, chips=2, walltime=0.0)
    sched = Scheduler(cluster)
    # fragment the pool: one 1-chip pod per node leaves 1+1 free chips
    for i in range(2):
        cluster.submit(mkpod(f"frag{i}", chips=1), 0.0)
    sched.run_once(0.0)
    # a quota-blocked pod alone must not provision anything
    cluster.apply_quota(Quota(owner="capped", chips=0), 1.0)
    cluster.submit(mkpod("q0", chips=1), 1.0, owner="capped")
    sched.run_once(1.0)
    assert "quota" in cluster.pods["q0"].last_reason
    assert jcs.reprovision(cluster, 2.0, horizon=600.0) == []
    # a 2-chip pod fits neither node (2 free chips in aggregate, 1+1
    # fragmented) -> the chip-concurrency path launches a pilot
    big = cluster.submit(mkpod("big", chips=2), 3.0)
    sched.run_once(3.0)
    assert not big.bound and "chips" in big.last_reason
    pilots = jcs.reprovision(cluster, 4.0, horizon=600.0, walltime=3600.0)
    assert len(pilots) == 1 and len(pilots[0].nodes) == 1
    cluster.heartbeat(pilots[0].nodes[0], 4.0)
    sched.run_once(4.0 + sched.backoff_max)
    assert big.bound and big.pod.node == pilots[0].nodes[0]
    # self-limiting: demand met, next call is a no-op
    assert jcs.reprovision(cluster, 5.0 + sched.backoff_max,
                           horizon=600.0) == []
    # a pod no replacement node could host either (request > slice size)
    # must never trigger pilots — launching would repeat forever
    huge = cluster.submit(mkpod("huge", chips=5), 100.0)
    sched.run_once(100.0)
    assert not huge.bound
    assert jcs.reprovision(cluster, 101.0, horizon=600.0) == []
    assert jcs.reprovision(cluster, 102.0, horizon=600.0) == []


def test_jcs_reprovision_counts_queue_backlog():
    """Live queue backlog converts to pod-seconds of serving demand: a
    site whose runway covers its pods' declared durations still gets a
    pilot when the backlog says the fleet is behind."""
    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = mkcluster({"nersc": 1}, chips=4, walltime=300.0)
    cluster.submit(mkpod("w0", chips=1), 0.0, expected_duration=100.0)
    cluster.assign("w0", "nersc0", 0.0)
    # runway 240 covers the 100s of declared work...
    assert jcs.reprovision(cluster, 0.0, horizon=600.0) == []
    # ...but not 100s + a 600-request backlog at 2 req/s (300s more)
    pilots = jcs.reprovision(cluster, 0.0, horizon=600.0, walltime=3600.0,
                             queue_backlog=600, service_rate=2.0)
    assert len(pilots) == 1


# ---------------------------------------------------- batch site drain

def test_drain_allocation_is_one_wave():
    """drain_allocation cordons every node up front: a displaced pod can
    never re-bind onto a sibling of the same expiring allocation."""
    cluster = mkcluster({"jlab": 2, "nersc": 1}, chips=4, walltime=0.0)
    cluster.apply_deployment(Deployment("web", 2, template=PodTemplate(
        tolerations=list(TOL), request_chips=1)), 0.0)
    plane = ControlPlane(cluster)
    plane.step(0.0)
    jlab_pods = [r for r in cluster.pods_of("web")
                 if r.pod.node and r.pod.node.startswith("jlab")]
    assert jlab_pods                           # spread put work at jlab
    plane.nodes.drain_allocation(["jlab0", "jlab1"], 1.0)
    assert not cluster.node_status["jlab0"].schedulable
    assert not cluster.node_status["jlab1"].schedulable
    plane.step(1.0)
    live = [r for r in cluster.pods_of("web") if r.bound]
    assert len(live) == 2
    assert all(r.pod.node == "nersc0" for r in live)
    # every reschedule event after the wave names the surviving site only
    resched = [e for e in cluster.events
               if e.reason == "Rescheduled" and e.time >= 1.0]
    assert resched and all("nersc0" in e.message for e in resched)


@pytest.fixture(scope="module")
def serving():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    return ElasticServing(cfg, tp=1).build(1, host_params=host)


def test_site_kill_zero_request_loss(serving, tmp_path):
    """Acceptance: replicas spread across two facilities; the whole jlab
    allocation is batch-drained mid-stream (facility kill); every
    in-flight request completes on the surviving site with slot tables
    restored — zero request loss, cross-site."""
    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = Cluster()
    wfs = fe.add_multi_wf("fed-", {"jlab": 1, "nersc": 1}, nodetype="tpu",
                          walltime=0.0)
    jcs.launch_multi(wfs, now=0.0, slice_spec=SliceSpec(chips=4),
                     cluster=cluster)
    topo = SiteTopology.parse("jlab:nersc:40")
    plane = ControlPlane(cluster, scheduler=Scheduler(cluster, topology=topo))
    plane.nodes.ckpt_dir = str(tmp_path)
    eng = StreamEngine(serving.cfg, serving, jcs.node_list(),
                       service_rate=6.0, max_batch=4, cluster=cluster,
                       plane=plane)
    eng.deploy(0.0)
    cluster.scale("ersap", 2, 0.0, source="test")
    eng.reconcile(0.0)
    assert sorted(cluster.nodes[p.node].site
                  for p in eng.pods.values()) == ["jlab", "nersc"]

    dt = 10.0
    for t in range(12):
        now = t * dt
        if t == 5:
            plane.drain_site("jlab", now)
        for name, node in cluster.nodes.items():
            if node.site != "jlab" or t < 5:
                cluster.heartbeat(name, now)
        eng.reconcile(now)
        eng.tick(now, dt, lam=1.0 if t < 6 else 0.0)

    assert eng.source.rid > 0
    assert len(eng.completed) == eng.source.rid     # zero loss
    assert len(eng.queue) == 0
    assert len(eng.pods) == 2
    assert all(cluster.nodes[p.node].site == "nersc"
               for p in eng.pods.values())
    moved = [r for r in cluster.pods_of("ersap") if r.restored_from]
    assert moved                                    # cross-site reschedule
    assert "SiteDrain" in cluster.event_reasons("jlab")
