"""Digital twin per paper §6: Eq. (3), Tables 8/9, DBN tracking, control."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.digital_twin.control import ControlPolicy, replicas_for_control
from repro.core.digital_twin.dbn import (DigitalTwin, observation_means,
                                         transition_matrix)
from repro.core.digital_twin.queue_model import (MU_EXACT, TABLE_16,
                                                 TABLE_32, calc_lq,
                                                 ground_truth, obs_lq,
                                                 observe)


def test_eq3_matches_table_calc_lq():
    """L_q = lambda^2/(mu(mu-lambda)) reproduces the Calc.Lq columns.
    Table 8 prints mu=167 (rounded); the column is generated with
    mu=500/3 — see MU_EXACT in queue_model."""
    for threads, tab in ((16, TABLE_16), (32, TABLE_32)):
        mu = MU_EXACT[threads]
        for state, lam, _mu_printed, units, obs, calc in tab:
            assert calc_lq(lam, mu) == pytest.approx(calc, rel=0.02)


def test_ground_truth_piecewise():
    gt = ground_truth(80)
    assert gt[9] == pytest.approx(4.0)        # rose 0.4/step for 10 steps
    assert gt[19] == pytest.approx(4.0)       # flat 10..20
    assert gt[29] == pytest.approx(0.0)       # fell back
    assert gt[49] == pytest.approx(4.0)
    assert gt[69] == pytest.approx(0.0)


def test_transition_matrix_stochastic():
    T = np.asarray(transition_matrix())
    assert np.allclose(T.sum(axis=1), 1.0)
    assert (T >= 0).all()


def test_observation_means_from_tables():
    m = np.asarray(observation_means())
    assert m[0, 0] == 32.0 and m[0, 4] == 241.0
    assert m[1, 0] == 1.56 and m[1, 4] == 3.56


@settings(max_examples=30, deadline=None)
@given(obs=st.floats(0.5, 300.0), u=st.sampled_from([16, 32]))
def test_belief_stays_normalized(obs, u):
    twin = DigitalTwin()
    b = twin.assimilate(obs, u)
    assert np.isclose(float(np.asarray(b).sum()), 1.0, atol=1e-5)
    assert (np.asarray(b) >= 0).all()


def test_dbn_tracks_ground_truth():
    """Fig. 8/9 reproduction: MAE under 0.6 states; escalation at pressure."""
    gt = ground_truth(80)
    twin, policy = DigitalTwin(), ControlPolicy()
    rng = np.random.default_rng(0)
    control, est, ctrl = 16, [], []
    for t, s in enumerate(gt):
        twin.assimilate(observe(s, control, rng), control)
        est.append(twin.estimate())
        control = policy.recommend(twin, control, t)
        ctrl.append(control)
    est, ctrl = np.array(est), np.array(ctrl)
    assert np.abs(est - gt).mean() < 0.6
    assert np.mean(ctrl[gt >= 3.0] == 32) > 0.8       # escalates under load
    assert np.mean(ctrl[gt <= 0.5] == 16) > 0.5       # recovers when calm


def test_control_replica_mapping():
    assert replicas_for_control(16, base_replicas=2) == 2
    assert replicas_for_control(32, base_replicas=2) == 4


def test_obs_interpolation_monotone_in_state():
    vals = [obs_lq(s, 16) for s in np.linspace(0, 4, 17)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
