"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs; prefill/decode consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config, shapes_for
from repro.models import model_api as MA


def make_batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            k3, (B, cfg.frontend_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    mod = MA.get_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: mod.train_loss(p, b, cfg)))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    # param tree structure matches grads
    assert jax.tree.structure(params) == jax.tree.structure(grads)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    mod = MA.get_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, cache = jax.jit(lambda p, t: mod.prefill(
        p, t, cfg, frontend=batch.get("frontend")))(params, batch["tokens"])
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(lambda p, t, c: mod.decode_step(
        p, t, c, cfg))(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits2))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["qwen2-7b", "yi-34b", "granite-20b",
                                  "minitron-8b", "xlstm-1.3b", "hymba-1.5b",
                                  "whisper-medium"])
def test_prefill_decode_consistency(arch):
    """prefill(full) last logits == prefill(half) + token-by-token decode."""
    cfg = get_config(arch).reduced()
    mod = MA.get_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S, Sp = 2, 24, 12
    batch = make_batch(cfg, B, S)
    fe = batch.get("frontend")
    full, _ = jax.jit(lambda p, t: mod.prefill(p, t, cfg, frontend=fe))(
        params, batch["tokens"])
    _, cache = jax.jit(lambda p, t: mod.prefill(p, t, cfg, frontend=fe))(
        params, batch["tokens"][:, :Sp])
    cache = MA.grow_cache(cfg, cache, S + (cfg.frontend_seq or 0)
                          + (cfg.n_meta_tokens or 0))
    dec = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg))
    lg = None
    for i in range(Sp, S):
        lg, cache = dec(params, batch["tokens"][:, i:i + 1], cache)
    assert jnp.max(jnp.abs(lg - full)) < 5e-2


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "llama4-scout-17b-a16e"])
def test_moe_dropless_consistency(arch):
    """With capacity >= S the MoE path is exact; prefill == decode chain."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_routed / cfg.moe.top_k)))
    mod = MA.get_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = jax.jit(lambda p, t: mod.prefill(p, t, cfg))(params, toks)
    cache = mod.init_cache(cfg, B, S + 4)
    dec = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg))
    lg = None
    for i in range(S):
        lg, cache = dec(params, toks[:, i:i + 1], cache)
    assert jnp.max(jnp.abs(lg - full)) < 5e-2


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = get_config("deepseek-moe-16b").reduced()
    mod = MA.get_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 32)
    loss = jax.jit(lambda p, b: mod.train_loss(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss)


def test_all_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = get_config("qwen2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (28, 3584, 28, 4, 18944, 152064, True)
    c = get_config("yi-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (60, 7168, 56, 8, 20480, 64000)
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (52, 6144, 48, 1, 24576, 49152)
    c = get_config("minitron-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.mlp) == (32, 4096, 32, 8, 16384, 256000, "relu2")
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab,
            c.moe.n_routed, c.moe.top_k) == (48, 5120, 40, 8, 202048, 16, 1)
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.moe.n_routed, c.moe.top_k,
            c.moe.n_shared, c.moe.d_ff_expert, c.vocab) == \
        (28, 2048, 64, 6, 2, 1408, 102400)
    c = get_config("paligemma-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.frontend) == (18, 2048, 8, 1, 16384, 257216, "vision")
    c = get_config("whisper-medium")
    assert (c.n_layers, c.encdec.n_enc_layers, c.d_model, c.n_heads,
            c.d_ff, c.vocab, c.encdec.enc_seq) == \
        (24, 24, 1024, 16, 4096, 51865, 1500)
    c = get_config("xlstm-1.3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab, c.d_ff) == \
        (48, 2048, 4, 50304, 0)
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.ssm.state_dim) == (32, 1600, 25, 5, 5504, 32001, 16)


def test_long_500k_gating():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    subq = {a for a in ARCH_IDS
            if any(s.name == "long_500k" for s in
                   shapes_for(get_config(a)))}
    assert subq == {"xlstm-1.3b", "hymba-1.5b", "llama4-scout-17b-a16e"}


def test_ring_cache_bounded_for_long_context():
    """Sub-quadratic archs keep O(window/chunk) decode state at 500k."""
    from repro.configs.base import SHAPES
    for arch in ("llama4-scout-17b-a16e", "hymba-1.5b", "xlstm-1.3b"):
        cfg = get_config(arch)
        cache, _ = MA.cache_specs(cfg, SHAPES["long_500k"])
        leaves = jax.tree.leaves(cache)
        total = sum(l.size * l.dtype.itemsize for l in leaves)
        assert total < 4 << 30, f"{arch} long-context state too big: {total}"
