"""Serving runtime (PR 2): slot-slab continuous batching, bucketed
compilation (bounded jit traces), fused scan decode, fractional tick
budgets, compile-cache reuse on rescale, and slot-table checkpoint
round-trips through the drain -> reschedule loop."""
import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_config
from repro.core.elastic import ElasticServing
from repro.core.jrm import SliceSpec, start_vk
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine
from repro.streaming.runtime import (DecodeRuntime, RuntimeConfig,
                                     RuntimeKernels, requests_from_state)


@pytest.fixture(scope="module")
def serving():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    return ElasticServing(cfg, tp=1).build(1, host_params=host)


def mk_runtime(serving, rcfg=None, **kw):
    rcfg = rcfg or RuntimeConfig(max_batch=4)
    return DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                         gen=serving.build_gen, **kw)


def mk_engine(serving, n_nodes=1, **kw):
    nodes = [start_vk(f"n{i}", now=0.0, slice_spec=SliceSpec(chips=4))
             for i in range(n_nodes)]
    return StreamEngine(serving.cfg, serving, nodes, **kw)


# ------------------------------------------------------------ correctness

def test_runtime_matches_legacy_decode_tokens(serving):
    """With a bucket-exact prompt, the slab path must emit the same greedy
    tokens as the legacy prefill + per-token decode loop."""
    cfg = serving.cfg
    rcfg = RuntimeConfig(max_batch=2, admit_tail=0)
    rt = mk_runtime(serving, rcfg, record_tokens=True)
    req = Request(rid=1, arrival=0.0, prompt_len=8, max_new=6)
    rt.submit([req])
    done = rt.pump()
    assert [f.req.rid for f in done] == [1]
    got = rt.token_log[1]                       # first + 6 block tokens
    # legacy reference: same prompt tokens (the runtime's admission rng)
    rng = np.random.default_rng(hash((1, 8)) % (2 ** 31))
    toks = rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32)
    logits, cache = serving.prefill_fn(serving.params, toks)
    cache = MA.grow_cache(cfg, cache, 8 + req.max_new + 1)
    tok = np.argmax(np.asarray(logits), -1)[:, None].astype(np.int32)
    ref = [int(tok[0, 0])]
    for _ in range(req.max_new):
        logits, cache = serving.decode_fn(serving.params, tok, cache)
        tok = np.argmax(np.asarray(logits), -1)[:, None].astype(np.int32)
        ref.append(int(tok[0, 0]))
    assert got[:len(ref)] == ref


def test_continuous_batching_exact_token_accounting(serving):
    """Every request generates exactly its own max_new — nobody rides
    along for a chunk-mate's longer generation."""
    rt = mk_runtime(serving)
    reqs = [Request(i, 0.0, prompt_len=5 + i, max_new=2 + 3 * (i % 4))
            for i in range(1, 11)]
    rt.submit(reqs)
    done = rt.pump()
    assert sorted(f.req.rid for f in done) == list(range(1, 11))
    for f in done:
        assert f.tokens == f.req.max_new
    assert rt.inflight == 0


def test_pump_drains_pending_when_tail_finishes_everything(serving):
    """Regression: requests shorter than the fused admission tail finish
    inside the admit dispatch itself; pump must still refill the freed
    slots until the pending queue is empty."""
    rcfg = RuntimeConfig(max_batch=2, admit_tail=4)
    rt = mk_runtime(serving, rcfg)
    rt.submit([Request(i, 0.0, prompt_len=6, max_new=3) for i in (1, 2, 3)])
    done = rt.pump()
    assert sorted(f.req.rid for f in done) == [1, 2, 3]
    assert rt.inflight == 0


# --------------------------------------------------- bucketed compilation

def test_trace_count_bounded_under_random_shapes(serving):
    """Regression guard: random (batch, prompt_len, max_new) mixes must
    not grow the jit trace count past the bucket bound."""
    rcfg = RuntimeConfig(max_batch=4)
    rt = mk_runtime(serving, rcfg)
    kern = rt.kernels
    rng = np.random.default_rng(3)
    rid = 0
    for round_ in range(12):
        n = int(rng.integers(1, 9))
        reqs = []
        for _ in range(n):
            rid += 1
            reqs.append(Request(rid, 0.0,
                                int(rng.integers(1, rcfg.max_prompt_bucket)),
                                int(rng.integers(1, 17))))
        rt.submit(reqs)
        for f in rt.pump():
            assert f.tokens == f.req.max_new
    traces = kern.trace_counts
    assert traces["admit"] >= 1 and traces["decode"] >= 1
    n_bb = len(rcfg.batch_buckets)
    n_lb = len(rcfg.prompt_buckets)
    # paged decode adds the kv-read-bucket dimension; the dense slab adds
    # the host-adaptive plain/block-skip pair per fused-step bucket
    n_kv = len(rcfg.kv_ladder) if rcfg.paged else 1
    n_skip = 1 if rcfg.paged or not rcfg.block_skip else 2
    assert traces["admit"] <= n_bb * n_lb * n_kv
    assert traces["decode"] <= len(rcfg.block_ladder) * n_kv * n_skip
    assert traces["admit"] + traces["decode"] <= kern.max_traces


def test_kernels_cached_across_runtimes_and_rescale(serving):
    """Replica runtimes share one kernel set per topology; re-building the
    serving mesh at a seen size reuses both the jitted prefill/decode and
    the runtime kernels (no re-lowering on scale oscillation)."""
    rcfg = RuntimeConfig(max_batch=4)
    k1 = serving.runtime_kernels(rcfg)
    k2 = serving.runtime_kernels(rcfg)
    assert k1 is k2
    pf, df = serving.prefill_fn, serving.decode_fn
    serving.build(serving.replicas)            # same (replicas, tp)
    assert serving.prefill_fn is pf and serving.decode_fn is df
    assert serving.runtime_kernels(rcfg) is k1


def test_oversize_requests_fall_back(serving):
    rcfg = RuntimeConfig(max_batch=4, max_prompt_bucket=16, max_new_cap=8)
    rt = mk_runtime(serving, rcfg)
    assert rt.fits(Request(1, 0.0, prompt_len=12, max_new=4))
    assert not rt.fits(Request(2, 0.0, prompt_len=99, max_new=4))
    assert not rt.fits(Request(3, 0.0, prompt_len=16, max_new=99))


# ------------------------------------------------------------- checkpoint

def test_slot_table_checkpoint_roundtrip(serving, tmp_path):
    """Mid-stream slot state survives save/restore through repro.checkpoint
    (the §4.5.4 drain path): a fresh runtime resumes the remainder and
    partial credit + finish credit sum to exactly max_new per request."""
    rt = mk_runtime(serving, RuntimeConfig(max_batch=2, decode_block=4))
    reqs = [Request(i, 0.5 * i, prompt_len=6, max_new=10) for i in (1, 2, 3)]
    rt.submit(reqs)
    done1 = rt.step()                           # partial progress only
    assert rt.inflight > 0
    state = rt.state()
    partial = rt.partial_tokens()
    tree = {k: np.asarray(v) for k, v in state.items()}
    checkpointer.save(tmp_path, 0, tree, meta={"pod": "r0"})
    restored, _ = checkpointer.restore(tmp_path, tree, step=0)

    rt2 = mk_runtime(serving, RuntimeConfig(max_batch=2, decode_block=4))
    rt2.restore(restored)
    done2 = rt2.pump()
    rids = sorted([f.req.rid for f in done1] + [f.req.rid for f in done2])
    assert rids == [1, 2, 3]                    # zero request loss
    # arrival timestamps survive (latency metrics stay truthful)
    by_rid = {f.req.rid: f.req for f in done2}
    for r in reqs:
        if r.rid in by_rid:
            assert by_rid[r.rid].arrival == pytest.approx(r.arrival)
    total = (partial + sum(f.tokens for f in done1)
             + sum(f.tokens for f in done2))
    assert total == sum(r.max_new for r in reqs)


def test_restored_rid_replays_exact_prompt_tokens(serving, tmp_path):
    """ROADMAP follow-up: restored requests used to re-randomize their
    prompts (admission tokens were seeded by the *group*'s first rid, so
    a rid restored into a different grouping got a different prompt). The
    content store pins each rid's prompt at first admission and rides the
    checkpoint: greedy output across a drain is a token-identical replay
    of the undisturbed run."""
    rcfg = RuntimeConfig(max_batch=2, admit_tail=0, decode_block=4)
    # undisturbed reference: both requests admitted as one group
    ref = mk_runtime(serving, rcfg, record_tokens=True)
    ref.submit([Request(1, 0.0, prompt_len=8, max_new=2),
                Request(2, 0.0, prompt_len=8, max_new=10)])
    ref.pump()
    ref_log = ref.token_log[2]
    assert len(ref_log) == 11                   # first + max_new tokens

    # drained run: r1 finishes in the first block; r2 is checkpointed
    # mid-generation and restored SOLO — the admission grouping changes,
    # the prompt must not
    rt = mk_runtime(serving, rcfg, record_tokens=True)
    rt.submit([Request(1, 0.0, prompt_len=8, max_new=2),
               Request(2, 0.0, prompt_len=8, max_new=10)])
    rt._admit_some()
    rt._decode_block()                          # r1 done, r2 has 6 left
    state = rt.state()
    assert int(state["content_len"][0]) == 8    # prompt rides the ckpt
    tree = {k: np.asarray(v) for k, v in state.items()}
    checkpointer.save(tmp_path, 0, tree, meta={"pod": "r0"})
    restored, _ = checkpointer.restore(tmp_path, tree, step=0)

    rt2 = mk_runtime(serving, rcfg, record_tokens=True)
    rt2.restore(restored)
    assert np.array_equal(rt2.content[2], rt.content[2])
    rt2.pump()
    assert 2 not in rt2.content     # store pruned once the rid finishes
    # the restored incarnation re-prefills the exact prompt: its greedy
    # stream is a prefix replay of the undisturbed run (1 + 6 tokens)
    got = rt2.token_log[2]
    assert got == ref_log[:len(got)]
    assert len(got) == 7


def test_requests_from_state_empty():
    assert requests_from_state({}) == []
    rt_state = {"inflight_rid": np.zeros(0, np.int64),
                "inflight_arrival": np.zeros(0),
                "inflight_plen": np.zeros(0, np.int64),
                "inflight_remaining": np.zeros(0, np.int64)}
    assert requests_from_state(rt_state) == []


def test_engine_drain_checkpoints_inflight_slots(serving, tmp_path):
    """End-to-end: a replica with mid-stream slots on a draining node is
    checkpointed; the rescheduled replica's runtime resumes the slot table
    and every request completes."""
    nodes = [start_vk("doomed", walltime=100.0, now=0.0,
                      slice_spec=SliceSpec(chips=4)),
             start_vk("healthy", now=0.0, slice_spec=SliceSpec(chips=4))]
    eng = StreamEngine(serving.cfg, serving, nodes, service_rate=50.0,
                       max_batch=4)
    eng._ensure_plane(0.0)
    # pin the replica onto the short-lease node
    eng.plane.scheduler.scorers = [
        lambda rec, node, sched, now: 1.0 if node.name == "doomed" else 0.0]
    eng.deploy(0.0)
    eng.plane.nodes.ckpt_dir = str(tmp_path)
    (name, rt), = eng.runtimes.items()
    assert eng.pods[name].node == "doomed"
    # park mid-stream work in the replica's slots (partial progress only:
    # admission + its fused tail, no full decode blocks)
    rt.submit([Request(101, 0.0, prompt_len=6, max_new=12),
               Request(102, 0.0, prompt_len=6, max_new=12)])
    rt._admit_some()
    assert rt.inflight == 2 and rt.partial_tokens() > 0
    # node enters its drain margin -> checkpoint, evict, reschedule
    now = 70.0
    eng.plane.scheduler.scorers = []
    for n in eng.cluster.nodes:
        eng.cluster.heartbeat(n, now)
    eng.reconcile(now)
    moved = [r for r in eng.cluster.pods_of("ersap") if r.restored_from]
    assert moved and moved[0].pod.node == "healthy"
    assert np.asarray(moved[0].restored_state["inflight_rid"]).size == 2
    # exactly one live copy of each in-flight request (the retire path and
    # the checkpoint restore both name the same rids — no double-serving)
    new_rt = eng.runtimes[moved[0].name]
    carried = ([r.rid for r in eng.queue] + [r.rid for r in new_rt.pending]
               + [s.req.rid for s in new_rt.slots if s.busy])
    assert sorted(carried) == [101, 102]
    eng.tick(now + 1.0, 1.0, lam=0.0)
    assert sorted(rid for rid, _ in eng.completed) == [101, 102]
    # partial + finish-time credit sums to exactly max_new per request
    assert eng.total_tokens == 24


# ------------------------------------------------------ engine satellites

def test_fractional_budget_no_starvation(serving):
    """service_rate * dt < 1 used to truncate to a 0 budget forever; the
    fractional carry must eventually serve the queue."""
    eng = mk_engine(serving, service_rate=0.3, max_batch=4)
    eng.deploy(0.0)
    eng.queue.extend(
        Request(i, 0.0, prompt_len=8, max_new=2) for i in range(1, 4))
    for t in range(12):
        eng.tick(float(t), 1.0, lam=0.0)
    assert eng.total_served == 3 and not eng.queue
    # carry stays a proper fraction (no unbounded accumulation)
    assert 0.0 <= eng._budget_frac < 1.0


def test_cp_ports_pruned_with_pods(serving):
    """The §4.6.3 control-plane port map follows the live pod set across
    scale/evict cycles instead of growing monotonically."""
    eng = mk_engine(serving, n_nodes=2, service_rate=5.0)
    eng.deploy(0.0)
    for i in range(4):
        eng.cluster.scale("ersap", 2, float(i), source="test")
        eng.reconcile(float(i))
        eng.cluster.scale("ersap", 1, float(i) + 0.5, source="test")
        eng.reconcile(float(i) + 0.5)
    assert set(eng._cp_ports) == set(eng.pods)
    assert len(eng._cp_ports) == 1


def test_engine_runtime_serves_varied_shapes(serving):
    """Engine + runtime under randomized request shapes: everything
    completes, token totals are exact, traces stay bounded."""
    eng = mk_engine(serving, service_rate=30.0, max_batch=4)
    eng.source = RequestSource(seed=5, prompt_range=(4, 40),
                               max_new_range=(1, 12))
    eng.deploy(0.0)
    for t in range(4):
        eng.tick(t * 1.0, 1.0, lam=8.0)
    eng.tick(5.0, 1.0, lam=0.0)
    assert eng.total_served == eng.source.rid > 0
    assert len(eng.completed) == eng.source.rid
    rt = next(iter(eng.runtimes.values()))
    assert (rt.kernels.trace_counts["admit"]
            + rt.kernels.trace_counts["decode"]) <= rt.kernels.max_traces
