"""JRM/JFM/JMS/JCS/JFE behaviors (paper §3, §4.1-4.2, §4.5, §5.1)."""
import pytest

from repro.core.jcs import CentralService
from repro.core.jfe import FrontEnd
from repro.core.jfm import FacilityManager
from repro.core.jms import MatchingService
from repro.core.jrm import SliceSpec, VirtualNode, start_vk
from repro.core.state_machine import Container, Pod

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name="p", chips=1, hbm=2 << 30, affinity=(), selector=None):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               affinity=list(affinity), node_selector=selector or {},
               request_chips=chips, request_hbm_bytes=hbm)


def test_walltime_lease_notready_but_not_terminated():
    n = start_vk("vk", walltime=100.0, now=0.0)
    assert n.tick(50.0)
    assert n.labels(50.0)["jiriaf.alivetime"] == "50"
    assert not n.tick(101.0)          # lease expired -> NotReady
    assert n.pods is not None         # VK not terminated (paper §4.2.3)
    # walltime=0 => no alivetime label, no expiry
    n0 = start_vk("vk0", walltime=0.0, now=0.0)
    assert "jiriaf.alivetime" not in n0.labels(1e9)
    assert n0.tick(1e9)


def test_affinity_paper_example():
    """§4.2.3 example: nodetype In [cpu], site In [nersc], alivetime Gt 10."""
    expr = [
        {"key": "jiriaf.nodetype", "operator": "In", "values": ["cpu"]},
        {"key": "jiriaf.site", "operator": "In", "values": ["nersc"]},
        {"key": "jiriaf.alivetime", "operator": "Gt", "values": ["10"]},
    ]
    good = start_vk("a", nodetype="cpu", site="nersc", walltime=100, now=0.0)
    assert good.matches(expr, now=0.0)
    assert not good.matches(expr, now=95.0)       # alivetime 5 < 10
    wrong_site = start_vk("b", nodetype="cpu", site="jlab", walltime=100, now=0)
    assert not wrong_site.matches(expr, now=0.0)


def test_taint_requires_toleration():
    n = start_vk("vk", now=0.0)
    bad = Pod("bad", [Container("c")])
    with pytest.raises(PermissionError):
        n.create_pod(bad, 0.0)
    ok = mkpod()
    n.create_pod(ok, 0.0)
    assert ok.node == "vk"


def test_jfm_scrape_stale_and_straggler():
    nodes = [start_vk(f"n{i}", now=0.0, slice_spec=SliceSpec(chips=4))
             for i in range(4)]
    for i, n in enumerate(nodes):
        n.tick(10.0, latency=0.1 if i < 3 else 5.0)
    nodes[0].last_heartbeat = -100.0          # stale
    fm = FacilityManager()
    pool = fm.scrape(nodes, now=10.0)
    assert not pool["n0"].ready
    assert pool["n3"].straggler and not pool["n1"].straggler
    assert fm.total_free_chips() == 12        # 3 ready x 4 chips


def test_jms_best_fit_and_constraints():
    big = start_vk("big", now=0.0, slice_spec=SliceSpec(chips=8))
    small = start_vk("small", now=0.0, slice_spec=SliceSpec(chips=2))
    lease = start_vk("short", walltime=50.0, now=0.0,
                     slice_spec=SliceSpec(chips=2))
    nodes = [big, small, lease]
    for n in nodes:
        n.tick(0.0)
    fm = FacilityManager()
    fm.scrape(nodes, 0.0)
    jms = MatchingService(fm)
    # best fit: 2-chip pod goes to the tightest node with enough walltime
    res = jms.bind(mkpod(chips=2), nodes, 0.0, expected_duration=100.0)
    assert res.node == "small"            # lease node excluded (50s < 100+60)
    fm.scrape(nodes, 0.0)
    res2 = jms.match(mkpod("p2", chips=16), nodes, 0.0)
    assert res2.node is None


def test_jms_prefers_non_straggler():
    a = start_vk("a", now=0.0, slice_spec=SliceSpec(chips=4))
    b = start_vk("b", now=0.0, slice_spec=SliceSpec(chips=4))
    a.tick(0.0, latency=9.0)
    b.tick(0.0, latency=0.1)
    c = start_vk("c", now=0.0, slice_spec=SliceSpec(chips=4))
    c.tick(0.0, latency=0.1)
    fm = FacilityManager()
    fm.scrape([a, b, c], 0.0)
    res = MatchingService(fm).match(mkpod(chips=4), [a, b, c], 0.0)
    assert res.node in ("b", "c")


def test_jcs_pilot_staggered_ports_and_walltime_margin():
    fe = FrontEnd()
    wf = fe.add_wf("vk-nersc", 5, walltime=300.0)
    jcs = CentralService(fe)
    pilot = jcs.launch_pilot(wf, now=0.0)
    assert len(pilot.nodes) == 5
    nodes = jcs.node_list()
    # staggered bring-up (sleep 3 per paper §5.1)
    assert nodes[1].created_at - nodes[0].created_at == pytest.approx(3.0)
    # §4.5.4: JRM walltime is 60s less than the Slurm walltime
    assert nodes[0].walltime == pytest.approx(240.0)
    # port ranges per §4.5.2
    for t in pilot.tunnels:
        if t.kind == "kubelet":
            assert 10000 <= t.local_port <= 19999
        if t.kind.startswith("custom-metrics"):
            assert 20000 <= t.local_port <= 49999
    jcs.teardown(wf.wf_id, 10.0)
    assert fe.table[wf.wf_id].state == "COMPLETED"
    assert not jcs.node_list()


def test_jfe_workflow_verbs():
    fe = FrontEnd()
    wf = fe.add_wf("vk", 2)
    assert [w.wf_id for w in fe.get_wf()] == [wf.wf_id]
    gone = fe.delete_wf(wf.wf_id)
    assert gone.state == "ARCHIVED" and not fe.get_wf()


def test_node_failure_reschedule():
    """Fault-tolerance loop: a pod's node dies (heartbeat stops), JFM marks
    it NotReady, and JMS reschedules the pod onto a surviving node."""
    a = start_vk("a", now=0.0, slice_spec=SliceSpec(chips=4))
    b = start_vk("b", now=0.0, slice_spec=SliceSpec(chips=4))
    nodes = [a, b]
    for n in nodes:
        n.tick(0.0)
    fm = FacilityManager(stale_after=30.0)
    fm.scrape(nodes, 0.0)
    jms = MatchingService(fm)
    pod = mkpod("worker", chips=4)
    res = jms.bind(pod, nodes, 0.0)
    victim = next(n for n in nodes if n.name == res.node)
    survivor = next(n for n in nodes if n.name != res.node)
    # victim stops heartbeating; JFM declares it dead on next scrape
    survivor.tick(100.0)
    pool = fm.scrape(nodes, 100.0)
    assert not pool[victim.name].ready
    assert pool[survivor.name].ready
    # reschedule: new incarnation of the pod binds to the survivor
    pod2 = mkpod("worker-retry", chips=4)
    res2 = jms.bind(pod2, nodes, 100.0)
    assert res2.node == survivor.name
    assert pod2.phase.value == "Running"


def test_walltime_drain_then_requeue_flow():
    """§4.5.4 end-to-end at the control-plane level: lease near expiry ->
    node drains -> JMS refuses new long work on it but accepts elsewhere."""
    short = start_vk("short", walltime=100.0, now=0.0,
                     slice_spec=SliceSpec(chips=4))
    fresh = start_vk("fresh", walltime=10_000.0, now=0.0,
                     slice_spec=SliceSpec(chips=4))
    nodes = [short, fresh]
    now = 50.0  # inside short's 60s drain margin (alive_left = 50)
    for n in nodes:
        n.tick(now)
    assert short.draining(now) and not fresh.draining(now)
    fm = FacilityManager()
    fm.scrape(nodes, now)
    jms = MatchingService(fm)
    res = jms.bind(mkpod(chips=4), nodes, now, expected_duration=300.0)
    assert res.node == "fresh"
