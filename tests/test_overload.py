"""Overload protection & graceful degradation (ISSUE-9): deadline-aware
admission, brownout watermarks with hysteresis, retry budgets, replica
circuit breaking, ring-capped logs, and the cost-modeled failover window.

Invariants under test: the brownout level never flaps inside the
hysteresis dead band and recovers in stages; retry budgets exhaust and
refill as token buckets; an ejected replica rejoins only through a
healthy half-open probe; a request whose deadline expired in the queue
never reaches prefill; bounded-queue backpressure loses nothing (every
request completes exactly once or is an explicit shed with a reason);
degraded service caps output length without changing token *content*
(prefix of the unloaded oracle's stream); ring caps truncate with
explicit markers; drain_site pays the topology's transfer window and the
engine serves degraded for its duration."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import qos
from repro.core.chaos import FaultInjector, FaultSpec
from repro.core.cluster import Cluster
from repro.core.elastic import ElasticServing
from repro.core.jrm import SliceSpec, start_vk
from repro.core.scheduler import Scheduler, SiteTopology
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine
from repro.streaming.runtime import DecodeRuntime, RuntimeConfig


@pytest.fixture(scope="module")
def serving():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    return ElasticServing(cfg, tp=1).build(1, host_params=host)


def mk_engine(serving, n_nodes=1, **kw):
    nodes = [start_vk(f"n{i}", now=0.0, slice_spec=SliceSpec(chips=4))
             for i in range(n_nodes)]
    kw.setdefault("runtime_cfg", RuntimeConfig(max_batch=4, admit_tail=0))
    return StreamEngine(serving.cfg, serving, nodes, **kw)


# ------------------------------------------------------ brownout controller

def test_brownout_escalates_only_after_dwell():
    bc = qos.BrownoutController(dwell_ticks=3)
    for i in range(2):
        assert bc.update(float(i), 0.95, 0.0) == 0
    assert bc.update(2.0, 0.95, 0.0) == 1
    # counter restarts per level: two more high ticks are not enough
    assert bc.update(3.0, 0.95, 0.0) == 1
    assert bc.update(4.0, 0.95, 0.0) == 1
    assert bc.update(5.0, 0.95, 0.0) == 2


def test_brownout_dead_band_holds_level_no_flap():
    bc = qos.BrownoutController(high_water=0.85, low_water=0.5,
                                dwell_ticks=2, recover_ticks=2)
    bc.level = 2
    # oscillate inside the dead band (and touch each watermark once,
    # never consecutively): the level must hold and nothing transitions
    for i, p in enumerate([0.7, 0.86, 0.7, 0.49, 0.7, 0.86, 0.7]):
        assert bc.update(float(i), p, 0.0) == 2
    assert bc.transitions == []


def test_brownout_staged_recovery_never_snaps_to_zero():
    bc = qos.BrownoutController(recover_ticks=2)
    bc.level = 3
    levels = [bc.update(float(i), 0.0, 0.0) for i in range(6)]
    # one level per recover_ticks — 3 -> 2 -> 1 -> 0, never 3 -> 0
    assert levels == [3, 2, 2, 1, 1, 0]
    assert [(old, new) for _, old, new, _ in bc.transitions] == \
        [(3, 2), (2, 1), (1, 0)]


def test_brownout_delay_ewma_drives_pressure():
    bc = qos.BrownoutController(delay_target_s=10.0, ewma_alpha=1.0,
                                dwell_ticks=1)
    assert bc.update(0.0, 0.0, 30.0) == 1          # delay 3x target
    assert bc.last_pressure == pytest.approx(3.0)


def test_brownout_degrade_knobs():
    bc = qos.BrownoutController(degrade_max_new=4)
    assert bc.max_new_cap() is None and bc.spec_enabled()
    bc.level = 1
    assert bc.max_new_cap() == 4 and not bc.spec_enabled()
    assert bc.shed_floor() == 0
    bc.level = 2
    assert bc.shed_floor() == qos.STANDARD.value
    bc.level = 3
    assert bc.shed_floor() == qos.LATENCY_CRITICAL.value


def test_tier_label_maps_to_highest_class_at_or_below():
    assert qos.tier_label(0) == "batch"
    assert qos.tier_label(10) == "standard"
    assert qos.tier_label(55) == "standard"
    assert qos.tier_label(100) == "latency-critical"
    assert qos.tier_label(5000) == "system"


# ----------------------------------------------------------- retry budgets

def test_retry_budget_exhausts_then_refills():
    rb = qos.RetryBudget(rate=1.0, burst=3.0)
    assert all(rb.allow("standard", 0.0) for _ in range(3))
    assert not rb.allow("standard", 0.0)           # bucket dry
    assert rb.granted == 3 and rb.denied == 1
    # tenants are isolated: another tenant still has its full burst
    assert rb.allow("batch", 0.0)
    # refill at ``rate``/s — 2 seconds buys 2 retries
    assert rb.allow("standard", 2.0)
    assert rb.allow("standard", 2.0)
    assert not rb.allow("standard", 2.0)


# --------------------------------------------------------- replica breaker

def test_breaker_ejects_probes_and_rejoins():
    br = qos.ReplicaBreaker(stall_ticks=2, probe_after_s=10.0,
                            probe_budget=2)
    assert br.allow("r0", 0.0) == -1               # closed: unbounded
    br.observe("r0", 0.0, 0, had_work=True)        # stall 1
    br.observe("r0", 1.0, 0, had_work=True)        # stall 2 -> eject
    assert br.state("r0") == qos.BREAKER_OPEN and br.ejections == 1
    assert br.allow("r0", 5.0) == 0                # still cooling off
    assert br.allow("r0", 11.0) == 2               # half-open: probes only
    br.note_probe("r0", 2)
    assert br.allow("r0", 12.0) == 0               # probe budget consumed
    br.observe("r0", 12.0, 5, had_work=True)       # healthy probe
    assert br.state("r0") == qos.BREAKER_CLOSED and br.rejoins == 1


def test_breaker_failed_probe_reopens():
    br = qos.ReplicaBreaker(stall_ticks=1, probe_after_s=10.0)
    br.observe("r0", 0.0, 0, had_work=True)
    assert br.state("r0") == qos.BREAKER_OPEN
    assert br.allow("r0", 10.0) > 0                # half-open
    br.observe("r0", 10.0, 0, had_work=True)       # stalled probe
    assert br.state("r0") == qos.BREAKER_OPEN
    # idle ticks (no work routed) never resolve a probe or count stalls
    br.allow("r0", 20.0)
    br.observe("r0", 20.0, 0, had_work=False)
    assert br.state("r0") == qos.BREAKER_HALF_OPEN


# ------------------------------------------------- source: surge + deferral

def test_surge_fault_scales_arrivals_within_window():
    src = RequestSource(seed=3)
    inj = FaultInjector([FaultSpec("surge", 10.0, "ersap", duration=20.0,
                                   magnitude=5.0)], seed=0)
    cluster = Cluster()
    counts = {}
    for t in range(6):
        now = t * 10.0
        inj.apply(cluster, now)
        src.surge = inj.surge_factor("ersap")
        counts[t] = (src.surge, len(src.arrivals(now, 10.0, 2.0)))
    assert counts[0][0] == 1.0
    assert counts[1][0] == 5.0 and counts[2][0] == 5.0
    assert counts[4][0] == 1.0                     # window expired
    # the surge factor targets by owner: another owner is untouched
    assert inj.surge_factor("other") == 1.0 or not inj.active


def test_defer_consumes_no_rng_and_releases_on_time():
    a = RequestSource(seed=7, tiers=((0, 0.5), (100, 0.5)))
    b = RequestSource(seed=7, tiers=((0, 0.5), (100, 0.5)))
    out_a = a.arrivals(0.0, 10.0, 1.0)
    out_b = b.arrivals(0.0, 10.0, 1.0)
    assert [r.rid for r in out_a] == [r.rid for r in out_b]
    # b defers two requests for retry; a drops them on the floor
    b.defer(out_b[:2], not_before=15.0)
    next_a = a.arrivals(10.0, 10.0, 1.0)
    next_b = b.arrivals(10.0, 10.0, 1.0)           # 15.0 not reached
    assert [(r.rid, r.priority) for r in next_a] == \
        [(r.rid, r.priority) for r in next_b]
    released = b.arrivals(20.0, 10.0, 0.0)
    assert [r.rid for r in released[:2]] == [r.rid for r in out_b[:2]]
    assert b.deferred_total == 2


def test_source_stamps_deadline_and_tiers():
    src = RequestSource(seed=1, ttl=30.0, tiers=((0, 1.0),))
    out = src.arrivals(0.0, 10.0, 5.0)
    assert out
    for r in out:
        assert r.deadline == pytest.approx(r.arrival + 30.0)
        assert r.priority == 0
    # ttl=0 keeps the no-deadline default
    assert RequestSource(seed=1).arrivals(0.0, 10.0, 5.0)[0].deadline == 0.0


# ----------------------------------------------------------- ring buffers

def test_cluster_event_ring_cap_truncates_with_marker():
    cluster = Cluster(events_cap=50)
    for i in range(120):
        cluster.record(float(i), "Pod", f"p{i}", "Tick", "")
    assert len(cluster.events) == 50
    assert cluster.events_truncated == 70
    assert cluster.events[0].name == "p70"         # oldest dropped first


def test_token_log_ring_cap_keeps_tail(serving):
    rcfg = RuntimeConfig(max_batch=2, admit_tail=0)
    full = DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                         gen=serving.build_gen, record_tokens=True)
    capped = DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                           gen=serving.build_gen, record_tokens=True,
                           token_log_cap=4)
    req = Request(rid=1, arrival=0.0, prompt_len=8, max_new=10)
    for rt in (full, capped):
        rt.submit([Request(**req.__dict__)])
        rt.pump()
    n_full = len(full.token_log[1])
    assert n_full > 4                              # cap actually binds
    assert len(capped.token_log[1]) == 4
    dropped = capped.token_log_dropped[1]
    assert dropped == n_full - 4                   # explicit marker
    assert list(capped.token_log[1]) == list(full.token_log[1])[dropped:]


# ------------------------------------------------- engine: admission + shed

def test_deadline_expired_in_queue_never_reaches_prefill(serving):
    eng = mk_engine(serving, service_rate=50.0, record_tokens=True)
    eng.deploy(0.0)
    dead = Request(rid=901, arrival=0.0, prompt_len=8, max_new=4,
                   deadline=5.0)
    live = Request(rid=902, arrival=0.0, prompt_len=8, max_new=4,
                   deadline=100.0)
    eng.queue.extend([dead, live])
    eng.tick(10.0, 1.0, lam=0.0)
    assert [rid for rid, _ in eng.completed] == [902]
    assert (901, "deadline", 10.0) in eng.shed
    assert eng.shed_counts == {"deadline": 1}
    for rt in eng.runtimes.values():
        assert 901 not in rt.token_log             # never prefilled


def test_bounded_queue_backpressure_defers_then_serves(serving):
    eng = mk_engine(serving, service_rate=2.0, record_tokens=True)
    eng.queue_cap = 4
    eng.deploy(0.0)
    src = eng.source
    # one burst far past the cap, then silence: overflow must defer
    # through the source and be served later — zero loss, no duplicates
    eng.tick(0.0, 1.0, lam=40.0)
    assert eng.rejected_total > 0 and eng.retried_total > 0
    assert len(eng.queue) <= eng.queue_cap
    for t in range(1, 40):
        eng.tick(float(t), 1.0, lam=0.0)
    done = [rid for rid, _ in eng.completed]
    assert len(done) == len(set(done)) == src.rid
    assert not src._deferred and not eng.shed


def test_backpressure_rejects_lowest_tier_first(serving):
    eng = mk_engine(serving, service_rate=1.0)
    eng.queue_cap = 2
    eng.deploy(0.0)
    lc = Request(rid=1, arrival=0.0, prompt_len=8, max_new=2, priority=100)
    std = Request(rid=2, arrival=0.0, prompt_len=8, max_new=2, priority=10)
    bat = Request(rid=3, arrival=0.0, prompt_len=8, max_new=2, priority=0)
    eng.source.arrivals = lambda now, dt, lam, **kw: [bat, std, lc]
    eng.tick(0.0, 1.0, lam=1.0)
    # room for two: latency-critical and standard admitted, batch deferred
    assert eng.rejected_total == 1
    queued = {r.rid for r in eng.queue} | \
        {rid for rid, _ in eng.completed} | \
        {s.req.rid for rt in eng.runtimes.values()
         for s in rt.slots if s.busy} | \
        {r.rid for rt in eng.runtimes.values() for r in rt.pending}
    assert {1, 2} <= queued and 3 not in queued


def test_retry_budget_dry_sheds_instead_of_retry_storm(serving):
    eng = mk_engine(serving, service_rate=1.0)
    eng.queue_cap = 1
    eng.retry_budget = qos.RetryBudget(rate=0.0, burst=1.0)
    eng.deploy(0.0)
    reqs = [Request(rid=i, arrival=0.0, prompt_len=8, max_new=2,
                    priority=10) for i in range(1, 5)]
    eng.source.arrivals = lambda now, dt, lam, **kw: list(reqs)
    eng.tick(0.0, 1.0, lam=1.0)
    # one deferred on the single budget token, the rest shed explicitly
    assert eng.retried_total == 1
    assert eng.shed_counts.get("retry-budget") == 2
    assert eng.retry_budget.denied == 2


def test_brownout_degrades_before_dropping(serving):
    """Level 1 must cap output length and disable speculative decode —
    and the capped stream must be a prefix of the uncapped one."""
    oracle = mk_engine(serving, service_rate=50.0, record_tokens=True)
    oracle.deploy(0.0)
    oracle.queue.append(Request(rid=7, arrival=0.0, prompt_len=8,
                                max_new=12))
    oracle.tick(0.0, 1.0, lam=0.0)
    o_log = [list(rt.token_log[7]) for rt in oracle.runtimes.values()
             if 7 in rt.token_log][0]

    eng = mk_engine(serving, service_rate=50.0, record_tokens=True)
    eng.brownout = qos.BrownoutController(degrade_max_new=3)
    eng.brownout.level = 1
    eng.brownout.dwell_ticks = 99                  # hold level 1
    eng.brownout.recover_ticks = 99
    eng.deploy(0.0)
    eng.queue.append(Request(rid=7, arrival=0.0, prompt_len=8, max_new=12))
    eng.tick(0.0, 1.0, lam=0.0)
    (rt,) = eng.runtimes.values()
    assert not rt.spec_enabled                     # luxury off while degraded
    log = list(rt.token_log[7])
    # prefill's first token + the capped 3 decode steps — not dropped
    assert len(log) == 4 < len(o_log)
    assert log == o_log[:len(log)]                 # prefix — same content
    assert not eng.shed


def test_breaker_routes_around_partitioned_replica(serving):
    """A replica that takes work but emits nothing is ejected and probed
    back in through the engine loop."""
    br = qos.ReplicaBreaker(stall_ticks=1, probe_after_s=5.0)
    br._state["r0"] = qos.BREAKER_OPEN             # ejected upstream
    br._opened_at["r0"] = 0.0
    eng = mk_engine(serving, service_rate=50.0)
    eng.breaker = br
    eng.deploy(0.0)
    (name,) = eng.runtimes.keys()
    br.forget("r0")
    br._state[name] = qos.BREAKER_OPEN
    br._opened_at[name] = 0.0
    eng.queue.append(Request(rid=5, arrival=0.0, prompt_len=8, max_new=2))
    eng.tick(1.0, 1.0, lam=0.0)
    assert not eng.completed                       # open: routed around
    assert len(eng.queue) == 1
    eng.tick(6.0, 1.0, lam=0.0)                    # half-open probe window
    assert [rid for rid, _ in eng.completed] == [5]
    assert br.state(name) == qos.BREAKER_CLOSED and br.rejoins == 1


# ------------------------------------------------- cost-modeled failover

def test_transfer_cost_model_and_parse():
    topo = SiteTopology.parse("jlab:nersc:40", "", "jlab:nersc:0.001")
    assert topo.bandwidth("jlab", "jlab") == float("inf")
    assert topo.bandwidth("jlab", "nersc") == 0.001
    assert topo.bandwidth("nersc", "jlab") == 0.001    # symmetric
    assert topo.transfer_cost(10 ** 6, "jlab", "jlab") == 0.0
    assert topo.transfer_cost(0, "jlab", "nersc") == 0.0
    # 1 MB over 1 Mbit/s = 8 s, plus the 40 ms one-way latency
    assert topo.transfer_cost(10 ** 6, "jlab", "nersc") == \
        pytest.approx(0.04 + 8.0)
    # unknown pairs fall back to the default pipe
    topo.set_bandwidth("jlab", "ornl", 2.0)
    assert topo.bandwidth("ornl", "jlab") == 2.0
    assert topo.bandwidth("nersc", "ornl") == topo.default_bandwidth_gbps


def test_preemption_ranks_cheap_transfers_first():
    cluster = Cluster()
    for name, site in (("a0", "jlab"), ("b0", "nersc")):
        cluster.register_node(
            start_vk(name, site=site, now=0.0,
                     slice_spec=SliceSpec(chips=2)), 0.0)
        cluster.heartbeat(name, 0.0)
    topo = SiteTopology.parse("jlab:nersc:40", "", "jlab:nersc:0.001")
    sched = Scheduler(cluster, topology=topo)
    from repro.core.state_machine import Container, Pod
    tol = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]
    rec = cluster.submit(Pod("v", [Container("c")], tolerations=tol,
                             request_chips=1), 0.0)
    rec.restored_state = {"kv": np.zeros(250_000, np.float32)}  # 1 MB
    assert sched._victim_state_bytes(rec) == 10 ** 6
    node = cluster.nodes["a0"]
    # the only other site is nersc over the thin pipe: ~8 s penalty
    assert sched._transfer_penalty([rec], node) == pytest.approx(0.04 + 8.0)
    # no topology -> no penalty term (legacy cost ordering preserved)
    assert Scheduler(cluster)._transfer_penalty([rec], node) == 0.0


def test_drain_site_pays_transfer_window_and_degrades(serving, tmp_path):
    cluster = Cluster()
    cluster.register_node(
        start_vk("j0", nodetype="tpu", site="jlab", now=0.0,
                 slice_spec=SliceSpec(chips=4)), 0.0)
    cluster.heartbeat("j0", 0.0)
    topo = SiteTopology.parse("jlab:nersc:40", "", "jlab:nersc:1e-09")
    from repro.core.controllers import ControlPlane
    plane = ControlPlane(cluster, scheduler=Scheduler(cluster,
                                                      topology=topo))
    plane.nodes.ckpt_dir = str(tmp_path)
    eng = StreamEngine(serving.cfg, serving, list(cluster.nodes.values()),
                       service_rate=50.0, cluster=cluster, plane=plane)
    eng.deploy(0.0)
    cluster.scale("ersap", 1, 0.0, source="test")
    eng.reconcile(0.0)
    assert all(cluster.nodes[p.node].site == "jlab"
               for p in eng.pods.values())
    cluster.register_node(
        start_vk("c0", nodetype="tpu", site="nersc", now=0.0,
                 slice_spec=SliceSpec(chips=4)), 0.0)
    cluster.heartbeat("c0", 0.0)
    now = 10.0
    plane.drain_site("jlab", now)
    assert plane.last_transfer_s > 0 and plane.last_transfer_bytes > 0
    assert any(e.reason == "SiteDrainTransfer" for e in cluster.events)
    # the engine was told to serve degraded for the transfer window
    assert eng.degrade_until == pytest.approx(now + plane.last_transfer_s)
    assert eng.transfer_windows == 1
    eng.reconcile(now)
    assert sorted(cluster.nodes[p.node].site
                  for p in eng.pods.values()) == ["nersc"]
    # while the window is open the tick runs at the forced degrade level
    eng.tick(now, 1.0, lam=0.0)
    assert eng._level >= eng.transfer_degrade_level
