"""QoS subsystem: priority classes, fair-share quotas, cost-ranked
preemption with §4.5.4 checkpointing, the pressure-aware autoscaler, and
the twin's (replicas, priority) action space.

Invariants under test: preemption never selects equal-or-higher priority
or non-preemptible pods; quota books balance (used + free == capacity,
per-owner sums match the node truth) after preempt -> requeue ->
reschedule; priority writes round-trip through a full drain; a
mixed-tenant pressure spike loses zero serving requests."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import qos
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.digital_twin.control import ControlPolicy
from repro.core.digital_twin.dbn import DigitalTwin
from repro.core.elastic import ElasticServing
from repro.core.hpa import HPA, HPAConfig, PressureSignals
from repro.core.jrm import SliceSpec, start_vk
from repro.core.scheduler import Scheduler
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name="p", chips=1, hbm=0):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips, request_hbm_bytes=hbm)


def mkcluster(n_nodes=2, chips=2, sites=None, walltimes=None, now=0.0):
    cluster = Cluster()
    for i in range(n_nodes):
        cluster.register_node(
            start_vk(f"n{i}", site=sites[i] if sites else "Local",
                     walltime=walltimes[i] if walltimes else 0.0, now=now,
                     slice_spec=SliceSpec(chips=chips)), now)
        cluster.heartbeat(f"n{i}", now)
    return cluster


# ---------------------------------------------------------- object model

def test_priority_class_resolves_at_submit():
    cluster = mkcluster(1)
    rec = cluster.submit(mkpod("a"), 0.0, priority_class="latency-critical")
    assert rec.priority == 100 and rec.preemptible
    sys_rec = cluster.submit(mkpod("b"), 0.0, priority_class="system")
    assert sys_rec.priority == 1000 and not sys_rec.preemptible
    with pytest.raises(ValueError):
        cluster.submit(mkpod("c"), 0.0, priority_class="no-such-tier")


def test_set_priority_retiers_live_and_pending_pods():
    cluster = mkcluster(1, chips=1)
    cluster.apply_deployment(Deployment("svc", 2, template=PodTemplate(
        tolerations=list(TOL), request_chips=1,
        priority_class="standard")), 0.0)
    plane = ControlPlane(cluster)
    plane.step(0.0)
    recs = cluster.pods_of("svc")
    bound = [r for r in recs if r.bound]
    pending = [r for r in recs if not r.bound]
    assert len(bound) == 1 and len(pending) == 1
    assert pending[0].next_retry > 0.0            # backed off
    cluster.set_priority("svc", "latency-critical", 5.0, source="twin")
    for r in cluster.pods_of("svc"):
        assert r.priority == 100
        assert r.priority_class == "latency-critical"
    # an escalated pending pod re-enters scheduling immediately
    assert pending[0].next_retry == 5.0 and pending[0].attempts == 0
    assert "PriorityChanged" in cluster.event_reasons("svc")
    # idempotent: no second event for the same tier
    cluster.set_priority("svc", "latency-critical", 6.0)
    assert cluster.event_reasons("svc").count("PriorityChanged") == 1
    # a *demotion* must not void the pending pod's backoff (only raises
    # re-enter scheduling; apply_deployment synced template.priority to
    # the class, so the raise-vs-demote comparison is against the real
    # tier, not the dataclass default 0)
    pending[0].next_retry = 99.0
    pending[0].attempts = 3
    cluster.set_priority("svc", "batch", 7.0)
    assert pending[0].next_retry == 99.0 and pending[0].attempts == 3
    # apply_deployment resolves a class-created template's numeric mirror
    dep2 = cluster.apply_deployment(Deployment("svc2", 1, template=PodTemplate(
        tolerations=list(TOL), priority_class="latency-critical")), 8.0)
    assert dep2.template.priority == 100


def test_quota_spec_parser():
    quotas = qos.parse_quotas("ersap:chips=8:kv_pages=1024,batch@jlab:chips=4")
    assert quotas[0] == qos.Quota("ersap", None, 8, None, 1024)
    assert quotas[1] == qos.Quota("batch", "jlab", 4, None, None)
    with pytest.raises(ValueError):
        qos.parse_quotas("ersap:watts=9")
    with pytest.raises(ValueError):
        qos.parse_quotas("ersap")


# --------------------------------------------------------------- quotas

def test_quota_filter_blocks_and_releases():
    cluster = mkcluster(2, chips=4)
    cluster.apply_quota(qos.Quota(owner="team", chips=2), 0.0)
    sched = Scheduler(cluster)
    for i in range(3):
        cluster.submit(mkpod(f"t{i}", chips=1), 0.0, owner="team")
    sched.run_once(0.0)
    bound = [r for r in cluster.pods.values() if r.bound]
    assert len(bound) == 2                       # third is over quota
    blocked = cluster.pods[next(r.name for r in cluster.pods.values()
                                if not r.bound)]
    assert "quota" in blocked.last_reason
    # quota-blocked pods park at max backoff and log one transition event
    assert blocked.next_retry == sched.backoff_max
    sched.run_once(sched.backoff_max + 1.0)
    assert cluster.event_reasons(blocked.name).count("FailedScheduling") == 1
    # a scale-down frees fair share -> the blocked pod binds
    cluster.evict(bound[0].name, 200.0)
    sched.run_once(200.0)
    assert cluster.pods[blocked.name].bound
    cluster.ledger.assert_balanced()


def test_failed_scheduling_event_reemitted_on_reason_transition():
    cluster = mkcluster(1, chips=1)
    cluster.apply_quota(qos.Quota(owner="team", chips=0), 0.0)
    sched = Scheduler(cluster)
    rec = cluster.submit(mkpod("a"), 0.0, owner="team")
    sched.run_once(0.0)
    sched.run_once(sched.backoff_max + 1.0)      # same reason: no new event
    assert cluster.event_reasons("a").count("FailedScheduling") == 1
    # an unquota'd pod takes the chip while "a" is parked...
    cluster.submit(mkpod("hog", chips=1), 70.0)
    sched.run_once(70.0)
    assert cluster.pods["hog"].bound
    # ...then the quota is raised: capacity is the blocker now — a
    # different reason, so exactly one more transition event
    cluster.apply_quota(qos.Quota(owner="team", chips=4), 130.0)
    sched.run_once(float(2 * sched.backoff_max + 71.0))
    assert not cluster.pods["a"].bound
    assert "chips" in rec.last_reason
    assert cluster.event_reasons("a").count("FailedScheduling") == 2


def test_per_site_quota_steers_to_other_site():
    cluster = mkcluster(2, chips=2, sites=["jlab", "nersc"])
    cluster.apply_quota(qos.Quota(owner="team", site="jlab", chips=0), 0.0)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("a"), 0.0, owner="team")
    sched.run_once(0.0)
    rec = cluster.pods["a"]
    assert rec.bound and cluster.nodes[rec.pod.node].site == "nersc"


def test_kv_pages_quota_counts_declared_pools():
    cluster = mkcluster(2, chips=4)
    cluster.apply_quota(qos.Quota(owner="serve", kv_pages=100), 0.0)
    sched = Scheduler(cluster)
    a = cluster.submit(mkpod("a"), 0.0, owner="serve", request_kv_pages=64)
    sched.run_once(0.0)
    assert a.bound
    b = cluster.submit(mkpod("b"), 1.0, owner="serve", request_kv_pages=64)
    sched.run_once(1.0)
    assert not b.bound and "kv_pages" in b.last_reason
    assert cluster.ledger.usage("serve").kv_pages == 64


def test_fair_share_orders_equal_priority_queue():
    cluster = mkcluster(1, chips=4)
    cluster.apply_quota(qos.Quota(owner="hog", chips=4), 0.0)
    cluster.apply_quota(qos.Quota(owner="fair", chips=4), 0.0)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("h0", chips=3), 0.0, owner="hog")
    sched.run_once(0.0)                          # hog at 3/4 share
    # one chip left; hog submitted FIRST but fair is further below quota
    cluster.submit(mkpod("h1", chips=1), 1.0, owner="hog")
    cluster.submit(mkpod("f0", chips=1), 2.0, owner="fair")
    sched.run_once(3.0)
    assert cluster.pods["f0"].bound
    assert not cluster.pods["h1"].bound


def test_reject_classification_ignores_node_and_owner_names():
    """Reject kinds are classified on the reason after the "node: "
    prefix — a node named 'quota-exp-0' must not make a capacity reject
    read as quota-blocked (which would park the pod at max backoff and
    hide it from reprovision's starved-chips sizing)."""
    from repro.core.jcs import CentralService
    cluster = Cluster()
    cluster.register_node(start_vk("quota-exp-0", now=0.0,
                                   slice_spec=SliceSpec(chips=1)), 0.0)
    cluster.heartbeat("quota-exp-0", 0.0)
    sched = Scheduler(cluster)
    rec = cluster.submit(mkpod("big", chips=2), 0.0)
    sched.run_once(0.0)
    assert "insufficient chips" in rec.last_reason
    # exponential backoff (capacity can free), not the quota park
    assert sched.backoff_base <= rec.next_retry \
        <= sched.backoff_base * (1 + sched.backoff_jitter)
    assert rec.next_retry < sched.backoff_max
    # and reprovision still counts it as chip-starved
    assert CentralService._starved_chips(cluster, 1.0) == {"Local": [2]}


# ----------------------------------------------------------- preemption

def test_preemption_never_selects_equal_or_higher_priority():
    cluster = mkcluster(1, chips=2)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("peer", chips=2), 0.0, priority_class="standard")
    sched.run_once(0.0)
    cluster.submit(mkpod("claimant", chips=2), 1.0,
                   priority_class="standard")
    sched.run_once(1.0)
    # equal priority: no preemption, the claimant backs off
    assert cluster.pods["peer"].bound
    assert not cluster.pods["claimant"].bound
    assert "Preempted" not in cluster.event_reasons()
    # escalate the claimant -> strictly higher now, preemption fires
    rec = cluster.pods["claimant"]
    rec.priority, rec.priority_class = 100, "latency-critical"
    rec.next_retry = 2.0
    sched.run_once(2.0)
    assert cluster.pods["claimant"].bound
    assert "Preempted" in cluster.event_reasons("peer")
    assert "peer" in cluster.pods and not cluster.pods["peer"].bound


def test_preemption_skips_non_preemptible_victims():
    cluster = mkcluster(1, chips=2)
    # a low-priority but non-preemptible tier (e.g. a licensed daemon)
    cluster.apply_priority_class(
        qos.PriorityClass("pinned", 1, preemptible=False), 0.0)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("pin", chips=2), 0.0, priority_class="pinned")
    sched.run_once(0.0)
    cluster.submit(mkpod("hi", chips=2), 1.0,
                   priority_class="latency-critical")
    sched.run_once(1.0)
    assert cluster.pods["pin"].bound             # untouched
    assert not cluster.pods["hi"].bound
    assert "Preempted" not in cluster.event_reasons()


def test_preempt_checkpoints_victim_and_books_balance(tmp_path):
    """Victims take the §4.5.4 checkpoint path: the requeued record
    carries the snapshot, the rebind is a Rescheduled event, and the
    quota ledger balances at every step of preempt -> requeue ->
    reschedule."""
    state = {"batch-0": {"step": 41}}
    cluster = mkcluster(1, chips=2)
    cluster.apply_quota(qos.Quota(owner="batch", chips=2), 0.0)
    cluster.apply_deployment(Deployment("batch", 1, template=PodTemplate(
        tolerations=list(TOL), request_chips=2, priority_class="batch",
        checkpoint_state=lambda name: state.get(name))), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    plane.step(0.0)
    assert cluster.pods["batch-0"].bound
    cluster.ledger.assert_balanced()

    cluster.submit(mkpod("hot", chips=2), 10.0,
                   priority_class="latency-critical")
    plane.scheduler.run_once(10.0)
    assert cluster.pods["hot"].bound
    victim = cluster.pods["batch-0"]
    assert not victim.bound
    assert victim.restored_from == "batch-0"
    assert int(victim.restored_state["step"]) == 41
    assert victim.priority_class == "batch"      # spec intact
    assert "Checkpointed" in cluster.event_reasons("batch-0")
    cluster.ledger.assert_balanced()

    # capacity appears -> the victim reschedules with its state
    cluster.register_node(start_vk("n1", now=20.0,
                                   slice_spec=SliceSpec(chips=2)), 20.0)
    cluster.heartbeat("n1", 20.0)
    plane.scheduler.run_once(20.0)
    moved = cluster.pods["batch-0"]
    assert moved.bound and moved.pod.node == "n1"
    assert "Rescheduled" in cluster.event_reasons("batch-0")
    books = cluster.ledger.assert_balanced()
    assert books["chips_used"] == 4


def test_preemptor_cannot_bypass_own_quota():
    cluster = mkcluster(1, chips=2)
    cluster.apply_quota(qos.Quota(owner="hot", chips=0), 0.0)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("low", chips=2), 0.0, priority_class="batch")
    sched.run_once(0.0)
    cluster.submit(mkpod("h0", chips=2), 1.0, owner="hot",
                   priority_class="latency-critical")
    sched.run_once(1.0)
    assert cluster.pods["low"].bound             # quota blocks the preemptor
    assert not cluster.pods["h0"].bound
    assert "Preempted" not in cluster.event_reasons()


# ------------------------------------------------- autoscaler + policy

def test_hpa_multi_signal_takes_max_proposal():
    cfg = HPAConfig(target=10.0, max_replicas=8, tokens_target=100.0,
                    occupancy_target=0.8, scale_down_stabilization=0.0)
    hpa = HPA(cfg)
    # queue calm, tokens calm, but the slab is saturated -> scale on memory
    d = hpa.evaluate_signals(2, PressureSignals(
        queue_depth=20.0, tokens_per_s=200.0, slab_occupancy=1.0), 0.0)
    assert d == 3                                 # ceil(2 * 1.0 / 0.8)
    # all signals in-band: hold
    hpa2 = HPA(cfg)
    assert hpa2.evaluate_signals(2, PressureSignals(
        queue_depth=20.0, tokens_per_s=200.0, slab_occupancy=0.8), 0.0) == 2
    # queue pressure dominates when it proposes more
    hpa3 = HPA(cfg)
    assert hpa3.evaluate_signals(2, PressureSignals(
        queue_depth=80.0, tokens_per_s=0.0, slab_occupancy=0.0), 0.0) == 8


def test_hpa_signals_respect_stabilization_window():
    cfg = HPAConfig(target=10.0, max_replicas=8,
                    scale_down_stabilization=300.0)
    hpa = HPA(cfg)
    assert hpa.evaluate_signals(2, PressureSignals(queue_depth=80.0),
                                0.0) == 8
    # pressure gone, but the 8-recommendation is inside the window
    assert hpa.evaluate_signals(8, PressureSignals(queue_depth=0.0),
                                100.0) == 8
    assert hpa.evaluate_signals(8, PressureSignals(queue_depth=0.0),
                                400.0) < 8


def test_policy_action_space_and_hysteresis():
    policy = ControlPolicy(occupancy_high=0.9, occupancy_low=0.5)
    twin = DigitalTwin()
    # calm queue, calm slab: low tier
    for _ in range(4):
        twin.assimilate(5.0, 16)
    control, tier = policy.recommend_action(twin, 16, 0.0, occupancy=0.2)
    assert control == 16 and tier == "standard"
    # memory pressure alone escalates the tier at unchanged capacity
    control, tier = policy.recommend_action(twin, 16, 1.0, occupancy=0.95)
    assert control == 16 and tier == "latency-critical"
    # hysteresis band: mid occupancy keeps the previous tier
    control, tier = policy.recommend_action(twin, 16, 2.0, occupancy=0.7)
    assert tier == "latency-critical"
    # clear the band: back to standard
    control, tier = policy.recommend_action(twin, 16, 3.0, occupancy=0.1)
    assert tier == "standard"
    # predicted queue spike escalates capacity AND tier together
    for _ in range(6):
        twin.assimilate(240.0, 16)
    control, tier = policy.recommend_action(twin, 16, 4.0, occupancy=0.1)
    assert control == 32 and tier == "latency-critical"


# ------------------------------------------------------ drain round-trip

def test_priority_write_round_trips_full_drain(tmp_path):
    """The twin's priority write survives the §4.5.4 loop: after a full
    walltime drain the replacement pods (new names, restored state) come
    back at the escalated tier."""
    counters = {}
    cluster = mkcluster(2, chips=2, walltimes=[120.0, 0.0])
    cluster.apply_deployment(Deployment("svc", 1, template=PodTemplate(
        tolerations=list(TOL), request_chips=1, priority_class="standard",
        checkpoint_state=lambda name: counters.get(name))), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    plane.scheduler.scorers = [
        lambda rec, node, sched, now: 1.0 if node.name == "n0" else 0.0]
    plane.step(0.0)
    first = cluster.pods_of("svc")[0]
    assert first.pod.node == "n0" and first.priority == 10
    counters[first.name] = {"served": 7}
    cluster.set_priority("svc", "latency-critical", 30.0, source="twin")
    assert cluster.pods_of("svc")[0].priority == 100

    now = 70.0                                   # inside the drain margin
    for name in cluster.nodes:
        cluster.heartbeat(name, now)
    plane.scheduler.scorers = []
    plane.step(now)
    moved = cluster.pods_of("svc")[0]
    assert moved.name != first.name and moved.bound
    assert moved.restored_from == first.name
    assert int(moved.restored_state["served"]) == 7
    # the escalated tier survived the drain into the replacement's spec
    assert moved.priority_class == "latency-critical"
    assert moved.priority == 100


# -------------------------------------------------- mixed-tenant e2e

def test_mixed_tenant_spike_zero_serving_loss(tmp_path):
    """Acceptance (compact bench_priority_spike): serving + saturating
    batch tenant at equal priority; a priority write + scale-up preempts
    batch (checkpointed), de-escalation lets batch resume — and every
    serving request that arrived is served exactly once."""
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    cluster = Cluster()
    for i in range(2):
        cluster.register_node(start_vk(f"n{i}", now=0.0,
                                       slice_spec=SliceSpec(chips=2)), 0.0)
        cluster.heartbeat(f"n{i}", 0.0)
    cluster.apply_quota(qos.Quota(owner="ersap", chips=2), 0.0)
    cluster.apply_quota(qos.Quota(owner="batch", chips=3), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    eng = StreamEngine(cfg, serving, list(cluster.nodes.values()),
                       service_rate=6.0, max_batch=4,
                       cluster=cluster, plane=plane)
    eng.deploy(0.0)

    batch = qos.BatchTenant(cluster, 3, priority_class="standard")
    eng.reconcile(0.0)
    assert batch.bound == 3

    dt = 10.0
    for t in range(18):
        now = t * dt
        for name in cluster.nodes:
            cluster.heartbeat(name, now)
        if t == 4:      # spike: the control writes (priority, replicas)
            cluster.set_priority("ersap", "latency-critical", now,
                                 source="twin")
            cluster.scale("ersap", 2, now, source="twin")
        if t == 10:     # spike over
            cluster.set_priority("ersap", "standard", now, source="twin")
            cluster.scale("ersap", 1, now, source="twin")
        eng.reconcile(now)
        batch.advance()
        eng.tick(now, dt, lam=1.5 if t < 12 else 0.0)
        cluster.ledger.assert_balanced()
        if t == 2:
            # the slab gauge scrapes the per-tick peak, not the post-pump
            # quiescent value (which is always 0)
            assert any(
                reg.metrics["ersap_slab_slots_used"].value > 0
                for reg in eng.registries.values()
                if "ersap_slab_slots_used" in reg.metrics)
    # a batch pod was preempted and resumed with checkpoint-identical state
    assert batch.resumed and not batch.mismatches
    assert eng.source.rid > 0
    assert len(eng.completed) == eng.source.rid   # zero loss, exactly once
    assert len(eng.queue) == 0
    preempted = [ev.name for ev in cluster.events
                 if ev.reason == "Preempted"]
    assert preempted and all(n.startswith("batch") for n in preempted)
