"""Paper §4.3: container/pod lifecycle state machines (Tables 6/7)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.state_machine import (CREATE_STAGES, CREATE_UIDS, GET_UIDS,
                                      Condition, ConditionStatus, Container,
                                      ContainerPhase, Pod, PodPhase,
                                      create_pod_container,
                                      get_pods_container)


def test_table6_uid_indices_verbatim():
    assert CREATE_UIDS == {
        "create-cont-readDefaultVolDirError": 0,
        "create-cont-copyFileError": 1,
        "create-cont-cmdStartError": 2,
        "create-cont-getPgidError": 3,
        "create-cont-createStdoutFileError": 4,
        "create-cont-createStderrFileError": 5,
        "create-cont-cmdWaitError": 6,
        "create-cont-writePgidError": 7,
        "create-cont-containerStarted": 8,
    }


def test_table7_uid_indices_verbatim():
    assert GET_UIDS == {
        "get-cont-create": 0,
        "get-cont-getPidsError": 1,
        "get-cont-getStderrFileInfoError": 2,
        "get-cont-stderrNotEmpty": 3,
        "get-cont-completed": 4,
        "get-cont-running": 5,
    }


def test_create_happy_path():
    c = Container("w")
    st_ = create_pod_container(c, now=1.0)
    assert st_.uid == "create-cont-containerStarted"
    assert st_.uid_index == 8
    assert st_.phase == ContainerPhase.RUNNING
    assert st_.pgid is not None
    assert st_.started_at == 1.0


@pytest.mark.parametrize("stage", CREATE_STAGES)
def test_create_failure_at_every_stage(stage):
    c = Container("w", fail_at=stage)
    st_ = create_pod_container(c, now=0.0)
    assert st_.phase == ContainerPhase.TERMINATED
    assert st_.uid.endswith("Error")
    assert st_.uid_index == CREATE_UIDS[st_.uid]
    assert c.stderr


def test_get_pods_running_then_completed():
    c = Container("w")
    create_pod_container(c, 0.0)
    st_ = get_pods_container(c, 1.0)
    assert st_.uid == "get-cont-running" and st_.uid_index == 5
    c._finished = True
    st_ = get_pods_container(c, 2.0)
    assert st_.uid == "get-cont-completed" and st_.uid_index == 4
    assert st_.exit_code == 0


def test_get_pods_stderr_not_empty_fails_pod():
    c = Container("w")
    create_pod_container(c, 0.0)
    c.stderr = "RuntimeError: boom"
    st_ = get_pods_container(c, 1.0)
    assert st_.uid == "get-cont-stderrNotEmpty" and st_.uid_index == 3
    pod = Pod("p", [c])
    assert pod.phase == PodPhase.FAILED


def test_pod_phase_and_conditions():
    conts = [Container("a"), Container("b")]
    pod = Pod("p", conts)
    assert pod.phase == PodPhase.PENDING
    for c in conts:
        create_pod_container(c, 5.0)
    pod.set_conditions_create(5.0)
    assert pod.phase == PodPhase.RUNNING and pod.ready
    types = {c.type: c for c in pod.conditions}
    assert types["PodScheduled"].status == ConditionStatus.TRUE
    assert types["PodInitialized"].status == ConditionStatus.TRUE
    assert types["PodReady"].status == ConditionStatus.TRUE
    # retrieval phase keeps PodReady transition pinned to first container start
    for c in conts:
        get_pods_container(c, 9.0)
    pod.set_conditions_get(9.0)
    assert pod.condition("PodReady").last_transition_time == 5.0
    # all containers complete -> Succeeded
    for c in conts:
        c._finished = True
        get_pods_container(c, 10.0)
    assert pod.phase == PodPhase.SUCCEEDED


@settings(max_examples=50, deadline=None)
@given(fail_stage=st.sampled_from([None] + CREATE_STAGES),
       finishes=st.booleans(), errors=st.booleans())
def test_lifecycle_invariants(fail_stage, finishes, errors):
    """Property: UID always consistent with table index; terminal states
    are absorbing w.r.t. GetPods; exit codes match stderr semantics."""
    c = Container("w", fail_at=fail_stage)
    create_pod_container(c, 0.0)
    if fail_stage is None and errors:
        c.stderr = "x"
    if fail_stage is None and finishes:
        c._finished = True
    s1 = get_pods_container(c, 1.0)
    assert s1.uid_index == GET_UIDS[s1.uid]
    s2 = get_pods_container(c, 2.0)
    if s1.phase == ContainerPhase.TERMINATED:
        assert s2.phase == ContainerPhase.TERMINATED
        assert (s2.exit_code == 0) == (not c.stderr and fail_stage is None)
