"""Loop-aware HLO analyzer: trip counts, dot FLOPs, wire model, traffic."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis as RA
from repro.roofline import hlo_graph as H


def test_wire_model_formulas():
    g = 16
    assert H._wire_bytes("all-gather", 1600, g) == 1600 * 15 / 16
    assert H._wire_bytes("reduce-scatter", 100, g) == 100 * 15
    assert H._wire_bytes("all-reduce", 1600, g) == 2 * 1600 * 15 / 16
    assert H._wire_bytes("all-to-all", 1600, g) == 1600 * 15 / 16
    assert H._wire_bytes("collective-permute", 1600, g) == 1600.0
    assert H._wire_bytes("all-reduce", 1600, 1) == 0.0


def test_shape_bytes_parsing():
    assert H._shape_elems_bytes("bf16[60,8,2048]{2,1,0}") == 60 * 8 * 2048 * 2
    assert H._shape_elems_bytes("f32[4,4]") == 64
    assert H._shape_elems_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert H._shape_elems_bytes("pred[]") == 1
    assert H._shape_elems_bytes("token[]") == 0


def test_group_size_parsing():
    assert H._group_size("replica_groups={{0,1,2,3}}", 1) == 4
    assert H._group_size("replica_groups=[32,16]<=[512]", 1) == 16
    assert H._group_size("no groups here", 7) == 7


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)).compile()
    la = H.analyze(c.as_text())
    assert la.while_trips == [7]
    assert la.dot_flops == 7 * 2 * 64 * 128 * 128


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)).compile()
    la = H.analyze(c.as_text())
    assert la.dot_flops == 3 * 5 * 2 * 32 * 64 * 64


def test_traffic_excludes_loop_copies_and_charges_slices():
    """A scan slicing a big stacked buffer must charge slice-sized reads,
    not the full buffer per iteration."""
    L, N = 16, 512

    def f(x, w):
        def body(c, wl):
            return c * wl[0, 0] + 1.0, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((L, N, N), jnp.float32)).compile()
    la = H.analyze(c.as_text())
    # full-buffer-per-iteration would be L * (L*N*N*4) = 256 MiB; the
    # slice-aware model charges ~one (1,N,N) slice per iteration (~2 MiB)
    naive = L * (L * N * N * 4)
    assert la.traffic_bytes < naive / 4, (la.traffic_bytes, naive)
    assert la.traffic_bytes < 64 << 20


def test_roofline_terms_and_dominant():
    r = RA.Roofline(flops_per_device=197e12, bytes_per_device=819e9 / 2,
                    wire_bytes_per_device=50e9 * 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(2.0)
    assert r.dominant == "collective"
    assert r.bound_s == pytest.approx(2.0)


def test_model_flops():
    from repro.configs.base import SHAPES
    class Cfg:  # minimal stand-in
        pass
    n = 1_000_000
    assert RA.model_flops(Cfg, SHAPES["train_4k"], n) == \
        6.0 * n * 4096 * 256
    assert RA.model_flops(Cfg, SHAPES["prefill_32k"], n) == \
        2.0 * n * 32768 * 32
    assert RA.model_flops(Cfg, SHAPES["decode_32k"], n) == 2.0 * n * 128
