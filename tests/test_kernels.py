"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
all against the pure-jnp oracles in repro.kernels.ref (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_chunkwise_kernel
from repro.kernels.paged_decode_attention import paged_decode_attention_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.xlstm import mlstm_chunkwise


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# --------------------------------------------------------- flash attention

SWEEP = [
    # B, Hq, Hkv, Sq, Sk, dh, causal, window, chunk, dtype
    (2, 4, 2, 128, 128, 64, True, None, None, jnp.float32),
    (1, 8, 1, 256, 256, 128, True, None, None, jnp.float32),
    (2, 4, 4, 128, 256, 64, False, None, None, jnp.float32),
    (1, 2, 2, 256, 256, 64, True, 64, None, jnp.float32),
    (1, 2, 1, 256, 256, 64, True, None, 128, jnp.float32),
    (2, 4, 2, 128, 128, 64, True, None, None, jnp.bfloat16),
    (1, 4, 2, 384, 384, 32, True, 128, None, jnp.float32),
]


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,dh,causal,window,chunk,dtype", SWEEP)
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, dh, causal, window,
                               chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                          interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@settings(max_examples=12, deadline=None)
@given(data=st.data())
def test_flash_attention_property(data):
    """Property: kernel == oracle across random GQA geometries, and output
    rows are convex combinations of V rows (|out| <= max |v|)."""
    B = data.draw(st.integers(1, 2))
    Hkv = data.draw(st.sampled_from([1, 2]))
    G = data.draw(st.sampled_from([1, 2, 4]))
    S = data.draw(st.sampled_from([128, 256]))
    dh = data.draw(st.sampled_from([32, 64]))
    causal = data.draw(st.booleans())
    ks = jax.random.split(jax.random.PRNGKey(data.draw(st.integers(0, 99))), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=2e-4)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


def test_blockwise_jnp_matches_naive():
    """The lowering-path jnp attention equals the naive oracle too."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, Hq, Hkv, S, dh = 2, 4, 2, 192, 32
    q = jax.random.normal(ks[0], (B, S, Hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, window=64, block_kv=64)
    ref = R.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True, window=64)
    np.testing.assert_allclose(out.transpose(0, 2, 1, 3), ref,
                               atol=2e-4, rtol=2e-4)


# ----------------------------------------------------------------- mLSTM

@pytest.mark.parametrize("B,S,H,dh,chunk,dtype", [
    (2, 256, 2, 64, 64, jnp.float32),
    (1, 128, 4, 32, 32, jnp.float32),
    (2, 128, 2, 64, 64, jnp.bfloat16),
    (1, 192, 1, 128, 64, jnp.float32),
])
def test_mlstm_kernel_sweep(B, S, H, dh, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, H, dh), dtype) * dh ** -0.5
    v = jax.random.normal(ks[2], (B, S, H, dh), dtype)
    li = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    lf = jax.random.normal(ks[4], (B, S, H), jnp.float32) + 2.0
    h_ref, (C_r, n_r, m_r) = R.mlstm_ref(q, k, v, li, lf)
    h_ker, (C_k, n_k, m_k) = mlstm_chunkwise_kernel(
        q, k, v, li, lf, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(h_ker, np.float32),
                               np.asarray(h_ref, np.float32), **tol(dtype))
    np.testing.assert_allclose(C_k, C_r, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(m_k, m_r, atol=1e-4, rtol=1e-4)


def test_mlstm_state_handoff_prefill_to_decode():
    """Kernel prefill state continues exactly via the decode recurrence."""
    from repro.models.xlstm import mlstm_decode
    B, S, H, dh = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (B, S + 1, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S + 1, H, dh), jnp.float32) * dh ** -0.5
    v = jax.random.normal(ks[2], (B, S + 1, H, dh), jnp.float32)
    li = jax.random.normal(ks[3], (B, S + 1, H), jnp.float32)
    lf = jax.random.normal(ks[4], (B, S + 1, H), jnp.float32)
    h_all, _ = R.mlstm_ref(q, k, v, li, lf)
    _, state = mlstm_chunkwise_kernel(q[:, :S], k[:, :S], v[:, :S],
                                      li[:, :S], lf[:, :S], chunk=32,
                                      interpret=True)
    h1, _ = mlstm_decode(q[:, S], k[:, S], v[:, S], li[:, S], lf[:, S], state)
    np.testing.assert_allclose(h1, h_all[:, S], atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------- SSM

@pytest.mark.parametrize("B,S,di,N,chunk,bdi", [
    (2, 256, 512, 16, 64, 256),
    (1, 128, 256, 8, 32, 128),
    (2, 64, 128, 16, 64, 64),
])
def test_ssm_kernel_sweep(B, S, di, N, chunk, bdi):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    u = jax.random.normal(ks[0], (B, S, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, N)))
    Bs = jax.random.normal(ks[3], (B, S, N))
    Cs = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (di,))
    y_ref, h_ref = R.ssm_ref(u, dt, A, Bs, Cs, D)
    y_ker, h_ker = ssm_scan_kernel(u, dt, A, Bs, Cs, D, chunk=chunk,
                                   block_di=bdi, interpret=True)
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(h_ker, h_ref, atol=2e-4, rtol=2e-3)


def test_ssm_kernel_matches_model_associative_scan():
    from repro.models.hybrid import ssm_scan
    B, S, di, N = 1, 128, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    u = jax.random.normal(ks[0], (B, S, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, N)))
    Bs = jax.random.normal(ks[3], (B, S, N))
    Cs = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (di,))
    y_model, h_model = ssm_scan(u, dt, A, Bs, Cs, D)
    y_ker, h_ker = ssm_scan_kernel(u, dt, A, Bs, Cs, D, chunk=32,
                                   block_di=128, interpret=True)
    np.testing.assert_allclose(y_ker, y_model, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(h_ker, h_model, atol=2e-4, rtol=2e-3)


# --------------------------------------------------------- decode attention

@pytest.mark.parametrize("B,Hq,Hkv,Smax,dh,bk,window,chunk", [
    (3, 8, 2, 1024, 64, 256, None, None),
    (2, 4, 1, 512, 128, 128, 128, None),
    (2, 2, 2, 512, 64, 256, None, 256),
])
def test_decode_attention_sweep(B, Hq, Hkv, Smax, dh, bk, window, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, dh), jnp.float32)
    lens = jnp.asarray(np.linspace(3, Smax, B).astype(np.int32))
    o_ref = R.decode_attention_ref(q, kc, vc, lengths=lens, window=window,
                                   chunk=chunk)
    o_ker = decode_attention_kernel(q, kc, vc, lens, window=window,
                                    chunk=chunk, block_k=bk, interpret=True)
    np.testing.assert_allclose(o_ker, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_attention_per_row_pos_kernel_parity():
    """The slab layout's per-row position vector (not just scalar pos):
    Pallas decode kernel (interpret) == jnp model decode attention."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, Hq, Hkv, Smax, dh = 4, 4, 2, 256, 32
    q = jax.random.normal(ks[0], (B, 1, Hq, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, dh), jnp.float32)
    pos = jnp.asarray([3, 77, 130, 255], jnp.int32)        # per-row depths
    o_jnp = decode_attention(q, kc, vc, pos=pos)
    o_skip = decode_attention(q, kc, vc, pos=pos, block_skip=64)
    o_ker = ops.decode_attention(q, kc, vc, pos + 1, block_k=64)
    np.testing.assert_allclose(o_ker, o_jnp, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(o_skip, o_jnp, atol=2e-5, rtol=2e-5)


def _paged_case(seed, B=3, Hq=4, Hkv=2, dh=16, ps=8, P=4, n_pages=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Hq, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, Hkv, dh)), jnp.float32)
    lengths = rng.integers(1, P * ps + 1, B)
    pages = np.zeros((B, P), np.int32)
    nxt = 1                      # page 0 stays the null page
    for b in range(B):
        for j in range(-(-int(lengths[b]) // ps)):
            pages[b, j] = nxt
            nxt += 1
    assert nxt <= n_pages
    return q, kp, vp, jnp.asarray(pages), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("window,chunk", [(None, None), (11, None),
                                          (None, 16)])
def test_paged_decode_attention_kernel_vs_gathered_ref(window, chunk):
    """Paged kernel (scalar-prefetch page table, per-row early exit over
    the page grid) == gather-the-pages-then-dense-oracle."""
    q, kp, vp, pages, lengths = _paged_case(0)
    out = paged_decode_attention_kernel(q, kp, vp, pages, lengths,
                                        window=window, chunk=chunk,
                                        interpret=True)
    B, P = pages.shape
    ps = kp.shape[1]
    kb = kp[pages].reshape(B, P * ps, *kp.shape[2:])
    vb = vp[pages].reshape(B, P * ps, *vp.shape[2:])
    ref = R.decode_attention_ref(q, kb, vb, lengths=lengths, window=window,
                                 chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_paged_decode_attention_property(data):
    seed = data.draw(st.integers(0, 99))
    B = data.draw(st.integers(1, 4))
    ps = data.draw(st.sampled_from([4, 8, 16]))
    P = data.draw(st.sampled_from([2, 4]))
    q, kp, vp, pages, lengths = _paged_case(seed, B=B, ps=ps, P=P,
                                            n_pages=B * P + 2)
    out = paged_decode_attention_kernel(q, kp, vp, pages, lengths,
                                        interpret=True)
    kb = kp[pages].reshape(B, P * ps, *kp.shape[2:])
    vb = vp[pages].reshape(B, P * ps, *vp.shape[2:])
    ref = R.decode_attention_ref(q, kb, vb, lengths=lengths)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_kernel_mode_routes_paged_dispatch():
    """The kernel_mode toggle end-to-end at the ops layer: pallas
    (interpret on CPU) and jnp must produce matching outputs for the
    slab layout's per-row lengths, and auto must resolve per backend."""
    q, kp, vp, pages, lengths = _paged_case(3)
    q4 = q[:, None]                           # ops layer takes (B,1,Hq,dh)
    try:
        ops.set_kernel_mode("jnp")
        assert ops.resolved_mode() == "jnp" and not ops.use_kernels()
        o_jnp = ops.decode_attention_paged(q4, kp, vp, pages, lengths,
                                           kv_bucket=32, page_size=8)
        ops.set_kernel_mode("pallas")
        assert ops.use_kernels()
        o_pal = ops.decode_attention_paged(q4, kp, vp, pages, lengths,
                                           kv_bucket=32, page_size=8)
        ops.set_kernel_mode("auto")
        assert ops.resolved_mode() == ("pallas" if ops.on_tpu() else "jnp")
    finally:
        ops.set_kernel_mode(None)
    np.testing.assert_allclose(o_pal, o_jnp, atol=2e-5, rtol=2e-5)
    with pytest.raises(ValueError):
        ops.set_kernel_mode("cuda")


def test_decode_step_paged_pallas_vs_jnp():
    """Model-level parity: one paged transformer decode step under
    kernel_mode=pallas (interpret) matches kernel_mode=jnp — logits and
    the KV written into the pool."""
    from repro.configs.base import get_config
    from repro.models import model_api as MA
    from repro.models import transformer
    cfg = get_config("qwen2-7b").reduced()
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    rows, ps, n_pages = 3, 8, 8
    pages = np.zeros((rows, 3), np.int32)
    pages[0, :2] = [1, 2]
    pages[1, :1] = [3]
    cache = MA.init_paged_cache(cfg, rows, n_pages, ps)
    cache["pos"] = jnp.asarray([9, 4, 0], jnp.int32)
    tok = jnp.asarray([[7], [11], [0]], jnp.int32)
    outs = {}
    try:
        for mode in ("jnp", "pallas"):
            ops.set_kernel_mode(mode)
            logits, new_cache = transformer.decode_step(
                params, tok, dict(cache), cfg, pages=jnp.asarray(pages),
                kv_bucket=16)
            outs[mode] = (logits, new_cache["dense"]["k"])
    finally:
        ops.set_kernel_mode(None)
    np.testing.assert_allclose(outs["pallas"][0], outs["jnp"][0],
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(outs["pallas"][1], outs["jnp"][1],
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_decode_attention_property(data):
    B = data.draw(st.integers(1, 3))
    Hkv = data.draw(st.sampled_from([1, 2]))
    G = data.draw(st.sampled_from([1, 3]))
    Smax = data.draw(st.sampled_from([256, 512]))
    dh = data.draw(st.sampled_from([32, 64]))
    seed = data.draw(st.integers(0, 99))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, dh), jnp.float32)
    lens = jnp.asarray(
        np.random.default_rng(seed).integers(1, Smax + 1, B), jnp.int32)
    o_ref = R.decode_attention_ref(q, kc, vc, lengths=lens)
    o_ker = decode_attention_kernel(q, kc, vc, lens, block_k=128,
                                    interpret=True)
    np.testing.assert_allclose(o_ker, o_ref, atol=2e-5, rtol=2e-5)
