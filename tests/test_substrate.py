"""Optimizer, schedule, data pipeline, checkpointing, grad compression."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, RequestSource, SyntheticDataset
from repro.optim import adamw
from repro.optim.compression import (dequantize_int8, ef_compress,
                                     ef_compress_tree, init_ef,
                                     quantize_int8)


# ------------------------------------------------------------------ adamw

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, clip_norm=100.0)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw.apply(grads, opt, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)
    # monotone decreasing after warmup
    vals = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(10, 100, 10)]
    assert all(b <= a for a, b in zip(vals, vals[1:]))


def test_grad_clip_scales_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    _, _, m = adamw.apply({"w": jnp.full(4, 100.0)}, opt, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ------------------------------------------------------------------- data

def test_data_deterministic_and_checkpointable():
    cfg = DataConfig(batch=4, seq=16, vocab=97)
    d1 = SyntheticDataset(cfg)
    b1 = [d1.next_batch() for _ in range(3)]
    d2 = SyntheticDataset(cfg)
    d2.next_batch()
    state = d2.state()
    d3 = SyntheticDataset(cfg)
    d3.restore(state)
    b3 = d3.next_batch()
    np.testing.assert_array_equal(b1[1]["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["tokens"][:, 1:],
                                  b1[0]["labels"][:, :-1])


def test_request_source_poisson_rate():
    src = RequestSource(seed=1)
    n = sum(len(src.arrivals(t * 1.0, 1.0, lam=5.0)) for t in range(500))
    assert 2200 < n < 2800      # ~2500 expected


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)},
            "s": jnp.asarray(3, jnp.int32)}
    for step in (1, 2, 3, 4):
        ckpt.save(tmp_path, step, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    dirs = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(dirs) == 2
    restored, meta = ckpt.restore(tmp_path, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert meta["step"] == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_checkpoint_async(tmp_path):
    t = ckpt.save_async(tmp_path, 7, {"a": jnp.ones(8)})
    t.join(timeout=30)
    restored, meta = ckpt.restore(
        tmp_path, {"a": jax.ShapeDtypeStruct((8,), jnp.float32)})
    assert float(restored["a"].sum()) == 8.0


# ------------------------------------------------------------ compression

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, scale, shape, pad = quantize_int8(x, block=256)
    x2 = dequantize_int8(q, scale, shape, pad)
    # max error <= scale/2 per block
    err = jnp.abs(x - x2)
    assert float(err.max()) <= float(scale.max()) * 0.51


def test_error_feedback_unbiased_over_time():
    """Sum of EF-compressed grads converges to sum of true grads."""
    key = jax.random.PRNGKey(1)
    ef = jnp.zeros(512)
    total_true = jnp.zeros(512)
    total_hat = jnp.zeros(512)
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (512,)) * 0.01
        g_hat, ef = ef_compress(g, ef, block=128)
        total_true += g
        total_hat += g_hat
    resid = float(jnp.abs(total_true - total_hat).max())
    # residual equals |ef| which is bounded by one quantization step
    assert resid < 5e-4
    np.testing.assert_allclose(total_hat + ef, total_true, atol=1e-5)


def test_ef_tree_wrapper():
    params = {"a": jnp.ones((8, 8)), "b": jnp.ones(16)}
    ef = init_ef(params)
    grads = jax.tree.map(lambda p: p * 0.1, params)
    g_hat, ef2 = ef_compress_tree(grads, ef)
    assert jax.tree.structure(g_hat) == jax.tree.structure(grads)
    assert float(jnp.abs(g_hat["a"] - 0.1).max()) < 1e-3


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_quantization_property(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (300,)) * scale
    q, s, shape, pad = quantize_int8(x, block=64)
    x2 = dequantize_int8(q, s, shape, pad)
    assert x2.shape == x.shape
    # relative block error bounded by 1/127 of block max
    assert float(jnp.abs(x - x2).max()) <= scale * 10.0 / 127 + 1e-6
