"""Prefix-sharing copy-on-write paged KV + speculative decode (PR 6):
refcount allocator semantics, splice-vs-prefill token identity (cross-wave
and same-wave sharing), CoW forking on sub-page prompts, randomized
refcount-books interleavings, drain -> restore sharing survival, and
k-token speculative decode equivalence. Every identity test compares
against the prefix-off (or spec-off) oracle on the same requests — the
sharing layer is an admission optimization, never a model change."""
import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_config
from repro.core.elastic import ElasticServing
from repro.data.pipeline import Request
from repro.models import model_api as MA
from repro.streaming.runtime import (DecodeRuntime, PageAllocator,
                                     RuntimeConfig)


@pytest.fixture(scope="module")
def serving():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    return ElasticServing(cfg, tp=1).build(1, host_params=host)


def mk_runtime(serving, rcfg, **kw):
    return DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                         gen=serving.build_gen, **kw)


def prefix_cfg(**kw):
    base = dict(max_batch=4, paged=True, page_size=16, admit_tail=0,
                prefix_cache=True)
    base.update(kw)
    return RuntimeConfig(**base)


def grouped(rid, plen, mnew, group):
    """A request carrying a template group's full prompt."""
    return Request(rid, 0.0, plen, mnew, prefix_group=group,
                   prefix_len=plen)


def oracle_log(serving, rcfg, reqs):
    """Greedy tokens of the same requests with sharing disabled."""
    import dataclasses
    off = dataclasses.replace(rcfg, prefix_cache=False, spec_decode=0)
    rt = mk_runtime(serving, off, record_tokens=True)
    rt.submit(list(reqs))
    rt.pump()
    return dict(rt.token_log)


# ------------------------------------------------------------- allocator

def test_refcount_share_free():
    a = PageAllocator(8)
    g = a.alloc(3)
    assert list(np.asarray(a.refcount)[g]) == [1, 1, 1]
    a.share(g[:2])                           # second holder splices
    assert list(np.asarray(a.refcount)[g]) == [2, 2, 1]
    assert a.shared_pages == 2
    # first free only decrements shared pages; the private one releases
    released = a.free(g)
    assert released == [g[2]]
    assert a.used_pages == 2 and a.shared_pages == 0
    # second free releases the rest; books balance, nothing double-freed
    released = a.free(g[:2])
    assert sorted(released) == sorted(g[:2])
    assert a.used_pages == 0 and a.free_pages == a.pool_pages == 8
    with pytest.raises(AssertionError):
        a.share([g[0]])                      # sharing a free page is a bug


# --------------------------------------------------- sharing correctness

def test_e2e_sharing_token_identity_any_mode(serving):
    """Small end-to-end sharing run honoring the ambient KERNEL_MODE (the
    CI pallas leg runs exactly this test in interpret mode): a second
    wave splices the first wave's still-referenced prompt pages and every
    token matches the no-sharing oracle."""
    rc = prefix_cfg(max_batch=2, decode_block=4)
    rt = mk_runtime(serving, rc, record_tokens=True)
    wave_a = [grouped(1, 16, 8, group=1)]
    wave_b = [grouped(2, 16, 2, group=1)]    # same template, later arrival
    rt.submit(wave_a)
    rt.step()                                # A admitted, still in flight
    rt.submit(wave_b)
    rt.pump()
    assert rt.prefix_hits == 1
    assert rt.token_log == oracle_log(serving, rc, wave_a + wave_b)
    assert rt.alloc.used_pages == 0 and not rt.page_table.any()


def test_same_wave_sharing_token_identity(serving):
    """One submission wave containing a template group: the leader
    prefills, same-wave mates splice its pages before it ever reaches the
    intern table (wave-local publication). Tokens match the oracle and
    the wave shares pages while in flight."""
    rc = prefix_cfg()
    reqs = [grouped(1, 32, 6, 1), grouped(2, 32, 4, 1),
            grouped(3, 32, 6, 2), Request(4, 0.0, 32, 5)]
    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit(reqs)
    rt._admit_some()
    assert rt.prefix_hits == 1               # rid 2 follows rid 1's grant
    assert rt.shared_pages > 0
    rt.pump()
    assert rt.token_log == oracle_log(serving, rc, reqs)
    assert rt.alloc.used_pages == 0


def test_partial_prefix_tail_admission(serving):
    """Shared page-aligned prefix with distinct tails: the hit splices
    the prefix pages and prefills only the remainder (a window dispatch,
    not a full prefill). Requires prompts spanning >1 page."""
    rc = prefix_cfg(max_batch=4, max_prompt_bucket=64, decode_block=4)
    # same 16-token template head, unique continuations; the leader's
    # max_new outlasts one decode block so its pages stay referenced
    mk = lambda rid: Request(rid, 0.0, 40, 12, prefix_group=3, prefix_len=16)
    reqs = [mk(1), mk(2)]
    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit([reqs[0]])
    rt.step()
    rt.submit([reqs[1]])
    rt.pump()
    assert rt.prefix_hits == 1
    assert rt.kernels.trace_counts["window"] >= 1    # tail prefill ran
    assert rt.token_log == oracle_log(serving, rc, reqs)


def test_cow_forks_writer_not_readers(serving):
    """Sub-page prompt (8 tokens, 16-token pages): both holders decode
    into the shared boundary page, so the first writer must fork onto its
    reserve page while the reader keeps the original — structurally
    visible (the rows end up on different physical pages) and
    token-identical to the no-sharing oracle."""
    rc = prefix_cfg(max_batch=2, decode_block=4)
    reqs = [grouped(1, 8, 8, 1), grouped(2, 8, 6, 1)]
    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit([reqs[0]])
    rt._admit_some()
    rt.submit([reqs[1]])
    rt._admit_some()
    pages0 = [s.pages[0] for s in rt.slots if s.busy]
    assert pages0[0] == pages0[1]            # boundary page shared
    assert rt.prefix_hits == 1
    rt._decode_block()                       # first write past the prompt
    pages1 = [s.pages[0] for s in rt.slots if s.busy]
    assert pages1[0] != pages1[1]            # writer forked, reader kept
    assert rt.cow_events >= 1
    rt.pump()
    assert rt.token_log == oracle_log(serving, rc, reqs)
    assert rt.alloc.used_pages == 0


# ------------------------------------------------------ refcount property

def test_refcount_books_random_interleavings(serving):
    """Seeded randomized admit/decode/retire/drain interleavings (the
    vendored-property-test posture: no hypothesis dependency). After
    every step: used + free == pool, page 0 unreferenced, and each page's
    refcount equals the number of slots holding it (pages + CoW reserve)
    — intern entries hold no references of their own."""
    rc = prefix_cfg(max_batch=4, decode_block=4, max_prompt_bucket=32,
                    max_new_cap=16, pool_pages=48)
    rt = mk_runtime(serving, rc)
    rng = np.random.default_rng(42)
    rid = 0

    def assert_books():
        a = rt.alloc
        assert a.used_pages + a.free_pages == rc.n_pool_pages
        holders = np.zeros(a.n_pages, np.int64)
        for s in rt.slots:
            if s.busy:
                for p in s.pages:
                    holders[p] += 1
                if s.reserve is not None:
                    holders[s.reserve] += 1
        assert holders[0] == 0               # null page never granted
        np.testing.assert_array_equal(np.asarray(a.refcount)[1:],
                                      holders[1:])
        for e in rt._intern.values():        # interned pages are live
            assert all(np.asarray(a.refcount)[list(e["pages"])] > 0)

    for round_ in range(30):
        op = rng.random()
        if op < 0.5 or not rt.inflight:
            n = int(rng.integers(1, 4))
            reqs = []
            for _ in range(n):
                rid += 1
                group = int(rng.integers(0, 3))
                plen = int(rng.choice([8, 16, 24, 32]))
                reqs.append(Request(rid, 0.0, plen,
                                    int(rng.integers(1, 9)),
                                    prefix_group=group,
                                    prefix_len=plen if group else 0))
            rt.submit(reqs)
            rt.step()
        elif op < 0.9:
            rt.step()
        else:
            carried = rt.drain()             # §4.5.4 eviction wave
            assert rt.alloc.used_pages == 0
            assert not rt.page_table.any()
            assert_books()
            rt.submit(carried)               # re-admission re-mints
            rt.step()
        assert_books()
    while rt.inflight:
        rt.step()
        assert_books()
    assert rt.alloc.used_pages == 0


# ------------------------------------------------------- drain -> restore

def test_drain_restore_preserves_sharing(serving, tmp_path):
    """Checkpoint mid-stream with two rows sharing a template prompt: the
    successor re-interns the prefix on re-admission (content-hash
    identity, not physical page ids), so sharing survives the move and
    the replay is token-identical to an uninterrupted run."""
    rc = prefix_cfg(max_batch=2, decode_block=4)
    reqs = [grouped(1, 16, 10, 1), grouped(2, 16, 8, 1)]
    ref = mk_runtime(serving, rc, record_tokens=True)
    ref.submit(list(reqs))
    ref.pump()

    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit(list(reqs))
    rt._admit_some()
    rt._decode_block()                       # both mid-generation
    assert rt.shared_pages > 0
    state = rt.state()
    tree = {k: np.asarray(v) for k, v in state.items()}
    checkpointer.save(tmp_path, 0, tree, meta={"pod": "r0"})
    restored, _ = checkpointer.restore(tmp_path, tree, step=0)
    rt.drain()
    assert rt.alloc.used_pages == 0

    rt2 = mk_runtime(serving, rc, record_tokens=True)
    rt2.restore(restored)
    rt2._admit_some()
    assert rt2.prefix_hits >= 1              # re-admission re-shared
    assert rt2.shared_pages > 0
    rt2.pump()
    assert rt2.alloc.used_pages == 0
    for r in reqs:                           # token-identical replay (the
        got = rt2.token_log[r.rid]           # PR-4 prefix-replay contract)
        assert got and got == ref.token_log[r.rid][:len(got)]


# ------------------------------------------------------ speculative decode

def test_spec_decode_token_identity(serving):
    """spec_decode=k emits exactly the one-token-at-a-time greedy stream
    (accept-prefix verification), and on replay traffic — identical
    prompts served after a paver completed — the stream drafter actually
    accepts (the speedup mechanism, not just a fallback)."""
    rc = prefix_cfg(max_batch=4, spec_decode=3)
    paver = [grouped(1, 16, 12, 1)]
    replay = [grouped(10 + j, 16, 12, 1) for j in range(3)]
    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit(list(paver))
    rt.pump()
    d0, a0 = rt.spec_drafted, rt.spec_accepted
    rt.submit(list(replay))
    rt.pump()
    assert rt.spec_rounds > 0
    # replay-phase drafts come from the paver's recorded stream and mostly
    # accept (the paver itself had nothing to draft from — excluded)
    assert (rt.spec_accepted - a0) / (rt.spec_drafted - d0) > 0.5
    # spec verify must dispatch fewer rounds than tokens emitted
    assert rt.spec_emitted > rt.spec_rounds
    assert rt.token_log == oracle_log(serving, rc, paver + replay)


def test_spec_requires_tail_free_admission(serving):
    with pytest.raises(ValueError):
        serving.runtime_kernels(
            RuntimeConfig(paged=True, spec_decode=2, admit_tail=4))


def test_prefix_and_spec_require_paged(serving):
    for bad in (RuntimeConfig(paged=False, prefix_cache=True),
                RuntimeConfig(paged=False, spec_decode=2, admit_tail=0)):
        with pytest.raises(ValueError):
            serving.runtime_kernels(bad)
