"""HPA per paper §4.4: Eq. (1), readiness gating, stabilization."""
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.hpa import (HPA, HPAConfig, MetricSample, desired_replicas,
                            pod_is_unready)
from repro.core.state_machine import Container, Pod, create_pod_container


def ready_pod(name, now):
    p = Pod(name, [Container("c")])
    create_pod_container(p.containers[0], now)
    p.set_conditions_create(now)
    return p


def test_eq1_paper_example():
    """§4.4.4: 4 replicas at 90% vs target 50% -> ceil(7.2) = 8."""
    assert desired_replicas(4, 90.0, 50.0) == 8


@settings(max_examples=100, deadline=None)
@given(current=st.integers(1, 64),
       metric=st.floats(0.01, 1e4),
       target=st.floats(0.01, 1e4))
def test_eq1_properties(current, metric, target):
    d = desired_replicas(current, metric, target)
    assert d == math.ceil(current * metric / target)
    assert d >= 1 or metric == 0
    # monotonicity in the metric
    assert desired_replicas(current, metric * 2, target) >= d


def test_readiness_gating_initialization_period():
    """Port of the §4.4.2 snippet: within cpuInitializationPeriod a pod is
    unready if not Ready or its sample predates readiness + window."""
    cfg = HPAConfig(target=50.0)
    pod = ready_pod("p", now=0.0)
    fresh = MetricSample(10.0, timestamp=200.0, window=60.0)
    stale = MetricSample(10.0, timestamp=30.0, window=60.0)
    assert not pod_is_unready(pod, fresh, now=100.0, cfg=cfg)
    assert pod_is_unready(pod, stale, now=100.0, cfg=cfg)
    # after the initialization period, Ready pods count regardless
    assert not pod_is_unready(pod, stale, now=1000.0, cfg=cfg)
    # missing start_time => unready
    p2 = Pod("q", [Container("c")])
    assert pod_is_unready(p2, fresh, now=100.0, cfg=cfg)


def test_hpa_scale_up_and_stabilized_scale_down():
    cfg = HPAConfig(target=50.0, max_replicas=10,
                    scale_down_stabilization=300.0,
                    cpu_initialization_period=0.0)
    hpa = HPA(cfg)
    pods = [ready_pod(f"p{i}", now=-1000.0) for i in range(4)]
    hot = {p.name: MetricSample(90.0, timestamp=0.0) for p in pods}
    assert hpa.evaluate(pods, hot, now=0.0) == 8
    # load drops: scale-down is held while the 8-recommendation from t=0 is
    # still inside the 300s window...
    pods8 = [ready_pod(f"p{i}", now=-1000.0) for i in range(8)]
    cold = {p.name: MetricSample(10.0, timestamp=200.0) for p in pods8}
    held = hpa.evaluate(pods8, cold, now=200.0)
    assert held == 8        # max recommendation in window still 8
    # ...and released once that recommendation ages out of the window
    later = {p.name: MetricSample(10.0, timestamp=700.0) for p in pods8}
    assert hpa.evaluate(pods8, later, now=700.0) < 8


def test_hpa_tolerance_deadband():
    cfg = HPAConfig(target=50.0, tolerance=0.1,
                    cpu_initialization_period=0.0)
    hpa = HPA(cfg)
    pods = [ready_pod(f"p{i}", now=-100.0) for i in range(4)]
    near = {p.name: MetricSample(52.0, timestamp=0.0) for p in pods}
    assert hpa.evaluate(pods, near, now=0.0) == 4   # within 10% deadband


@settings(max_examples=50, deadline=None)
@given(metric=st.floats(1.0, 500.0), n=st.integers(1, 12))
def test_hpa_bounds_property(metric, n):
    cfg = HPAConfig(target=50.0, min_replicas=2, max_replicas=6,
                    cpu_initialization_period=0.0)
    hpa = HPA(cfg)
    pods = [ready_pod(f"p{i}", now=-100.0) for i in range(n)]
    samples = {p.name: MetricSample(metric, timestamp=0.0) for p in pods}
    d = hpa.evaluate(pods, samples, now=0.0)
    assert 2 <= d <= 6 or d == n
