"""Sharding resolver + ZeRO spec rules + sharded-vs-unsharded equivalence."""
import os
import subprocess
import sys

SUB_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
# without this, jax spends minutes probing for accelerator platforms in
# the stripped subprocess environment
if "JAX_PLATFORMS" in os.environ:
    SUB_ENV["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.models import model_api as MA
from repro.optim.adamw import zero1_spec
from repro.sharding.api import DEFAULT_RULES, ShardCtx


class FakeMesh:
    """Shape-only mesh stand-in so resolver tests don't need 256 devices."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def ctx16():
    c = ShardCtx.__new__(ShardCtx)
    c.mesh = FakeMesh({"data": 16, "model": 16})
    c.rules = dict(DEFAULT_RULES)
    return c


def test_divisible_dims_get_model_axis():
    c = ctx16()
    assert c.spec(("vocab", None), (152064, 3584)) == P("model")
    assert c.spec((None, None, "ffn"), (28, 3584, 18944)) == \
        P(None, None, "model")


def test_non_divisible_heads_fall_back_to_replicated():
    c = ctx16()
    # 28 heads % 16 != 0 -> None
    # (singleton-tuple spelling P(("data",)) only compares equal to this on
    # newer jax; the bare form means the same sharding on every version)
    assert c.spec(("batch", None, "heads", None), (256, 4096, 28, 128)) == \
        P("data")
    # 32 heads divides -> sharded
    sp = c.spec(("batch", None, "heads", None), (256, 4096, 32, 128))
    assert sp == P("data", None, "model")


def test_axis_used_once_per_spec():
    c = ctx16()
    # expert takes model; ffn cannot reuse it
    sp = c.spec((None, "expert", None, "ffn"), (28, 64, 2048, 2816))
    assert sp == P(None, "model")


def test_cache_seq_joint_sharding_for_batch1():
    c = ctx16()
    # batch=1 unshardable; cache_seq grabs data+model jointly (256-way)
    sp = c.spec((None, "batch", "cache_seq"), (48, 1, 524288))
    assert sp == P(None, None, ("data", "model"))
    # batch=128 takes data; cache_seq falls back to model only
    sp = c.spec((None, "batch", "cache_seq"), (48, 128, 32768))
    assert sp == P(None, ("data",), ("model",)) or \
        sp == P(None, "data", "model")


def test_multipod_batch_takes_pod_and_data():
    c = ShardCtx.__new__(ShardCtx)
    c.mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    c.rules = dict(DEFAULT_RULES)
    assert c.spec(("batch", None), (256, 4096)) == P(("pod", "data"))


def test_zero1_spec_insertion():
    mesh = FakeMesh({"data": 16, "model": 16})
    # param sharded on last dim by model; zero1 adds data on a free dim
    sp = zero1_spec(P(None, None, "model"), (28, 3584, 18944), mesh)
    assert sp == P(None, "data", "model")
    # data already used -> unchanged
    sp2 = zero1_spec(P("data", "model"), (256, 4096), mesh)
    assert sp2 == P("data", "model")
    # nothing divisible -> unchanged
    sp3 = zero1_spec(P(), (7, 9), mesh)
    assert sp3 == P()


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded():
    """4-device subprocess: one train step on mesh (2,2) must match the
    single-device result (same loss)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_cell
from repro.models import model_api as MA
from repro.optim import adamw

cfg = get_config("qwen2-7b").reduced()
shape = ShapeConfig("t", "train", 32, 4)
mod = MA.get_module(cfg)
params = mod.init(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)
k = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(k, (4, 32), 0, cfg.vocab),
         "labels": jax.random.randint(k, (4, 32), 0, cfg.vocab),
         "mask": jnp.ones((4, 32), jnp.float32)}

cell0 = make_train_cell(cfg, shape, None, microbatches=1)
p0, o0, m0 = cell0.fn(params, opt, batch)

mesh = make_mesh((2, 2), ("data", "model"))
cell = make_train_cell(cfg, shape, mesh, microbatches=1)
ps = jax.tree.map(jax.device_put, params, cell.in_shardings[0])
os_ = jax.tree.map(jax.device_put, opt, cell.in_shardings[1])
bs = {kk: jax.device_put(v, s) for (kk, v), s in
      zip(batch.items(), [cell.in_shardings[2][kk] for kk in batch])}
p1, o1, m1 = cell.jit()(ps, os_, bs)
d = abs(float(m0["loss"]) - float(m1["loss"]))
print("LOSS_DELTA", d)
assert d < 1e-3, d
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=dict(SUB_ENV), cwd="/root/repo",
                       timeout=420)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_one_cell_compiles_on_512_devices():
    """The dry-run entrypoint itself (512 fake devices, production mesh)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "hymba-1.5b",
         "--shape", "long_500k", "--mesh", "pod", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True,
        env=dict(SUB_ENV), cwd="/root/repo", timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout
