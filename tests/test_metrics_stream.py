"""Monitoring stack (§4.6) + elastic serving + streaming engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.elastic import ElasticServing
from repro.core.jrm import SliceSpec, start_vk
from repro.core.metrics import (Endpoint, Prometheus, Registry, Service,
                                ServiceMonitor)
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine


# ----------------------------------------------------------------- metrics

def test_registry_and_scrape():
    reg = Registry()
    reg.counter("served").inc(5)
    reg.gauge("queue").set(7)
    reg.histogram("lat").observe(0.3)
    svc = Service("s", selector={"app": "x"}, labels={"monitored": "true"})
    svc.add_endpoint(Endpoint("pod-0", "172.17.0.1", 2221, 20000, reg))
    prom = Prometheus(monitors=[ServiceMonitor("m", {"monitored": "true"})],
                      services=[svc])
    n = prom.scrape(now=1.0)
    assert n >= 3
    assert prom.query_latest("served")["pod-0"] == 5
    prom.scrape(now=2.0)
    assert len(prom.query_range("queue", "pod-0")) == 2


def test_same_pod_ip_requires_port_remap():
    """§4.6.3: identical pod IPs + identical CP ports must be rejected."""
    svc = Service("s", selector={})
    svc.add_endpoint(Endpoint("a", "172.17.0.1", 2221, 20000, Registry()))
    with pytest.raises(ValueError):
        svc.add_endpoint(Endpoint("b", "172.17.0.1", 2221, 20000, Registry()))
    # remapped CP port is fine even with the same pod IP
    svc.add_endpoint(Endpoint("b", "172.17.0.1", 2221, 20001, Registry()))
    assert len(svc.endpoints) == 2


def test_service_label_selection():
    svc = Service("s", selector={"app": "ersap"})
    assert svc.selects({"app": "ersap", "x": "y"})
    assert not svc.selects({"app": "other"})


# ------------------------------------------------------------------ elastic

def test_elastic_scale_preserves_outputs():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    s = ElasticServing(cfg, tp=1)
    s.build(1, host_params=host)
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)).astype(np.int32)
    l1, _ = s.prefill_fn(s.params, toks)
    s.scale_to(1)           # no-op
    assert s.replicas == 1
    s2 = s.build(s.max_replicas())
    l2, _ = s.prefill_fn(s.params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)
    assert len(s.scale_events) >= 1


# ------------------------------------------------------------------ engine

def test_stream_engine_serves_and_scales():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    nodes = [start_vk(f"n{i}", now=0.0, slice_spec=SliceSpec(chips=4))
             for i in range(2)]
    eng = StreamEngine(cfg, serving, nodes, service_rate=2.0, max_batch=4)
    eng.deploy(0.0)
    assert len(eng.pods) == 1
    total_q = 0
    for t in range(6):
        q = eng.tick(t * 5.0, 5.0, lam=2.0)
        total_q += q
    served = sum(st.served for st in eng.stats.values())
    assert served > 0
    assert eng.completed
    # metrics flowed through the Prometheus stack
    assert eng.prom.query_latest("ersap_served_total")
    # control loop runs and keeps replica count within bounds
    desired = eng.control_step(30.0)
    assert 1 <= desired <= serving.max_replicas()


def test_engine_real_model_tokens():
    """The engine runs actual prefill+decode: token counts add up."""
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    nodes = [start_vk("n0", now=0.0, slice_spec=SliceSpec(chips=4))]
    eng = StreamEngine(cfg, serving, nodes, service_rate=1.0, max_batch=2)
    eng.deploy(0.0)
    eng.queue.extend(eng.source.arrivals(0.0, 1.0, lam=3.0))
    eng.tick(1.0, 2.0, lam=0.0)
    st = eng.stats["ersap-0"]
    assert st.tokens == st.served * 16     # max_new defaults to 16
