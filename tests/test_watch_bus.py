"""Watch-bus delivery semantics (ISSUE-8 satellite).

The event-driven control plane leans on three guarantees from
``Cluster._emit``: (1) subscribers for a kind are invoked in
registration order for every event; (2) re-entrant writes from inside a
callback are *queued*, not dispatched recursively, so every subscriber
sees every delta exactly once and in emission order (breadth-first);
(3) unsubscribing — anyone, including yourself, including mid-dispatch —
is safe and takes effect immediately: an unsubscribed callback receives
nothing more, not even the event currently being fanned out.
"""
from repro.core.cluster import (ADDED, DELETED, KIND_NODE, KIND_POD,
                                MODIFIED, Cluster)
from repro.core.jrm import SliceSpec, start_vk
from repro.core.state_machine import Container, Pod

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name, chips=1):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips)


def mkcluster(n=1, chips=4):
    cluster = Cluster()
    for i in range(n):
        cluster.register_node(
            start_vk(f"n{i}", slice_spec=SliceSpec(chips=chips)), 0.0)
        cluster.heartbeat(f"n{i}", 0.0)
    return cluster


def test_subscribers_fire_in_registration_order_per_event():
    cluster = mkcluster(0)
    log = []
    cluster.watch(KIND_POD, lambda ev: log.append(("a", ev.name, ev.type)))
    cluster.watch(KIND_POD, lambda ev: log.append(("b", ev.name, ev.type)))
    cluster.submit(mkpod("p0"), 0.0)
    cluster.submit(mkpod("p1"), 0.0)
    assert log == [("a", "p0", ADDED), ("b", "p0", ADDED),
                   ("a", "p1", ADDED), ("b", "p1", ADDED)]


def test_reentrant_write_queues_no_lost_or_duplicated_deltas():
    """A subscriber that writes to the store mid-dispatch must not make
    any other subscriber miss or double-see a delta: the nested emit is
    queued and fanned out breadth-first after the current event."""
    cluster = mkcluster(0)
    seen_a, seen_b, order = [], [], []

    def sub_a(ev):
        seen_a.append((ev.name, ev.type))
        order.append(("a", ev.name))
        if ev.name == "p0" and ev.type == ADDED:
            # re-entrant store write from inside the fan-out
            cluster.submit(mkpod("p1"), 0.0)

    def sub_b(ev):
        seen_b.append((ev.name, ev.type))
        order.append(("b", ev.name))

    cluster.watch(KIND_POD, sub_a)
    cluster.watch(KIND_POD, sub_b)
    cluster.submit(mkpod("p0"), 0.0)

    # exactly once each, in emission order, for both subscribers
    assert seen_a == [("p0", ADDED), ("p1", ADDED)]
    assert seen_b == [("p0", ADDED), ("p1", ADDED)]
    # breadth-first: everyone finishes p0 before anyone starts p1
    assert order == [("a", "p0"), ("b", "p0"), ("a", "p1"), ("b", "p1")]


def test_unsubscribe_during_dispatch_is_immediate_and_safe():
    """A pulls B's subscription while an event is in flight: B must not
    receive that event (delivery had not reached it yet) nor any later
    one — and the dispatch loop must not blow up on the mutation."""
    cluster = mkcluster(0)
    seen_b = []
    unsub_b = []

    def sub_a(ev):
        if unsub_b:
            unsub_b.pop()()

    cluster.watch(KIND_POD, sub_a)
    unsub_b.append(cluster.watch(KIND_POD, seen_b.append))
    cluster.submit(mkpod("p0"), 0.0)     # A unsubscribes B mid-fan-out
    cluster.submit(mkpod("p1"), 0.0)
    assert seen_b == []


def test_self_unsubscribe_receives_exactly_one_event():
    cluster = mkcluster(0)
    seen = []
    handle = []

    def one_shot(ev):
        seen.append(ev.name)
        handle.pop()()

    handle.append(cluster.watch(KIND_POD, one_shot))
    cluster.submit(mkpod("p0"), 0.0)
    cluster.submit(mkpod("p1"), 0.0)
    assert seen == ["p0"]


def test_unsubscribe_is_idempotent():
    cluster = mkcluster(0)
    seen = []
    unsub = cluster.watch(KIND_POD, seen.append)
    unsub()
    unsub()                               # second call is a no-op
    cluster.submit(mkpod("p0"), 0.0)
    assert seen == []


def test_heartbeat_reason_deltas_and_ready_transition():
    cluster = Cluster()
    cluster.register_node(start_vk("n0", slice_spec=SliceSpec(chips=2)), 0.0)
    seen = []
    cluster.watch(KIND_NODE, lambda ev: seen.append((ev.type, ev.reason)))
    cluster.heartbeat("n0", 1.0)
    # steady-state heartbeats are heartbeat-reason only: subscribers rely
    # on this to skip them in O(1) without invalidating capacity indices
    assert seen == [(MODIFIED, "heartbeat")]
    seen.clear()
    # a readiness flip through the JFM feed path is a "status" delta
    cluster.set_node_status("n0", 2.0, ready=False)
    assert seen == [(MODIFIED, "status")]
    seen.clear()
    # a straggler flip regroups the capacity index: also "status"
    cluster.set_node_status("n0", 3.0, ready=False, straggler=True)
    assert seen == [(MODIFIED, "status")]


def test_delta_counters_track_emissions_and_deliveries():
    cluster = mkcluster(0)
    base_emitted = cluster.deltas_emitted
    cluster.watch(KIND_POD, lambda ev: None)
    cluster.watch(KIND_POD, lambda ev: None)
    before = cluster.deltas_dispatched
    cluster.submit(mkpod("p0"), 0.0)
    assert cluster.deltas_emitted == base_emitted + 1
    per_event = cluster.deltas_dispatched - before
    # at least the two test watchers (internal subscribers like the
    # quota ledger ride the same bus and count too)
    assert per_event >= 2
    cluster.submit(mkpod("p1"), 0.0)
    # one emission -> exactly one delivery per live subscriber, stable
    # across events
    assert cluster.deltas_emitted == base_emitted + 2
    assert cluster.deltas_dispatched == before + 2 * per_event


def test_bind_and_delete_reasons_flow_through_the_bus():
    cluster = mkcluster(1)
    seen = []
    cluster.watch(KIND_POD, lambda ev: seen.append((ev.type, ev.reason)))
    cluster.submit(mkpod("p"), 0.0)
    cluster.assign("p", "n0", 0.0)
    cluster.evict("p", 1.0)
    assert seen[0] == (ADDED, "")
    assert (MODIFIED, "bind") in seen
    assert seen[-1][0] == DELETED
