"""Unified observability plane: lifecycle tracing, Prometheus-style
exposition, tick profiler, SLO flight recorder, and the metrics.py
edge cases the plane leans on.

Acceptance capstone: one rid's full span chain — enqueue -> admit ->
decode -> drain -> restore -> retire, across fault incarnations —
reconstructs from a flight-recorder dump via ``tools/tracedump.py``.
"""
import json
import math
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.elastic import ElasticServing
from repro.core.jrm import SliceSpec, start_vk
from repro.core.metrics import (COUNT_BUCKETS, Endpoint, Histogram,
                                Registry, Service, split_series)
from repro.core.observability import (FlightRecorder, SLOConfig,
                                      TickProfiler, parse_exposition,
                                      render_exposition)
from repro.core.tracing import NULL_TRACER, Tracer
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine
from repro.streaming.runtime import RuntimeConfig

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
import metriclint                                             # noqa: E402
import tracedump                                              # noqa: E402


# ------------------------------------------------- metrics.py edge cases

def test_histogram_exact_boundary_lands_in_its_bucket():
    h = Histogram()                       # (0.005, 0.05, 0.5, ...)
    h.observe(0.05)                       # bisect_left: le=0.05 bucket
    assert h.counts[1] == 1 and sum(h.counts) == 1
    h.observe(0.005)
    assert h.counts[0] == 1
    h.observe(1e9)                        # +Inf bucket
    assert h.counts[-1] == 1


def test_histogram_quantile_empty_single_and_inf_mass():
    h = Histogram(buckets=(1.0, 2.0, math.inf))
    assert h.quantile(0.5) == 0.0         # empty -> 0.0
    h.observe(0.5)
    q = h.quantile(0.99)                  # single sample: inside (0, 1]
    assert 0.0 <= q <= 1.0
    h2 = Histogram(buckets=(1.0, math.inf))
    h2.observe(50.0)                      # all mass beyond the ladder
    assert h2.quantile(0.99) == 1.0       # largest finite bound
    h3 = Histogram(buckets=(1.0, 2.0, 4.0, math.inf))
    for v in (0.5, 1.5, 1.6, 3.0, 3.5):
        h3.observe(v)
    qs = [h3.quantile(q) for q in (0.1, 0.5, 0.9, 1.0)]
    assert qs == sorted(qs)               # monotone in q
    assert qs[-1] <= 4.0


def test_registry_labeled_series_are_distinct_and_stable():
    reg = Registry()
    reg.counter("ersap_shed_total", {"reason": "deadline"}).inc(3)
    reg.counter("ersap_shed_total", {"reason": "brownout"}).inc()
    reg.counter("ersap_shed_total", {"reason": "deadline"}).inc()
    assert reg.counter("ersap_shed_total",
                       {"reason": "deadline"}).value == 4
    # unlabeled API unchanged
    reg.gauge("ersap_queue_len").set(7)
    assert reg.metrics["ersap_queue_len"].value == 7
    base, lbl = split_series('ersap_shed_total{reason="deadline"}')
    assert base == "ersap_shed_total" and lbl == '{reason="deadline"}'
    # labeled histogram flattens with the label block preserved
    reg.histogram("ersap_queue_wait_s", {"tier": "lc"}).observe(0.2)
    flat = reg.collect()
    assert flat['ersap_queue_wait_s_sum{tier="lc"}'] == pytest.approx(0.2)
    assert flat['ersap_queue_wait_s_count{tier="lc"}'] == 1


def test_service_same_pod_ip_requires_unique_cp_ports():
    """§4.6.3: VK pods share VKUBELET_POD_IP, so endpoints must remap
    exporter ports to unique control-plane ports."""
    svc = Service("obs", selector={"app": "ersap"})
    svc.add_endpoint(Endpoint("p0", "10.0.0.1", 2221, 9100, Registry()))
    svc.add_endpoint(Endpoint("p1", "10.0.0.1", 2221, 9101, Registry()))
    with pytest.raises(ValueError):
        svc.add_endpoint(Endpoint("p2", "10.0.0.1", 2221, 9100,
                                  Registry()))
    assert len(svc.endpoints) == 2


# ------------------------------------------------------------ exposition

def test_exposition_renders_and_parses_back():
    reg = Registry()
    reg.counter("ersap_served_total").inc(5)
    reg.gauge("ersap_queue_len").set(3)
    h = reg.histogram("ersap_latency_s", buckets=(0.1, 1.0, math.inf))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(2.0)
    text = render_exposition({"pod-a": reg})
    assert "# TYPE ersap_latency_s histogram" in text
    assert "# TYPE ersap_served_total counter" in text
    flat = parse_exposition(text)
    assert flat['ersap_served_total{pod="pod-a"}'] == 5
    # bucket series are cumulative and end at +Inf == _count
    assert flat['ersap_latency_s_bucket{pod="pod-a",le="0.1"}'] == 1
    assert flat['ersap_latency_s_bucket{pod="pod-a",le="1"}'] == 2
    assert flat['ersap_latency_s_bucket{pod="pod-a",le="+Inf"}'] == 3
    assert flat['ersap_latency_s_count{pod="pod-a"}'] == 3
    assert flat['ersap_latency_s_sum{pod="pod-a"}'] == \
        pytest.approx(2.55)
    # the standalone metriclint parser agrees (no repro imports there)
    tmp = pathlib.Path(str(ROOT)) / "bench_check"
    tmp.mkdir(exist_ok=True)
    f = tmp / "_test_expo.prom"
    f.write_text(text)
    try:
        assert metriclint.parse_exposition_file(str(f)) == flat
    finally:
        f.unlink()


def test_exposition_parser_rejects_malformed_lines():
    with pytest.raises(ValueError):
        parse_exposition("ersap_x{unclosed 1")
    with pytest.raises(ValueError):
        parse_exposition("ersap_x notafloat")
    with pytest.raises(ValueError):
        parse_exposition("just-one-token")
    assert parse_exposition("# comment\n\n") == {}


# ---------------------------------------------------------------- tracer

def test_tracer_ring_bound_and_chain_order():
    tr = Tracer(cap=8)
    for i in range(12):
        tr.span("decode", float(i), rid=1, step=i)
    assert len(tr.spans) == 8 and tr.dropped == 4
    chain = tr.chain(1)
    assert [s.attrs["step"] for s in chain] == list(range(4, 12))
    assert [s.seq for s in chain] == sorted(s.seq for s in chain)


def test_tracer_incarnation_bumps_on_restore_and_block_spans_match():
    tr = Tracer()
    tr.span("enqueue", 0.0, rid=7)
    tr.span("prefill", 1.0, rids=(7, 9))      # block span, rid=0
    tr.span("restore", 2.0, rid=7)
    tr.span("decode", 3.0, rid=7)
    incs = [s.inc for s in tr.chain(7)]
    assert incs == [0, 0, 1, 1]               # restore itself is inc=1
    assert tr.rids() == [7, 9]
    assert NULL_TRACER.span("x", 0.0) is None and not NULL_TRACER.spans
    d = tr.dump()
    assert d[1]["attrs"]["rids"] == [7, 9]    # JSON-safe (tuple -> list)


# -------------------------------------------------------------- profiler

def test_tick_profiler_accumulates_and_nests():
    p = TickProfiler()
    with p.phase("tick.schedule"):
        with p.phase("pump.admit"):
            pass
    with p.phase("tick.schedule"):
        pass
    s = p.summary()
    assert s["tick.schedule"]["calls"] == 2
    assert s["pump.admit"]["calls"] == 1
    assert s["tick.schedule"]["total_s"] >= 0.0
    assert s["tick.schedule"]["mean_us"] >= 0.0


# ------------------------------------------------------- flight recorder

def test_flight_recorder_trips_slo_and_writes_incident(tmp_path):
    tr = Tracer()
    tr.span("enqueue", 0.0, rid=1)
    fr = FlightRecorder(tr, slo=SLOConfig(lc_p99_s=1.0, min_samples=4,
                                          cooldown_s=60.0),
                        dump_dir=str(tmp_path))
    for i in range(8):
        fr.note_latency(float(i), 5.0, priority=100)   # way over SLO
        fr.note_served(float(i))
    assert fr.check(8.0) is not None
    assert fr.check(9.0) is None                       # cooldown holds
    assert fr.check(120.0) is not None                 # cooldown expired
    files = sorted(tmp_path.glob("incident_*.json"))
    assert len(files) == 2
    bundle = json.loads(files[0].read_text())
    assert bundle["reason"] == "lc-p99"
    assert bundle["spans"] and bundle["spans"][0]["rid"] == 1
    assert bundle["burn"]["lc_p99_s"] == pytest.approx(5.0)
    # full dump is JSON-safe and tracedump-readable
    dump = json.loads(json.dumps(fr.dump()))
    assert tracedump.all_rids(tracedump.spans_of(dump)) == [1]
    assert [i["reason"] for i in dump["incidents"]] == \
        ["lc-p99", "lc-p99"]


def test_flight_recorder_shed_and_restore_burn():
    fr = FlightRecorder(slo=SLOConfig(shed_frac=0.25, restore_s=10.0,
                                      min_samples=2, window_s=100.0))
    for i in range(6):
        fr.note_served(float(i))
        fr.note_latency(float(i), 0.1)
    b = fr.burn(6.0)
    assert b["shed_frac"] == 0.0
    for i in range(6):
        fr.note_shed(float(i))
    assert fr.check(6.0)["reason"] == "shed-fraction"
    fr2 = FlightRecorder(slo=SLOConfig(restore_s=10.0))
    fr2.note_restore(5.0, 30.0)
    assert fr2.check(5.0)["reason"] == "restore-latency"
    # sliding window forgets old samples
    assert fr2.burn(5000.0)["restore_max_s"] == 0.0


def test_invariant_auditor_trips_recorder_before_raising():
    from types import SimpleNamespace

    from repro.core.chaos import ChaosInvariantError, InvariantAuditor
    from repro.core.cluster import Cluster
    cluster = Cluster()
    cluster.register_node(start_vk("n0", now=0.0,
                                   slice_spec=SliceSpec(chips=2)), 0.0)
    fr = FlightRecorder()
    dup = SimpleNamespace(runtimes={}, completed=[(7, 0.0), (7, 1.0)],
                          queue=[], _node_reachable=lambda name: True)
    aud = InvariantAuditor(cluster, engine=dup, recorder=fr)
    with pytest.raises(ChaosInvariantError):
        aud.audit(1.0)
    assert fr.incidents and fr.incidents[0]["reason"] == "invariant"


# ------------------------------------------------------ tracedump helpers

def test_tracedump_subsequence_and_render():
    assert tracedump.has_subsequence(
        ["enqueue", "admit", "decode", "decode", "retire"],
        ["enqueue", "decode", "retire"])
    assert not tracedump.has_subsequence(
        ["admit", "enqueue"], ["enqueue", "admit"])
    bundle = {"spans": [
        {"name": "enqueue", "t": 0.0, "rid": 3, "seq": 1, "inc": 0,
         "attrs": {}},
        {"name": "decode", "t": 1.0, "rid": 0, "seq": 2, "inc": 0,
         "attrs": {"rids": [3], "steps": 16}},
        {"name": "retire", "t": 2.0, "rid": 3, "seq": 3, "inc": 0,
         "attrs": {"tokens": 16}},
    ]}
    assert tracedump.find_chain(bundle, ["enqueue", "decode", "retire"]) \
        == 3
    assert tracedump.find_chain(bundle, ["enqueue", "restore"]) is None
    out = tracedump.render(bundle)
    assert "rid 3" in out and "retire" in out


def test_metriclint_inventory_is_clean():
    """Every ersap_* metric named anywhere in src/ must be documented in
    docs/ARCHITECTURE.md — the same gate the obs-smoke CI job runs."""
    assert metriclint.main([]) == 0


# -------------------------------------- capstone: end-to-end span chain

def _mk_engine(n_nodes=2, chips=2):
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    nodes = [start_vk(f"n{i}", now=0.0, slice_spec=SliceSpec(chips=chips))
             for i in range(n_nodes)]
    return StreamEngine(cfg, serving, nodes, service_rate=100.0,
                        max_batch=4,
                        runtime_cfg=RuntimeConfig(max_batch=4,
                                                  admit_tail=0))


def test_request_chain_reconstructs_across_drain_restore(tmp_path):
    """Acceptance: a request is admitted, its node is drained mid-flight
    (checkpoint -> evict -> reschedule -> restore), and it finishes on
    the replacement replica. The flight-recorder dump reconstructs the
    whole life — enqueue, admit, decode, drain, restore, retire — for
    that one rid, across fault incarnations, via tools/tracedump.py."""
    eng = _mk_engine()
    tracer = Tracer()
    recorder = FlightRecorder(tracer, dump_dir=str(tmp_path / "inc"))
    eng.deploy(0.0)
    eng.enable_observability(tracer=tracer, recorder=recorder,
                             profiler=TickProfiler())
    eng.plane.nodes.ckpt_dir = str(tmp_path / "ckpt")
    eng.reconcile(0.0)
    assert eng.runtimes

    # one long request, hand-stamped the way RequestSource.arrivals does
    pod0 = next(iter(eng.runtimes))
    rt = eng.runtimes[pod0]
    tracer.span("enqueue", 0.0, rid=1, prompt_len=8, max_new=48)
    rt.sim_now = 0.0
    rt.submit([Request(1, 0.0, 8, 48, trace_id=1)], force=True)
    rt.step()                              # admit + one block: in flight
    assert any(s.busy for s in rt.slots)

    # drain the node under it; reconcile reschedules with restored state
    victim = eng.pods[pod0].node
    eng.plane.nodes._drain_node(victim, 1.0)
    eng.reconcile(1.0)
    assert any(p.node != victim for p in eng.pods.values())

    # replacement replica finishes the request
    for t in range(2, 8):
        eng.reconcile(float(t))
        eng.tick(float(t), 1.0, lam=0.0)
        if any(rid == 1 for rid, _ in eng.completed):
            break
    assert any(rid == 1 for rid, _ in eng.completed)

    out = tmp_path / "trace.json"
    out.write_text(json.dumps(recorder.dump()))
    bundle = json.loads(out.read_text())
    want = ["enqueue", "admit", "decode", "drain", "restore", "retire"]
    assert tracedump.find_chain(bundle, want) == 1
    # the same life is visible across fault incarnations: admits on both
    # sides of the restore carry different inc stamps
    names_incs = [(s["name"], s["inc"]) for s in
                  tracedump.rid_spans(tracedump.spans_of(bundle), 1)]
    admits = [inc for name, inc in names_incs if name == "admit"]
    assert 0 in admits and 1 in admits
    assert ("retire", 1) in names_incs
    # CLI gate used by the obs-smoke job
    assert tracedump.main([str(out), "--require-chain",
                           ",".join(want)]) == 0

    # the unified pipeline saw the request end to end
    flat = parse_exposition(eng.exposition())
    served = sum(v for k, v in flat.items()
                 if k.startswith("ersap_served_total"))
    assert served >= 1
    assert any(k.startswith("ersap_queue_wait_s_count") or
               k.startswith("ersap_ttft_s_count") for k in flat)


def test_engine_observability_is_opt_in_and_metrics_always_on():
    """Without enable_observability the engine runs span-free (the <5%%
    bench contrasts exactly this), while the unified registry still
    records shed/served counters for the compat properties."""
    src = RequestSource(seed=3)
    eng = _mk_engine()
    eng.deploy(0.0)
    eng.queue.extend(src.arrivals(0.0, 1.0, lam=6.0))
    eng.tick(0.0, 1.0, lam=0.0)
    assert eng.tracer is None and eng.recorder is None
    assert eng.total_served > 0
    assert isinstance(eng.shed_counts, dict)
    flat = parse_exposition(eng.exposition())
    served = sum(v for k, v in flat.items()
                 if k.startswith("ersap_served_total"))
    assert served == eng.total_served
    assert 'ersap_queue_len{pod="_engine"}' in flat   # engine registry
