"""Differential polling-vs-event-driven control-plane harness (ISSUE-8).

The tentpole claim: the event-driven plane (controllers reconcile only
dirty objects off watch deltas, the scheduler places through an
incrementally-maintained capacity index) is *observationally identical*
to the polling plane (every object visited every tick, full-scan
placement). ``ControlPlane(polling=True)`` keeps the old plane alive
behind a flag; each scenario script here runs under both modes and the
harness asserts three things are byte-identical:

  * the final **store** — every pod's node/phase/owner/priority/retry
    bookkeeping/binding epoch, every node's status and resident pod
    set, every Deployment's replica state, the fence epochs;
  * the **event trail** — the full (time, kind, name, reason) audit
    sequence (messages are excluded only because checkpoint paths
    embed per-run tempdirs);
  * the **pod token outputs** — BatchTenant progress counters and
    checkpoint round-trip evidence, the workload-visible effect.

Strict runs disable ``wake_on_freed`` on the event side: wake
intentionally binds parked pods *earlier* than polling's backoff timer
(that improvement is proven separately below, including the satellite
regression for quota-blocked pods parked at ``backoff_max``).

The property test at the bottom drives randomized op interleavings and
checks the scheduler's incremental indices against a from-scratch
recompute (``CapacityIndex.verify``) plus quota-ledger book balance.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.chaos import FaultInjector, InvariantAuditor
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.jrm import SliceSpec, start_vk
from repro.core.qos import BatchTenant, PriorityClass, Quota
from repro.core.state_machine import Container, Pod

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]
GB = 1024**3


def mkpod(name, chips=1):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips)


def add_node(cluster, name, now, *, chips=4, site="Local", walltime=0.0):
    cluster.register_node(
        start_vk(name, site=site, walltime=walltime, now=now,
                 slice_spec=SliceSpec(chips=chips)), now)
    cluster.heartbeat(name, now)


# ------------------------------------------------------------- snapshots

def store_snapshot(cluster):
    """Everything observable about the final store, order-insensitive
    where the store itself is a dict keyed by name."""
    pods = {n: (r.pod.node, r.pod.phase.name, r.owner, r.priority,
                r.preemptible, r.attempts, round(r.next_retry, 9),
                r.last_reason, r.binding_epoch, r.restored_from)
            for n, r in cluster.pods.items()}
    nodes = {n: (st.ready, st.schedulable, st.reachable, st.straggler,
                 tuple(sorted(cluster.nodes[n].pods))
                 if n in cluster.nodes else ())
             for n, st in cluster.node_status.items()}
    deps = {n: (d.replicas, d.next_ordinal, d.template.priority_class)
            for n, d in cluster.deployments.items()}
    return (pods, nodes, deps, cluster.binding_epoch,
            dict(cluster.fence_epochs))


def trail(cluster):
    """The audit sequence. Messages excluded: checkpoint events embed
    per-run tempdir paths; everything else must line up exactly."""
    return [(e.time, e.kind, e.name, e.reason) for e in cluster.events]


def run_mode(scenario, polling, tmp_path=None, wake=False):
    cluster = Cluster()
    plane = ControlPlane(cluster, polling=polling)
    if not polling and not wake:
        # strict identity: wake binds parked pods EARLIER by design;
        # it is asserted as an improvement in the wake tests below
        plane.scheduler.wake_on_freed = False
    if tmp_path is not None:
        mode = "wake" if wake else ("polling" if polling else "event")
        plane.nodes.ckpt_dir = str(tmp_path / mode)
    tokens = scenario(cluster, plane)
    return cluster, plane, tokens


def assert_identical(scenario, tmp_path=None):
    """The differential harness: polling vs event-driven over one
    scenario script -> identical stores, trails and token outputs."""
    c_poll, _, tok_poll = run_mode(scenario, polling=True,
                                   tmp_path=tmp_path)
    c_evt, plane_evt, tok_evt = run_mode(scenario, polling=False,
                                         tmp_path=tmp_path)
    assert store_snapshot(c_poll) == store_snapshot(c_evt)
    assert trail(c_poll) == trail(c_evt)
    assert tok_poll == tok_evt
    # the event side must actually have run on the index fast path, and
    # its incremental state must agree with a from-scratch recompute
    assert plane_evt.scheduler.use_index and \
        plane_evt.scheduler._index is not None
    plane_evt.scheduler._index.verify(1e9)
    return c_poll, c_evt


# ------------------------------------------------------------- scenarios
# Each scenario drives the full loop through (cluster, plane) only and
# returns the workload-visible token outputs.

def scenario_churn(cluster, plane):
    """Node churn: short-walltime nodes drain and expire mid-run,
    replacements register late, stragglers flip in and out."""
    for i in range(6):
        add_node(cluster, f"n{i}", 0.0, chips=2,
                 site="alpha" if i < 3 else "beta",
                 walltime=250.0 if i % 2 else 0.0)
    cluster.apply_priority_class(PriorityClass("batch", 10), 0.0)
    tenant = BatchTenant(cluster, replicas=8, now=0.0)
    for t in range(0, 601, 10):
        now = float(t)
        if t == 300:     # replacement capacity arrives
            add_node(cluster, "r0", now, chips=2, site="alpha")
            add_node(cluster, "r1", now, chips=2, site="beta")
        if t == 200:     # straggler flip regroups the index
            cluster.set_node_status("n0", now, ready=True, straggler=True)
        if t == 400:
            cluster.set_node_status("n0", now, ready=True, straggler=False)
        for n in list(cluster.nodes):
            cluster.heartbeat(n, now)
        plane.step(now)
        tenant.advance()
    assert tenant.mismatches == []
    return (dict(tenant.counters), sorted(tenant.resumed),
            tenant.total_progress)


def scenario_drain_site_kill(cluster, plane):
    """Operator kills a whole site mid-run; its pods checkpoint and
    re-serve on the surviving site."""
    for i in range(3):
        add_node(cluster, f"a{i}", 0.0, chips=4, site="alpha")
        add_node(cluster, f"b{i}", 0.0, chips=4, site="beta")
    cluster.apply_priority_class(PriorityClass("batch", 10), 0.0)
    tenant = BatchTenant(cluster, replicas=10, now=0.0)
    for t in range(0, 401, 10):
        now = float(t)
        if t == 100:
            plane.drain_site("beta", now)
        for n in list(cluster.nodes):
            cluster.heartbeat(n, now)
        plane.step(now)
        tenant.advance()
    live = cluster.pods_of("batch")
    assert live and all(r.pod.node is None or
                        cluster.nodes[r.pod.node].site == "alpha"
                        for r in live)
    assert tenant.mismatches == []
    return (dict(tenant.counters), sorted(tenant.resumed),
            tenant.total_progress)


def scenario_preemption_spike(cluster, plane):
    """Quota-capped batch tenant preempted by a latency-critical spike
    (scale + set_priority), then the spike recedes."""
    for i in range(4):
        add_node(cluster, f"n{i}", 0.0, chips=2)
    cluster.apply_priority_class(PriorityClass("batch", 10), 0.0)
    cluster.apply_priority_class(
        PriorityClass("critical", 100, preemptible=False), 0.0)
    cluster.apply_priority_class(PriorityClass("standard", 50), 0.0)
    cluster.apply_quota(Quota("batch", chips=6), 0.0)
    tenant = BatchTenant(cluster, replicas=8, now=0.0)
    cluster.apply_deployment(Deployment("web", 0, template=PodTemplate(
        labels={"app": "web"}, tolerations=list(TOL), request_chips=1,
        priority_class="standard")), 0.0)
    for t in range(0, 301, 10):
        now = float(t)
        if t == 50:      # the spike: scale up and escalate mid-flight
            cluster.scale("web", 5, now, source="hpa")
        if t == 80:
            cluster.set_priority("web", "critical", now, source="twin")
        if t == 180:     # spike recedes; batch reclaims its share
            cluster.scale("web", 1, now, source="hpa")
        for n in list(cluster.nodes):
            cluster.heartbeat(n, now)
        plane.step(now)
        tenant.advance()
    assert tenant.mismatches == []
    return (dict(tenant.counters), sorted(tenant.resumed),
            tenant.total_progress,
            {n: (r.pod.node, r.priority) for n, r in cluster.pods.items()
             if r.owner == "web"})


def scenario_fault_storm(cluster, plane):
    """The PR-7 chaos storm: seeded crash/partition/flap/walltime-cut
    schedule through the public seams, invariant-audited every tick."""
    for i in range(5):
        add_node(cluster, f"n{i}", 0.0, chips=2,
                 site="alpha" if i < 3 else "beta")
    cluster.apply_priority_class(PriorityClass("batch", 10), 0.0)
    tenant = BatchTenant(cluster, replicas=6, now=0.0)
    inj = FaultInjector(["crash:*@40", "partition:*@80+60",
                         "flap:*@120+30", "walltime_cut:n1@200x50"],
                        seed=11)
    auditor = InvariantAuditor(cluster)
    for t in range(0, 401, 10):
        now = float(t)
        inj.apply(cluster, now)
        for n in list(cluster.nodes):
            cluster.heartbeat(n, now)
        plane.step(now)
        tenant.advance()
        auditor.audit(now)
    # a crash loses un-checkpointed progress by design — what matters
    # here is that both planes lose EXACTLY the same progress, so the
    # mismatch evidence is part of the compared token output
    return (dict(tenant.counters), sorted(tenant.resumed),
            tenant.total_progress, list(tenant.mismatches),
            list(inj.log))


def test_differential_churn():
    assert_identical(scenario_churn)


def test_differential_drain_site_kill(tmp_path):
    assert_identical(scenario_drain_site_kill, tmp_path)


def test_differential_preemption_spike(tmp_path):
    assert_identical(scenario_preemption_spike, tmp_path)


def test_differential_fault_storm(tmp_path):
    assert_identical(scenario_fault_storm, tmp_path)


def test_wake_mode_reaches_same_outcomes():
    """wake_on_freed changes *when* parked pods retry, never *where*
    they land: every scenario still converges to a fully-bound tenant
    with balanced books and a verified index."""
    for scenario in (scenario_churn, scenario_preemption_spike):
        cluster, plane, _ = run_mode(scenario, polling=False, wake=True)
        plane.scheduler._index.verify(1e9)
        cluster.ledger.assert_balanced()


# ----------------------------------------- wake-on-freed (satellite 4)

def park(sched, cluster, now, rounds=8):
    """Drive a pending pod to its max-backoff parking orbit."""
    for i in range(rounds):
        sched.run_once(now + float(i))


def test_quota_release_wakes_parked_pod_same_tick():
    """Regression: a quota-blocked pod parks at backoff_max (waiting
    cannot free a fair-share cap) — but a quota *raise* must re-arm it
    on the very next pass, not after the parked timer runs out."""
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=4)
    cluster.apply_quota(Quota("t", chips=1), 0.0)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p0"), 0.0, owner="t")
    cluster.submit(mkpod("p1"), 0.0, owner="t")
    plane.scheduler.run_once(0.0)
    rec = cluster.pods["p1"]
    assert cluster.pods["p0"].bound and not rec.bound
    assert rec.next_retry >= plane.scheduler.backoff_max
    cluster.apply_quota(Quota("t", chips=4), 1.0)     # the release
    plane.scheduler.run_once(1.0)
    assert rec.bound, "quota-released delta must re-arm the parked pod"


def test_quota_release_stays_parked_without_wake():
    """The pre-fix behavior, kept honest behind the polling flag: with
    wake disabled the same pod sleeps out its full backoff_max."""
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=4)
    cluster.apply_quota(Quota("t", chips=1), 0.0)
    plane = ControlPlane(cluster, polling=True)
    cluster.submit(mkpod("p0"), 0.0, owner="t")
    cluster.submit(mkpod("p1"), 0.0, owner="t")
    plane.scheduler.run_once(0.0)
    cluster.apply_quota(Quota("t", chips=4), 1.0)
    plane.scheduler.run_once(1.0)
    assert not cluster.pods["p1"].bound          # still parked...
    plane.scheduler.run_once(cluster.pods["p1"].next_retry)
    assert cluster.pods["p1"].bound              # ...until the timer


def test_consumer_exit_wakes_quota_blocked_sibling():
    """Freeing share by a sibling's exit is a quota release too: the
    bound consumer's DELETED delta wakes pods of the same owner."""
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=4)
    cluster.apply_quota(Quota("t", chips=1), 0.0)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p0"), 0.0, owner="t")
    cluster.submit(mkpod("p1"), 0.0, owner="t")
    plane.scheduler.run_once(0.0)
    assert not cluster.pods["p1"].bound
    cluster.evict("p0", 2.0)                     # consumer exits
    plane.scheduler.run_once(2.0)
    assert cluster.pods["p1"].bound


def test_capacity_freed_wakes_backoff_parked_pod():
    """A no-fit pod in exponential backoff retries immediately when a
    bound pod's eviction frees chips, instead of waiting out its
    jittered timer."""
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=1)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p0"), 0.0)
    cluster.submit(mkpod("p1"), 0.0)
    park(plane.scheduler, cluster, 0.0)
    rec = cluster.pods["p1"]
    assert cluster.pods["p0"].bound and not rec.bound
    assert rec.next_retry > 10.0
    cluster.evict("p0", 8.0)                     # capacity freed
    plane.scheduler.run_once(8.0)
    assert rec.bound


def test_heartbeats_never_wake_parked_pods():
    """The bulk of bus traffic at scale is heartbeats; they carry no
    capacity information and must not re-arm anything."""
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=1)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p0"), 0.0)
    cluster.submit(mkpod("p1"), 0.0)
    plane.scheduler.run_once(0.0)
    rec = cluster.pods["p1"]
    attempts = rec.attempts
    cluster.heartbeat("n0", 1.0)
    plane.scheduler.run_once(1.0)
    assert not rec.bound and rec.attempts == attempts


def test_node_added_wakes_parked_pods():
    cluster = Cluster()
    add_node(cluster, "n0", 0.0, chips=1)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p0"), 0.0)
    cluster.submit(mkpod("p1"), 0.0)
    park(plane.scheduler, cluster, 0.0)
    rec = cluster.pods["p1"]
    assert not rec.bound and rec.next_retry > 10.0
    add_node(cluster, "n1", 9.0, chips=1)        # fresh capacity
    plane.scheduler.run_once(9.0)
    assert rec.bound


def test_event_budget_carries_remainder_across_ticks():
    """``event_budget`` caps dirty objects reconciled per controller per
    tick; the excess stays dirty and lands next tick — bounded tick
    latency without dropped work."""
    cluster = Cluster()
    for i in range(2):
        add_node(cluster, f"n{i}", 0.0, chips=4)
    plane = ControlPlane(cluster, event_budget=1)
    for name in ("a", "b"):
        cluster.apply_deployment(Deployment(name, 2, template=PodTemplate(
            labels={"app": name}, tolerations=list(TOL),
            request_chips=1)), 0.0)
    plane.step(0.0)
    made = {r.owner for r in cluster.pods.values()}
    assert made == {"a"}, "budget 1: only the first dirty Deployment runs"
    plane.step(1.0)
    made = {r.owner for r in cluster.pods.values()}
    assert made == {"a", "b"}, "the remainder must carry, not drop"
    assert all(r.bound for r in cluster.pods.values())


# --------------------------------------- property test (satellite 2)

OWNERS = ("alpha", "beta", "gamma")


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_incremental_indices_match_recompute(data):
    """Randomized bind/evict/scale/heartbeat/cut_walltime/status
    interleavings: after every burst the scheduler's incremental
    capacity index must equal a from-scratch recompute and the quota
    ledger's books must balance against node-side truth."""
    cluster = Cluster()
    plane = ControlPlane(cluster)
    cluster.apply_quota(Quota("alpha", chips=5), 0.0)
    n_nodes, n_pods = 0, 0
    now = 0.0
    for _ in range(3):
        add_node(cluster, f"n{n_nodes}", now,
                 chips=data.draw(st.integers(1, 4)))
        n_nodes += 1
    n_ops = data.draw(st.integers(15, 30))
    for _ in range(n_ops):
        now += data.draw(st.floats(0.5, 15.0))
        op = data.draw(st.sampled_from(
            ("register", "deregister", "submit", "step", "evict",
             "heartbeat", "cut_walltime", "status")))
        names = list(cluster.nodes)
        if op == "register":
            add_node(cluster, f"n{n_nodes}", now,
                     chips=data.draw(st.integers(1, 4)),
                     walltime=data.draw(st.sampled_from((0.0, 120.0))))
            n_nodes += 1
        elif op == "deregister" and len(names) > 1:
            cluster.deregister_node(
                data.draw(st.sampled_from(names)), now)
        elif op == "submit":
            cluster.submit(
                mkpod(f"p{n_pods}", chips=data.draw(st.integers(1, 2))),
                now, owner=data.draw(st.sampled_from(OWNERS)),
                priority=data.draw(st.integers(0, 2)))
            n_pods += 1
        elif op == "step":
            plane.step(now)
        elif op == "evict" and cluster.pods:
            name = data.draw(st.sampled_from(sorted(cluster.pods)))
            cluster.evict(name, now)
        elif op == "heartbeat" and names:
            cluster.heartbeat(data.draw(st.sampled_from(names)), now)
        elif op == "cut_walltime" and names:
            cluster.cut_walltime(data.draw(st.sampled_from(names)), now,
                                 data.draw(st.floats(0.0, 60.0)))
        elif op == "status" and names:
            cluster.set_node_status(
                data.draw(st.sampled_from(names)), now,
                ready=data.draw(st.booleans()),
                straggler=data.draw(st.booleans()))
        plane.scheduler._index.verify(now)
    plane.step(now + 1.0)
    plane.scheduler._index.verify(now + 1.0)
    cluster.ledger.assert_balanced()
