"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only dryrun subprocesses force 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
