"""End-to-end behaviour tests: training improves + survives failure with
bit-identical resume; serving pipeline processes the pressure trajectory
with twin-driven scaling; slurm asset generation."""
import pathlib
import subprocess
import sys

import pytest

import os

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
# without this, jax spends minutes probing for accelerator platforms in
# the stripped subprocess environment
if "JAX_PLATFORMS" in os.environ:
    ENV["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
CWD = "/root/repo"


def run(args, timeout=560):
    return subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                          text=True, env=ENV, cwd=CWD, timeout=timeout)


@pytest.mark.slow
def test_train_loss_improves(tmp_path):
    r = run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
             "--steps", "40", "--batch", "8", "--seq", "64",
             "--ckpt-dir", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "improved" in r.stdout and "NOT improved" not in r.stdout


@pytest.mark.slow
def test_crash_restart_resumes_identically(tmp_path):
    """Simulated node failure at step 25; restart resumes from step-20
    checkpoint and reaches the same final loss as an uninterrupted run."""
    base = run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
                "--steps", "40", "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path / "a"), "--ckpt-every", "10"])
    final_line = [l for l in base.stdout.splitlines() if l.startswith("step   39")]
    crash = run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
                 "--steps", "40", "--batch", "4", "--seq", "32",
                 "--ckpt-dir", str(tmp_path / "b"), "--ckpt-every", "10",
                 "--kill-at-step", "25"])
    assert "[failure] simulated node loss" in crash.stdout
    resume = run(["repro.launch.train", "--arch", "qwen2-7b", "--reduced",
                  "--steps", "40", "--batch", "4", "--seq", "32",
                  "--ckpt-dir", str(tmp_path / "b"), "--ckpt-every", "10"])
    assert "[restore] resumed from step 20" in resume.stdout
    resumed_line = [l for l in resume.stdout.splitlines()
                    if l.startswith("step   39")]
    assert final_line and resumed_line and final_line == resumed_line


@pytest.mark.slow
def test_walltime_drain_checkpoints_and_exits(tmp_path):
    """§4.5.4: inside the 60s drain margin the trainer checkpoints and
    exits for requeue instead of being killed mid-step."""
    r = run(["repro.launch.train", "--arch", "xlstm-1.3b", "--reduced",
             "--steps", "200", "--batch", "2", "--seq", "32",
             "--ckpt-dir", str(tmp_path), "--walltime", "90",
             "--step-seconds", "1.0"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[drain] checkpointed" in r.stdout
    from repro.checkpoint import checkpointer as ckpt
    assert ckpt.latest_step(tmp_path) is not None


@pytest.mark.slow
def test_serve_e2e_twin_scales(tmp_path):
    r = run(["repro.launch.serve", "--arch", "qwen2-7b", "--devices", "8",
             "--tp", "2", "--nodes", "4", "--ticks", "40",
             "--kernel-mode", "auto"], timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    # the kernel dispatch mode is resolved and logged before any tracing
    assert "[kernels] mode=auto (resolved " in r.stdout
    assert "[done] served=" in r.stdout
    # the twin escalated at least once under the pressure trajectory
    assert "scale events=[(0.0, 0, 1)" in r.stdout
    assert ", 1, 2)" in r.stdout


def test_slurm_asset_generation(tmp_path):
    from repro.launch.slurm import generate
    files = generate(tmp_path, nodes=40, walltime="03:00:00")
    assert set(files) == {"deploy-serving.sh", "nersc-slurm.sh",
                          "node-setup.sh"}
    slurm = (pathlib.Path(tmp_path) / "nersc-slurm.sh").read_text()
    assert "#SBATCH -N 40" in slurm and "sleep 3" in slurm
    node = (pathlib.Path(tmp_path) / "node-setup.sh").read_text()
    # §4.5.4: JIRIAF walltime = slurm walltime - 60s
    assert 'JIRIAF_WALLTIME="10740"' in node
    assert "ssh -NfL $APISERVER_PORT" in node
    assert "ssh -NfR $KUBELET_PORT" in node
