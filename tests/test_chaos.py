"""Chaos fault-injection subsystem + failure-hardened recovery paths.

Covers the ISSUE-7 tentpole end to end: deterministic seeded fault
schedules through control-plane seams (``repro.core.chaos``), checkpoint
integrity manifests with fall-back to the last good generation, bounded
retry-with-backoff on flaky I/O, epoch fencing of partitioned nodes,
two-phase drains that survive a mid-drain walltime cut, the flap window
(NotReady with fresh heartbeats is NOT an eviction), and the every-tick
``InvariantAuditor``. The capstone scenario partitions a serving node
mid-run and proves the re-served work is token-identical (prefix replay)
to a fault-free oracle with zero request loss and exactly-once
completion.
"""
import json

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_config
from repro.core.chaos import (ChaosInvariantError, FaultInjector, FaultSpec,
                              InvariantAuditor, corrupt_latest_generation)
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.elastic import ElasticServing
from repro.core.jrm import SliceSpec, start_vk
from repro.core.scheduler import Scheduler, _jitter_u
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine
from repro.streaming.runtime import RuntimeConfig

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name="p", chips=1):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips)


def mkcluster(n_nodes=3, chips=4, walltimes=None, now=0.0):
    cluster = Cluster()
    for i in range(n_nodes):
        wall = walltimes[i] if walltimes else 0.0
        cluster.register_node(
            start_vk(f"n{i}", walltime=wall, now=now,
                     slice_spec=SliceSpec(chips=chips)), now)
        cluster.heartbeat(f"n{i}", now)
    return cluster


# ------------------------------------------------------------ fault specs

def test_faultspec_parse_forms():
    s = FaultSpec.parse("partition:n0@120+45")
    assert (s.kind, s.target, s.at, s.duration) == \
        ("partition", "n0", 120.0, 45.0)
    s = FaultSpec.parse("straggler:*@60+30x8")
    assert (s.target, s.duration, s.magnitude) == ("*", 30.0, 8.0)
    s = FaultSpec.parse("walltime_cut:n2@100x70")
    assert s.magnitude == 70.0 and s.duration == 0.0
    assert FaultSpec.parse("crash@10").target == "*"   # bare kind
    with pytest.raises(ValueError):
        FaultSpec.parse("meteor:n0@5")                 # unknown kind
    with pytest.raises(ValueError):
        FaultSpec.parse("crash:n0")                    # missing @time


def test_injector_seeded_wildcard_is_deterministic():
    logs = []
    for _ in range(2):
        cluster = mkcluster(4)
        inj = FaultInjector(["crash:*@5", "flap:*@10+10"], seed=7)
        for t in (0.0, 5.0, 10.0, 15.0, 25.0):
            inj.apply(cluster, t)
        logs.append(list(inj.log))
        # the crashed node's heartbeat clock froze at the pre-crash tick
        victim = next(tgt for (_, kind, tgt) in inj.log if kind == "crash")
        assert cluster.nodes[victim].last_heartbeat == 0.0
    assert logs[0] == logs[1] and logs[0]


# ------------------------------------------------- checkpoint durability

def test_save_writes_integrity_manifest(tmp_path):
    tree = {"a": np.arange(6, dtype=np.int64),
            "b": np.ones((2, 3), np.float32)}
    checkpointer.save(tmp_path, 0, tree)
    meta = json.loads((tmp_path / "step_00000000" / "meta.json").read_text())
    assert len(meta["checksums"]) == 2
    assert meta["tree_keys"] == ["a", "b"]
    assert checkpointer.verify_step(tmp_path, 0)


def test_truncated_generation_falls_back_to_last_good(tmp_path):
    tree0 = {"served": np.asarray(7), "tokens": np.asarray(100)}
    tree1 = {"served": np.asarray(9), "tokens": np.asarray(140)}
    checkpointer.save(tmp_path, 0, tree0)
    checkpointer.save(tmp_path, 1, tree1)
    hit = corrupt_latest_generation(tmp_path)      # truncates on disk
    assert hit is not None and "step_00000001" in hit
    assert checkpointer.latest_step(tmp_path) == 1
    assert checkpointer.latest_good_step(tmp_path) == 0
    assert not checkpointer.verify_step(tmp_path, 1)
    # asking for the damaged generation explicitly is an integrity error
    with pytest.raises(checkpointer.CheckpointCorruptError):
        checkpointer.restore(tmp_path, tree1, step=1)
    # asking for "the latest" silently recovers from the last good one
    got, meta = checkpointer.restore(tmp_path, tree0)
    assert meta["step"] == 0 and int(got["served"]) == 7
    # crash path: rebuild from disk alone via the tree_keys manifest
    state, meta2 = checkpointer.load_tree(tmp_path)
    assert meta2["step"] == 0 and int(state["tokens"]) == 100


def test_bitflip_fails_leaf_checksum(tmp_path):
    checkpointer.save(tmp_path, 0, {"w": np.arange(32, dtype=np.int64)})
    npz = tmp_path / "step_00000000" / "leaves.npz"
    raw = bytearray(npz.read_bytes())
    raw[len(raw) // 2] ^= 0xFF                     # flip a payload byte
    npz.write_bytes(bytes(raw))
    assert not checkpointer.verify_step(tmp_path, 0)
    assert checkpointer.latest_good_step(tmp_path) is None
    with pytest.raises(FileNotFoundError):
        checkpointer.load_tree(tmp_path)           # no usable generation


def test_with_retry_bounded_backoff_and_timeout():
    calls = {"n": 0}
    naps = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("EIO")
        return "ok"

    assert checkpointer.with_retry(flaky, retries=3, backoff=0.01,
                                   sleep=naps.append) == "ok"
    assert calls["n"] == 3
    assert naps == pytest.approx([0.01, 0.02])     # exponential backoff

    def always():
        raise OSError("mount wedged")

    with pytest.raises(OSError):
        checkpointer.with_retry(always, retries=1, backoff=0.01,
                                sleep=naps.append)
    # a zero wall budget stops retrying even with attempts left
    calls["n"] = 0

    def count_and_fail():
        calls["n"] += 1
        raise OSError("down")

    with pytest.raises(OSError):
        checkpointer.with_retry(count_and_fail, retries=50, backoff=0.0,
                                timeout=0.0, sleep=lambda s: None)
    assert calls["n"] == 1


# ------------------------------------------------------- scheduler jitter

def test_backoff_jitter_is_deterministic_and_decorrelates():
    assert _jitter_u("a", 1) == _jitter_u("a", 1)
    assert _jitter_u("a", 1) != _jitter_u("b", 1)
    assert _jitter_u("a", 1) != _jitter_u("a", 2)
    for n in ("a", "b", "c"):
        assert 0.0 <= _jitter_u(n, 1) < 1.0


def test_scheduler_jitter_spreads_synchronized_retries():
    cluster = mkcluster(1, chips=1)
    sched = Scheduler(cluster, backoff_base=5.0, enable_preemption=False)
    cluster.submit(mkpod("hog", chips=1), 0.0)
    sched.run_once(0.0)
    ra = cluster.submit(mkpod("wa", chips=1), 0.0)
    rb = cluster.submit(mkpod("wb", chips=1), 0.0)
    sched.run_once(0.0)
    # same base backoff, same tick — the thundering herd is decorrelated
    assert ra.next_retry != rb.next_retry
    for rec in (ra, rb):
        assert 5.0 <= rec.next_retry <= 5.0 * (1 + sched.backoff_jitter)
    # jitter off: exact exponential base (the pre-PR behavior)
    cluster2 = mkcluster(1, chips=1)
    sched2 = Scheduler(cluster2, backoff_base=5.0, backoff_jitter=0.0,
                       enable_preemption=False)
    cluster2.submit(mkpod("hog", chips=1), 0.0)
    sched2.run_once(0.0)
    rc = cluster2.submit(mkpod("wc", chips=1), 0.0)
    sched2.run_once(0.0)
    assert rc.next_retry == pytest.approx(5.0)


# ----------------------------------------------------------- flap window

def test_flap_window_no_eviction_single_recovery_event():
    cluster = mkcluster(1)
    plane = ControlPlane(cluster)
    cluster.submit(mkpod("p"), 0.0)
    plane.step(0.0)
    assert cluster.pods["p"].bound
    inj = FaultInjector(["flap:n0@10+30"])
    for t in range(0, 80, 10):
        inj.apply(cluster, float(t))
        plane.step(float(t))
    # NotReady with fresh heartbeats is a flap, not a death: no eviction
    assert cluster.pods["p"].bound
    assert "Evicted" not in cluster.event_reasons("p")
    # one NotReady episode -> exactly one NodeRecovered event
    assert cluster.event_reasons("n0").count("NodeRecovered") == 1


def test_stale_heartbeats_still_fail_the_node():
    """The flap fix must not soften real deaths: a NotReady node whose
    heartbeats also went stale is failed and its pods re-served."""
    cluster = mkcluster(2)
    cluster.apply_deployment(Deployment("svc", 1, template=PodTemplate(
        tolerations=list(TOL), request_chips=1)), 0.0)
    plane = ControlPlane(cluster)
    plane.step(0.0)
    victim = cluster.pods_of("svc")[0].pod.node
    survivor = next(n for n in cluster.nodes if n != victim)
    inj = FaultInjector([FaultSpec("crash", 10.0, victim)])
    for t in range(0, 60, 10):
        inj.apply(cluster, float(t))
        plane.step(float(t))
    live = cluster.pods_of("svc")
    assert len(live) == 1 and live[0].pod.node == survivor


# ------------------------------------------------------ chaos filesystem

def test_injector_ckpt_corrupt_hits_disk_through_the_schedule(tmp_path):
    pod_dir = tmp_path / "svc-0"
    checkpointer.save(pod_dir, 0, {"served": np.asarray(5)})
    cluster = mkcluster(1)
    inj = FaultInjector([FaultSpec("ckpt_corrupt", 1.0, "svc-0")],
                        ckpt_dir=str(tmp_path))
    inj.apply(cluster, 1.0)
    assert not checkpointer.verify_step(pod_dir, 0)
    assert any(e.reason == "ChaosCkptCorrupt" for e in cluster.events)
    # the recovery path sees no usable generation -> {} (start fresh),
    # not a crash
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    assert plane.nodes.recover_from_disk("svc-0", 2.0) == {}


# --------------------------------------------------- two-phase drain

def test_walltime_cut_mid_drain_resumes_from_background_checkpoint(tmp_path):
    """Phase 1 (periodic background snapshots) + phase 2 (paced drain):
    a walltime cut interrupts the drain after one pod; the survivor is
    recovered from its last background generation — not start-fresh."""
    counters = {}
    cluster = mkcluster(2, chips=4, walltimes=[1000.0, 0.0])
    cluster.apply_deployment(Deployment("svc", 2, template=PodTemplate(
        tolerations=list(TOL), request_chips=1,
        checkpoint_state=lambda name: counters.get(name))), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    plane.nodes.bg_checkpoint_every = 10.0
    plane.nodes.drain_pods_per_tick = 1
    # both replicas start on the doomed node
    plane.scheduler.scorers = [
        lambda rec, node, sched, now: 1.0 if node.name == "n0" else 0.0]
    plane.step(0.0)
    first = sorted(r.name for r in cluster.pods_of("svc"))
    assert len(first) == 2
    assert all(r.pod.node == "n0" for r in cluster.pods_of("svc"))
    for i, name in enumerate(first):
        counters[name] = {"served": 10 + i, "tokens": 100 + i}
    plane.scheduler.scorers = []

    inj = FaultInjector(["walltime_cut:n0@30x10"])   # 10s of lease left
    for t in (10.0, 20.0, 30.0, 40.0, 50.0):
        inj.apply(cluster, t)
        plane.step(t)

    assert cluster.nodes["n0"].walltime == pytest.approx(40.0)
    live = cluster.pods_of("svc")
    assert len(live) == 2 and all(r.pod.node == "n1" for r in live)
    reasons = cluster.event_reasons()
    # one pod drained gracefully before the cut bit...
    assert "Checkpointed" in reasons
    # ...the other was caught mid-drain and recovered from the last
    # background generation written at t<=30
    assert "CrashRestored" in reasons
    for rec in live:
        assert rec.restored_from in first
        assert int(rec.restored_state["served"]) == \
            int(counters[rec.restored_from]["served"])


def test_double_eviction_parks_state_exactly_once(tmp_path):
    """Regression: a drain and a racing walltime-expiry fail hitting the
    same pod must park its checkpoint once — not feed two restores."""
    counters = {}
    cluster = mkcluster(2, chips=4, walltimes=[100.0, 0.0])
    cluster.apply_deployment(Deployment("svc", 1, template=PodTemplate(
        tolerations=list(TOL), request_chips=1,
        checkpoint_state=lambda name: counters.get(name))), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    plane.scheduler.scorers = [
        lambda rec, node, sched, now: 1.0 if node.name == "n0" else 0.0]
    plane.step(0.0)
    first = cluster.pods_of("svc")[0]
    counters[first.name] = {"served": 3}
    now = 50.0
    for n in cluster.nodes:
        cluster.heartbeat(n, now)
    plane.scheduler.scorers = []
    plane.nodes._drain_node("n0", now)
    plane.nodes._fail_node("n0", now, "walltime expired")   # racing path
    assert cluster.event_reasons(first.name).count("Evicted") == 1
    assert len(plane.deployments.pending_restores.get("svc", [])) == 1
    plane.deployments.reconcile(now)
    plane.scheduler.run_once(now)
    live = cluster.pods_of("svc")
    assert len(live) == 1 and live[0].bound
    assert int(live[0].restored_state["served"]) == 3


# ------------------------------------------------------ invariant audits

def test_auditor_green_on_healthy_cluster():
    cluster = mkcluster(2)
    cluster.submit(mkpod("p"), 0.0)
    ControlPlane(cluster).step(0.0)
    out = InvariantAuditor(cluster).audit(1.0)
    assert out["nodes"] == 2


def test_auditor_catches_quota_book_imbalance():
    cluster = mkcluster(1)
    aud = InvariantAuditor(cluster)
    aud.audit(0.0)
    # a ghost pod lands on the kubelet with no store record: node truth
    # and owner books diverge
    cluster.nodes["n0"].create_pod(mkpod("ghost"), 1.0)
    with pytest.raises(ChaosInvariantError):
        aud.audit(1.0)


def test_auditor_catches_duplicate_completion_and_double_booking():
    from types import SimpleNamespace
    cluster = mkcluster(1)
    dup = SimpleNamespace(runtimes={}, completed=[(7, 0.0), (7, 1.0)],
                          queue=[], _node_reachable=lambda name: True)
    with pytest.raises(ChaosInvariantError):
        InvariantAuditor(cluster, engine=dup).audit(1.0)
    from repro.data.pipeline import Request
    twice = SimpleNamespace(runtimes={}, completed=[],
                            queue=[Request(3, 0.0, 8, 4),
                                   Request(3, 0.0, 8, 4)],
                            _node_reachable=lambda name: True)
    with pytest.raises(ChaosInvariantError):
        InvariantAuditor(cluster, engine=twice).audit(2.0)


# ------------------------------------- capstone: partition + epoch fence

def _mk_engine(walltimes, service_rate=6.0, chips=2):
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(1, host_params=host)
    nodes = [start_vk(f"n{i}", walltime=w, now=0.0,
                      slice_spec=SliceSpec(chips=chips))
             for i, w in enumerate(walltimes)]
    return StreamEngine(cfg, serving, nodes, service_rate=service_rate,
                        max_batch=4, record_tokens=True,
                        runtime_cfg=RuntimeConfig(max_batch=4, admit_tail=0))


def _setup(eng, ckpt_dir=None):
    eng.deploy(0.0)
    if ckpt_dir is not None:
        eng.plane.nodes.ckpt_dir = ckpt_dir
        eng.plane.nodes.bg_checkpoint_every = 10.0
    eng.cluster.scale("ersap", 2, 0.0, source="test")
    eng.reconcile(0.0)
    assert len(eng.pods) == 2
    assert len({p.node for p in eng.pods.values()}) == 2


def _drive(eng, ticks, dt=10.0, lam_until=8, injector=None, auditor=None):
    """Tick loop; returns every runtime incarnation ever live (so retired
    replicas' token logs stay inspectable)."""
    seen = {}
    for t in range(ticks):
        now = t * dt
        if injector is not None:
            injector.apply(eng.cluster, now)
        else:
            for name in eng.cluster.nodes:
                eng.cluster.heartbeat(name, now)
        eng.reconcile(now)
        eng.tick(now, dt, lam=1.0 if t < lam_until else 0.0)
        for rt in eng.runtimes.values():
            seen[id(rt)] = rt
        if auditor is not None:
            auditor.audit(now)
    return seen


def test_partition_rejoin_epoch_fence_token_identical(tmp_path):
    """Acceptance scenario: a serving node is partitioned mid-run long
    enough to be declared dead and its replica re-served elsewhere; on
    rejoin the stale replica is epoch-fenced. The chaos run loses zero
    requests, completes each exactly once, and every token any
    incarnation emitted is a prefix of the fault-free oracle's stream
    for that rid (deterministic prompt replay)."""
    oracle = _mk_engine([0.0, 0.0, 0.0])
    _setup(oracle)
    o_rts = _drive(oracle, 20)
    assert oracle.source.rid > 0
    assert len(oracle.completed) == oracle.source.rid
    o_logs = {}
    for rt in o_rts.values():
        for rid, log in rt.token_log.items():
            o_logs[rid] = list(log)        # fault-free: one incarnation/rid

    eng = _mk_engine([0.0, 0.0, 0.0])
    _setup(eng, ckpt_dir=str(tmp_path))
    victim = sorted(p.node for p in eng.pods.values())[0]
    victim_pods = {n for n, p in eng.pods.items() if p.node == victim}
    inj = FaultInjector([FaultSpec("partition", 30.0, victim, duration=90.0)])
    aud = InvariantAuditor(eng.cluster, engine=eng)
    rts = _drive(eng, 20, injector=inj, auditor=aud)
    assert aud.checks == 20

    # the partition ran its course: severed, declared dead, re-served,
    # rejoined, fenced
    reasons = eng.cluster.event_reasons()
    assert "Partitioned" in reasons and "Rejoined" in reasons
    fenced = [e for e in eng.cluster.events if e.reason == "Fenced"]
    assert fenced and all(e.name in victim_pods for e in fenced)
    assert eng.cluster.fence_epochs == {}           # floor consumed
    assert not eng.cluster.orphaned_pods(victim)    # kubelet cleaned up
    # the replica set is whole again and the victim's pod moved
    assert len(eng.pods) == 2
    moved = [r for r in eng.cluster.pods_of("ersap") if r.restored_from]
    assert any(r.restored_from in victim_pods for r in moved)

    # zero request loss, exactly-once completion
    assert eng.source.rid == oracle.source.rid      # identical workload
    done = [rid for rid, _ in eng.completed]
    assert len(done) == eng.source.rid
    assert len(set(done)) == len(done)
    assert not eng.queue

    # token identity vs the oracle: every incarnation's log is a prefix
    # of the oracle stream for that rid — replay, never divergence or
    # double-emission past the oracle's sequence
    compared = 0
    for rt in rts.values():
        for rid, log in rt.token_log.items():
            assert rid in o_logs
            assert list(log) == o_logs[rid][:len(log)], \
                f"rid {rid} diverged from the fault-free oracle"
            compared += 1
    assert compared > 0
