"""Declarative control plane: object store, reconciling scheduler,
drain-aware controllers, and the end-to-end churn scenario (§4.5.4 closed
loop: drain -> checkpoint -> evict -> reschedule with zero request loss)."""
import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_config
from repro.core.cluster import (ADDED, DELETED, KIND_POD, Cluster,
                                Deployment, PodTemplate)
from repro.core.controllers import (ControlPlane, DeploymentController,
                                    NodeLifecycleController)
from repro.core.elastic import ElasticServing
from repro.core.jfm import FacilityManager
from repro.core.jrm import SliceSpec, start_vk
from repro.core.scheduler import Scheduler
from repro.core.state_machine import Container, Pod
from repro.models import model_api as MA
from repro.streaming.engine import StreamEngine

TOL = [{"key": "virtual-kubelet.io/provider", "value": "mock"}]


def mkpod(name="p", chips=1, hbm=0):
    return Pod(name, [Container("c")], tolerations=list(TOL),
               request_chips=chips, request_hbm_bytes=hbm)


def mkcluster(n_nodes=3, chips=4, walltimes=None, now=0.0):
    cluster = Cluster()
    for i in range(n_nodes):
        wall = walltimes[i] if walltimes else 0.0
        cluster.register_node(
            start_vk(f"n{i}", walltime=wall, now=now,
                     slice_spec=SliceSpec(chips=chips)), now)
        cluster.heartbeat(f"n{i}", now)
    return cluster


# ----------------------------------------------------------- object store

def test_store_watch_bus_and_event_trail():
    cluster = mkcluster(1)
    seen = []
    cluster.watch(KIND_POD, lambda ev: seen.append((ev.type, ev.name)))
    cluster.submit(mkpod("a"), 1.0)
    Scheduler(cluster).run_once(1.0)
    cluster.evict("a", 2.0, reason="Evicted")
    assert (ADDED, "a") in seen and (DELETED, "a") in seen
    assert cluster.event_reasons("a") == ["Created", "Scheduled", "Evicted"]


def test_scale_is_a_spec_write_only():
    cluster = mkcluster(1)
    dep = cluster.apply_deployment(Deployment("d", 1), 0.0)
    cluster.scale("d", 3, 1.0, source="hpa")
    assert dep.replicas == 3
    assert not cluster.pods            # nothing created until a controller runs
    assert "Scaled" in cluster.event_reasons("d")


# -------------------------------------------------------------- scheduler

def test_scheduler_backoff_retries_until_capacity_frees():
    cluster = mkcluster(1, chips=2)
    sched = Scheduler(cluster, backoff_base=5.0, enable_preemption=False)
    cluster.submit(mkpod("big", chips=2), 0.0)
    sched.run_once(0.0)
    rec = cluster.submit(mkpod("waiting", chips=2), 0.0)
    sched.run_once(0.0)
    assert not rec.bound and rec.attempts == 1
    # exponential base stretched by the decorrelation jitter (<= 25%)
    assert 5.0 <= rec.next_retry <= 5.0 * (1 + sched.backoff_jitter)
    sched.run_once(1.0)                     # still backing off: not retried
    assert rec.attempts == 1
    sched.run_once(7.0)                     # retried, still no room
    assert rec.attempts == 2
    assert 10.0 <= rec.next_retry - 7.0 <= 10.0 * (1 + sched.backoff_jitter)
    cluster.evict("big", 20.0)              # capacity frees
    sched.run_once(20.0)
    assert rec.bound
    reasons = cluster.event_reasons("waiting")
    # both failed attempts share one reason -> one transition event
    assert reasons.count("FailedScheduling") == 1
    assert reasons[-1] == "Scheduled"


def test_scheduler_preemption_requeues_victims():
    cluster = mkcluster(1, chips=2)
    sched = Scheduler(cluster)
    cluster.submit(mkpod("low", chips=2), 0.0, priority=0)
    sched.run_once(0.0)
    cluster.submit(mkpod("high", chips=2), 1.0, priority=10)
    decisions = sched.run_once(1.0)
    assert decisions[0].node == "n0" and decisions[0].preempted == ("low",)
    assert cluster.pods["high"].bound
    # victim was requeued, not lost
    assert "low" in cluster.pods and not cluster.pods["low"].bound
    assert "Preempted" in cluster.event_reasons("low")
    # second node appears -> the victim lands there on the next pass
    cluster.register_node(start_vk("n1", now=2.0,
                                   slice_spec=SliceSpec(chips=2)), 2.0)
    cluster.heartbeat("n1", 2.0)
    sched.run_once(2.0)
    assert cluster.pods["low"].pod.node == "n1"


def test_scheduler_never_preempts_onto_draining_node():
    cluster = mkcluster(1, chips=2, walltimes=[100.0])
    sched = Scheduler(cluster)
    cluster.submit(mkpod("low", chips=2), 0.0, priority=0)
    sched.run_once(0.0)
    now = 50.0                              # inside the 60s drain margin
    cluster.heartbeat("n0", now)
    cluster.submit(mkpod("high", chips=2), now, priority=10)
    decisions = sched.run_once(now)
    assert decisions[-1].node is None       # backoff, not preemption
    assert "low" in cluster.pods and cluster.pods["low"].bound


def test_scheduler_spreads_replicas_across_nodes():
    cluster = mkcluster(3, chips=4)
    sched = Scheduler(cluster)
    for i in range(3):
        cluster.submit(mkpod(f"r{i}", chips=1), 0.0)
    sched.run_once(0.0)
    nodes = {cluster.pods[f"r{i}"].pod.node for i in range(3)}
    assert nodes == {"n0", "n1", "n2"}


# ------------------------------------------------------------- controllers

def test_deployment_controller_converges_and_scales_down():
    cluster = mkcluster(2, chips=4)
    cluster.apply_deployment(Deployment("web", 3, template=PodTemplate(
        tolerations=list(TOL), request_chips=1)), 0.0)
    plane = ControlPlane(cluster)
    plane.step(0.0)
    assert len([r for r in cluster.pods.values() if r.bound]) == 3
    cluster.scale("web", 1, 5.0, source="user")
    plane.step(5.0)
    live = cluster.pods_of("web")
    assert len(live) == 1 and live[0].bound
    assert "ScaledDown" in cluster.event_reasons()


def test_node_failure_evicts_and_replaces():
    """Crash path: heartbeats stop, JFM feed marks the node NotReady, the
    lifecycle controller evicts, the deployment replaces, the scheduler
    re-places — all declaratively."""
    cluster = mkcluster(2, chips=4)
    fm = FacilityManager(stale_after=30.0)
    cluster.apply_deployment(Deployment("web", 2, template=PodTemplate(
        tolerations=list(TOL), request_chips=1)), 0.0)
    plane = ControlPlane(cluster)
    fm.feed(cluster, 0.0)
    plane.step(0.0)
    victim_node = cluster.pods_of("web")[0].pod.node
    survivor = next(n for n in cluster.nodes if n != victim_node)
    # only the survivor heartbeats from now on
    cluster.heartbeat(survivor, 100.0)
    fm.feed(cluster, 100.0)
    plane.step(100.0)
    live = [r for r in cluster.pods_of("web") if r.bound]
    assert len(live) == 2
    assert all(r.pod.node == survivor for r in live)


def test_drain_checkpoint_evict_reschedule_restores_state(tmp_path):
    """Satellite: a pod on a node whose lease enters the drain margin is
    checkpointed through repro.checkpoint, evicted, and rescheduled onto a
    healthy node with its runtime state restored."""
    counters = {}

    cluster = mkcluster(2, chips=4, walltimes=[120.0, 0.0])
    cluster.apply_deployment(Deployment("svc", 1, template=PodTemplate(
        tolerations=list(TOL), request_chips=1,
        checkpoint_state=lambda name: counters.get(name))), 0.0)
    plane = ControlPlane(cluster)
    plane.nodes.ckpt_dir = str(tmp_path)
    # force initial placement onto the short-lease node
    plane.scheduler.scorers = [
        lambda rec, node, sched, now: 1.0 if node.name == "n0" else 0.0]
    plane.step(0.0)
    first = cluster.pods_of("svc")[0]
    assert first.pod.node == "n0"
    counters[first.name] = {"served": 42, "tokens": 678}

    now = 70.0                              # alive_left = 50 < 60s margin
    for name in cluster.nodes:
        cluster.heartbeat(name, now)
    plane.scheduler.scorers = []            # back to neutral scoring
    plane.step(now)

    moved = cluster.pods_of("svc")[0]
    assert moved.name != first.name
    assert moved.pod.node == "n1" and moved.bound
    assert moved.restored_from == first.name
    assert int(moved.restored_state["served"]) == 42
    assert int(moved.restored_state["tokens"]) == 678
    # the checkpoint went through repro.checkpoint's atomic on-disk path
    assert checkpointer.latest_step(tmp_path / first.name) == 0
    # event trail: the §4.5.4 loop is auditable
    assert "Draining" in cluster.event_reasons("n0")
    old = cluster.event_reasons(first.name)
    assert "Checkpointed" in old and "Evicted" in old
    assert "Rescheduled" in cluster.event_reasons(moved.name)


# -------------------------------------------------- engine + control plane

def _engine(nodes_walltimes, service_rate=4.0, replicas=1, chips=4):
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=1).build(replicas, host_params=host)
    nodes = [start_vk(f"n{i}", walltime=w, now=0.0,
                      slice_spec=SliceSpec(chips=chips))
             for i, w in enumerate(nodes_walltimes)]
    eng = StreamEngine(cfg, serving, nodes, service_rate=service_rate,
                       max_batch=4)
    return eng


def test_engine_scale_down_leaves_no_stale_stats_or_endpoints(tmp_path):
    """Satellite: retired replicas disappear from stats AND from the
    Service endpoints, so Prometheus stops scraping dead pods."""
    eng = _engine([0.0, 0.0], replicas=1)
    eng.deploy(0.0)
    eng.cluster.scale("ersap", 2, 1.0, source="test")
    eng.reconcile(1.0)
    assert len(eng.pods) == 2
    assert set(eng.stats) == set(eng.pods)
    eng.tick(2.0, 2.0, lam=2.0)
    served_before = eng.total_served
    eng.cluster.scale("ersap", 1, 3.0, source="test")
    eng.reconcile(3.0)
    live = set(eng.pods)
    assert len(live) == 1
    assert set(eng.stats) == live
    assert set(eng.registries) == live
    eps = {ep.pod for svc in eng.prom.services for ep in svc.endpoints}
    assert eps == live                      # no stale scrape targets
    assert eng.total_served == served_before   # global counters survive


def test_e2e_churn_zero_request_loss(tmp_path):
    """Acceptance: a streaming Deployment across 3 nodes; one node's
    walltime expires mid-run; the NodeLifecycleController checkpoints and
    evicts, the scheduler re-places the replica, and every in-flight
    request is eventually served — with the full event trail recorded."""
    eng = _engine([160.0, 0.0, 0.0], service_rate=6.0, chips=2)
    eng.deploy(0.0)
    eng.plane.nodes.ckpt_dir = str(tmp_path / "drain")
    # single-CPU jax clamps the mesh to 1 data replica; the Deployment
    # spec is still free to ask for 2 simulated serving pods
    eng.cluster.scale("ersap", 2, 0.0, source="test")
    eng.reconcile(0.0)
    assert len(eng.pods) == 2
    # one replica sits on the doomed short-lease node (spread scoring
    # guarantees the two replicas land on distinct nodes)
    assert len({p.node for p in eng.pods.values()}) == 2

    dt = 10.0
    for t in range(16):
        now = t * dt
        for name in eng.cluster.nodes:
            eng.cluster.heartbeat(name, now)
        eng.reconcile(now)
        eng.tick(now, dt, lam=1.0 if t < 10 else 0.0)
    # drain ticks: no new arrivals, queue must empty through live replicas
    for t in range(16, 22):
        now = t * dt
        for name in eng.cluster.nodes:
            eng.cluster.heartbeat(name, now)
        eng.reconcile(now)
        eng.tick(now, dt, lam=0.0)

    # zero lost in-flight requests: everything that arrived completed
    assert eng.source.rid > 0
    assert len(eng.completed) == eng.source.rid
    assert len(eng.queue) == 0
    # the replica set converged back to spec on healthy nodes
    assert len(eng.pods) == 2
    assert all(p.node != "n0" for p in eng.pods.values())
    # event trail: Scheduled -> Draining -> (Checkpointed) -> Evicted ->
    # Rescheduled all visible in the Cluster event store
    reasons = eng.cluster.event_reasons()
    for expected in ("Scheduled", "Draining", "Checkpointed", "Evicted",
                     "Rescheduled"):
        assert expected in reasons, f"missing {expected} in {set(reasons)}"
    # the moved replica carried its counters across the reschedule
    moved = [r for r in eng.cluster.pods_of("ersap") if r.restored_from]
    assert moved and moved[0].restored_state is not None
