"""Paged KV slab (PR 4): page allocator invariants, paged-vs-dense token
identity, pool-exhaustion backpressure, fragmentation-free reuse across
mid-stream retirement, page-table checkpoint round-trip through the
§4.5.4 drain loop, trace bounds under randomized shapes, and the
equal-HBM wide-batch configuration."""
import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs.base import get_config
from repro.core.elastic import ElasticServing
from repro.data.pipeline import Request
from repro.models import model_api as MA
from repro.streaming.runtime import (DecodeRuntime, PageAllocator,
                                     RuntimeConfig)


@pytest.fixture(scope="module")
def serving():
    cfg = get_config("qwen2-7b").reduced()
    mod = MA.get_module(cfg)
    host = jax.tree.map(np.asarray, mod.init(jax.random.PRNGKey(0), cfg))
    return ElasticServing(cfg, tp=1).build(1, host_params=host)


def mk_runtime(serving, rcfg, **kw):
    return DecodeRuntime(serving.runtime_kernels(rcfg), serving.params,
                         gen=serving.build_gen, **kw)


def paged_cfg(**kw):
    base = dict(max_batch=4, paged=True, page_size=16)
    base.update(kw)
    return RuntimeConfig(**base)


def used_by_slots(rt):
    return sum(len(s.pages) for s in rt.slots if s.busy)


# ------------------------------------------------------------- allocator

def test_page_allocator_alloc_free_reuse():
    a = PageAllocator(6)
    assert a.n_pages == 7 and a.free_pages == 6 and a.used_pages == 0
    g1 = a.alloc(2)
    g2 = a.alloc(3)
    assert g1 is not None and g2 is not None
    assert 0 not in g1 + g2                  # null page never granted
    assert len(set(g1) | set(g2)) == 5       # no page owned twice
    assert a.used_pages == 5
    # all-or-nothing: 2 > 1 free -> None, nothing consumed
    assert a.alloc(2) is None
    assert a.free_pages == 1
    a.free(g1)
    assert a.free_pages == 3 and a.used_pages == 3
    # freshly freed pages are reused (LIFO) and conservation holds
    g3 = a.alloc(3)
    assert set(g1) <= set(g3)
    assert a.used_pages + a.free_pages == a.pool_pages == 6


def test_footprint_and_fits():
    rc = paged_cfg(max_prompt_bucket=16, max_new_cap=16, pool_pages=2)
    # prompt bucket 16 + 8 generated + 1 frozen-row slot = 25 -> 2 pages
    assert rc.page_footprint(16, 8) == 2
    assert rc.fits(Request(1, 0.0, prompt_len=12, max_new=8))
    # capacity would hold it, but the pool cannot: falls back to chunked
    assert not rc.fits(Request(2, 0.0, prompt_len=16, max_new=16))


# ----------------------------------------------------------- correctness

def test_paged_matches_dense_tokens(serving):
    """The paged slab must emit exactly the dense slab's greedy tokens —
    the layout is an optimization, not a model change."""
    reqs = lambda: [Request(i, 0.0, prompt_len=5 + i, max_new=2 + 3 * (i % 4))
                    for i in range(1, 9)]
    logs = {}
    for name, rcfg in (("dense", RuntimeConfig(max_batch=4, admit_tail=0,
                                               paged=False)),
                       ("paged", paged_cfg(admit_tail=0))):
        rt = mk_runtime(serving, rcfg, record_tokens=True)
        rt.submit(reqs())
        done = rt.pump()
        assert sorted(f.req.rid for f in done) == list(range(1, 9))
        logs[name] = dict(rt.token_log)
    assert logs["paged"] == logs["dense"]


def test_pool_exhaustion_blocks_admission_until_retirement(serving):
    """A pool smaller than the slot count's worst case: admission waits
    for retirements instead of over-committing, every request completes,
    and the high-water mark respects the pool."""
    rc = paged_cfg(pool_pages=6, max_prompt_bucket=16, max_new_cap=32)
    rt = mk_runtime(serving, rc)
    reqs = [Request(i, 0.0, prompt_len=10, max_new=12) for i in range(1, 9)]
    assert all(rt.fits(r) for r in reqs)     # each fits alone (2 pages)
    rt.submit(reqs)
    done = rt.pump()
    assert sorted(f.req.rid for f in done) == list(range(1, 9))
    assert all(f.tokens == f.req.max_new for f in done)
    assert rt.pages_hwm <= 6
    assert rt.alloc.used_pages == 0 and rt.alloc.free_pages == 6
    assert not rt.page_table.any()           # every row back on null pages


def test_reuse_after_midstream_retirement_no_fragmentation(serving):
    """Short requests retire mid-stream under longer ones; their pages are
    re-granted to later admissions (unit granularity = no stranded
    fragments) and the slot/allocator books always balance."""
    rc = paged_cfg(max_batch=2, decode_block=4, pool_pages=8,
                   max_prompt_bucket=16, max_new_cap=32)
    rt = mk_runtime(serving, rc)
    rt.submit([Request(1, 0.0, prompt_len=8, max_new=2),
               Request(2, 0.0, prompt_len=8, max_new=24),
               Request(3, 0.0, prompt_len=8, max_new=2),
               Request(4, 0.0, prompt_len=8, max_new=2)])
    done = []
    seen_pages = set()
    for _ in range(40):
        done.extend(rt.step())
        assert rt.alloc.used_pages == used_by_slots(rt)
        assert rt.alloc.used_pages + rt.alloc.free_pages == rc.n_pool_pages
        for s in rt.slots:
            if s.busy:
                seen_pages.update(s.pages)
        if not rt.inflight:
            break
    assert sorted(f.req.rid for f in done) == [1, 2, 3, 4]
    # the pool is smaller than the sum of footprints ever admitted, so
    # reuse must have happened for all four to complete
    total_footprint = sum(rc.page_footprint(8, mn) for mn in (2, 24, 2, 2))
    assert total_footprint > rc.n_pool_pages or len(seen_pages) < total_footprint


# ------------------------------------------------------------ checkpoint

def test_paged_checkpoint_roundtrip_token_identity(serving, tmp_path):
    """Page-table state through drain -> evict -> restore: the checkpoint
    carries the logical ledger (not physical page ids); the successor's
    admission re-allocates pages and replays token-identical output."""
    rc = paged_cfg(max_batch=2, admit_tail=0, decode_block=4)
    ref = mk_runtime(serving, rc, record_tokens=True)
    ref.submit([Request(1, 0.0, prompt_len=8, max_new=2),
                Request(2, 0.0, prompt_len=8, max_new=10)])
    ref.pump()
    ref_log = ref.token_log[2]

    rt = mk_runtime(serving, rc, record_tokens=True)
    rt.submit([Request(1, 0.0, prompt_len=8, max_new=2),
               Request(2, 0.0, prompt_len=8, max_new=10)])
    rt._admit_some()
    rt._decode_block()                      # r1 done, r2 mid-generation
    assert rt.alloc.used_pages == used_by_slots(rt) > 0
    state = rt.state()
    tree = {k: np.asarray(v) for k, v in state.items()}
    checkpointer.save(tmp_path, 0, tree, meta={"pod": "r0"})
    restored, _ = checkpointer.restore(tmp_path, tree, step=0)
    # predecessor drains: every page returns to its pool
    rt.drain()
    assert rt.alloc.used_pages == 0 and not rt.page_table.any()

    rt2 = mk_runtime(serving, rc, record_tokens=True)
    rt2.restore(restored)
    rt2.pump()
    assert rt2.alloc.used_pages == 0        # successor books balance too
    got = rt2.token_log[2]
    assert got == ref_log[:len(got)]        # token-identical replay
    assert len(got) == 7                    # 1 prefill argmax + 6 remaining


# ------------------------------------------------------------ trace bound

def test_paged_trace_counts_bounded_random_shapes(serving):
    rc = paged_cfg()
    rt = mk_runtime(serving, rc)
    rng = np.random.default_rng(9)
    rid = 0
    for _ in range(10):
        reqs = []
        for _ in range(int(rng.integers(1, 9))):
            rid += 1
            reqs.append(Request(rid, 0.0,
                                int(rng.integers(1, rc.max_prompt_bucket)),
                                int(rng.integers(1, 17))))
        rt.submit(reqs)
        for f in rt.pump():
            assert f.tokens == f.req.max_new
    traces = rt.kernels.trace_counts
    assert traces["admit"] + traces["decode"] <= rt.kernels.max_traces
    n_kv = len(rc.kv_ladder)
    assert traces["admit"] <= (len(rc.batch_buckets)
                               * len(rc.prompt_buckets) * n_kv)
    assert traces["decode"] <= len(rc.block_ladder) * n_kv


# -------------------------------------------------------- equal-HBM slots

def test_equal_hbm_pool_carries_more_concurrent_requests(serving):
    """The PagedAttention batch story: with the pool sized to the dense
    slab's KV entries, short-request footprints let 3x the slots run
    concurrently — impossible for the dense layout at the same HBM."""
    dense = RuntimeConfig(max_batch=4, paged=False)
    entries = (dense.max_batch + 1) * dense.capacity
    rc = paged_cfg(max_batch=12, pool_pages=entries // 16)
    rt = mk_runtime(serving, rc)
    rt.submit([Request(i, 0.0, prompt_len=6, max_new=12)
               for i in range(1, 13)])
    rt._admit_some()
    busy = sum(s.busy for s in rt.slots)
    assert busy == 12 > dense.max_batch
    assert rt.alloc.used_pages * rc.page_size <= entries
    done = rt.pump()
    assert sorted(f.req.rid for f in done) == list(range(1, 13))
