"""Deterministic synthetic token pipeline (host-sharded, checkpointable).

Sequences are a position-hashed Markov-ish stream so training loss
decreases measurably without external data. The iterator state is one
integer (step), saved with checkpoints — restart resumes the exact stream.
For serving, ``RequestSource`` generates Poisson request arrivals feeding
the streaming engine's FIFO queue (the paper §6 sender analog)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

import jax


def _tokens(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # order-1 structure: next token = (prev * a + noise) % vocab so models
    # can actually learn something
    a = 31
    x = np.zeros((batch, seq + 1), np.int64)
    x[:, 0] = rng.integers(0, vocab, batch)
    noise = rng.integers(0, 7, (batch, seq))
    for t in range(seq):
        x[:, t + 1] = (x[:, t] * a + noise[:, t]) % vocab
    return x


@dataclass
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    frontend_seq: int = 0
    d_model: int = 0


@dataclass
class SyntheticDataset:
    cfg: DataConfig
    step: int = 0

    def next_batch(self, shardings=None):
        x = _tokens(self.step, self.cfg.batch, self.cfg.seq, self.cfg.vocab,
                    self.cfg.seed)
        batch = {
            "tokens": x[:, :-1].astype(np.int32),
            "labels": x[:, 1:].astype(np.int32),
            "mask": np.ones((self.cfg.batch, self.cfg.seq), np.float32),
        }
        if self.cfg.frontend_seq:
            rng = np.random.default_rng(self.step + 7)
            batch["frontend"] = rng.normal(
                0, 1, (self.cfg.batch, self.cfg.frontend_seq,
                       self.cfg.d_model)).astype(np.float32)
        self.step += 1
        if shardings is not None:
            batch = {k: jax.device_put(v, shardings[k])
                     for k, v in batch.items()}
        return batch

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])


@dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new: int
    # shared-prefix identity: requests with the same (prefix_group > 0,
    # prefix_len > 0) mint identical first ``prefix_len`` prompt tokens —
    # the multi-tenant system-prompt / few-shot-template traffic shape the
    # prefix cache exploits. 0/0 keeps fully independent prompts.
    prefix_group: int = 0
    prefix_len: int = 0
    # overload protection: absolute completion deadline (sim seconds;
    # 0.0 = none). The engine sheds a request whose deadline has passed
    # while it queued *before* it burns prefill compute.
    deadline: float = 0.0
    # QoS tier of the issuing tenant (PriorityClass.value — batch=0,
    # standard=10, latency-critical=100). Brownout sheds low tiers first.
    priority: int = 10
    # trace context stamped at the RequestSource (== rid for sourced
    # traffic; 0 = untraced). Rides checkpoints so a restored request's
    # spans keep chaining to the same trace across fault incarnations.
    trace_id: int = 0


@dataclass
class RequestSource:
    """Poisson arrivals at rate lam(t) — the stream sender of paper §6.

    ``prompt_range`` / ``max_new_range`` (inclusive) randomize per-request
    shapes — the workload that punishes shape-keyed jit caches and rewards
    the serving runtime's bucketed compilation. Defaults keep the seed's
    fixed-shape stream."""
    seed: int = 0
    rid: int = 0
    prompt_range: tuple = None        # e.g. (8, 48)
    max_new_range: tuple = None       # e.g. (2, 16)
    # shared-prefix traffic shaping: with probability ``prefix_share`` a
    # request joins one of ``prefix_groups`` template groups and its first
    # ``prefix_len`` tokens are the group's common prefix
    prefix_share: float = 0.0
    prefix_len: int = 0
    prefix_groups: int = 1
    # overload shaping: ttl > 0 stamps every request with an absolute
    # deadline = arrival + ttl. ``surge`` multiplies the instantaneous
    # arrival rate (the flash-crowd seam chaos `surge:` faults drive).
    # ``tiers`` is an optional ((priority, weight), ...) mix; empty keeps
    # every request at the standard tier (priority 10).
    ttl: float = 0.0
    surge: float = 1.0
    tiers: tuple = ()
    # optional observability hook: when set, every minted request gets an
    # ``enqueue`` span and every deferral a ``defer`` span.
    tracer: object = None

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        # backpressure backlog: (not_before, Request) pairs re-released by
        # ``arrivals``. Deferral never touches the RNG, so retried traffic
        # does not perturb the deterministic arrival stream.
        self._deferred = []
        self.deferred_total = 0

    def defer(self, requests, not_before: float) -> None:
        """Park rejected requests for client-side retry at ``not_before``."""
        for req in requests:
            self._deferred.append((float(not_before), req))
            if self.tracer is not None:
                self.tracer.span("defer", not_before, rid=req.rid)
        self.deferred_total += len(requests)

    def _take_deferred(self, now: float):
        due = [r for t, r in self._deferred if t <= now]
        self._deferred = [(t, r) for t, r in self._deferred if t > now]
        return due

    def _tier(self) -> int:
        if not self.tiers:
            return 10
        total = sum(w for _, w in self.tiers)
        u = self.rng.random() * total
        acc = 0.0
        for prio, w in self.tiers:
            acc += w
            if u < acc:
                return int(prio)
        return int(self.tiers[-1][0])

    def arrivals(self, now: float, dt: float, lam: float, prompt_len=32,
                 max_new=16):
        out = self._take_deferred(now)
        n = self.rng.poisson(lam * max(self.surge, 0.0) * dt)
        for _ in range(n):
            self.rid += 1
            plen = prompt_len if self.prompt_range is None else \
                int(self.rng.integers(self.prompt_range[0],
                                      self.prompt_range[1] + 1))
            mnew = max_new if self.max_new_range is None else \
                int(self.rng.integers(self.max_new_range[0],
                                      self.max_new_range[1] + 1))
            grp, pfx = 0, 0
            if (self.prefix_share > 0 and self.prefix_len > 0
                    and self.rng.random() < self.prefix_share):
                grp = 1 + int(self.rng.integers(self.prefix_groups))
                pfx = min(self.prefix_len, plen)
            arrival = now + self.rng.uniform(0, dt)
            ddl = arrival + self.ttl if self.ttl > 0 else 0.0
            prio = self._tier()
            out.append(Request(self.rid, arrival, plen, mnew,
                               prefix_group=grp, prefix_len=pfx,
                               deadline=ddl, priority=prio,
                               trace_id=self.rid))
            if self.tracer is not None:
                self.tracer.span("enqueue", arrival, rid=self.rid,
                                 prompt_len=plen, max_new=mnew,
                                 priority=prio, deadline=ddl)
        return out
