"""Step builders: sharded train_step / prefill / serve_step (decode) for any
(arch x shape x mesh) cell. Used by the dry-run, the drivers, and tests."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model_api as MA
from repro.optim import adamw
from repro.sharding.api import ShardCtx, tree_shardings, tree_specs


@dataclasses.dataclass
class Cell:
    """A lowered/lowerable (arch x shape x mesh) unit."""
    cfg: ArchConfig
    shape: ShapeConfig
    ctx: ShardCtx
    fn: callable
    args: tuple                      # abstract args (ShapeDtypeStruct trees)
    in_shardings: tuple
    out_shardings: object
    donate: tuple = ()               # train: (params, opt); decode: (cache,)

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jit().lower(*self.args)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec) if mesh is not None else None


def _maybe(tree, mesh):
    return tree if mesh is not None else None


def pick_microbatches(cfg: ArchConfig, shape: ShapeConfig, ctx: ShardCtx,
                      target_tokens_per_shard: int = 16384) -> int:
    dp = ctx.axis_size("data") * ctx.axis_size("pod")
    B = shape.global_batch
    per_shard = max(B // max(dp, 1), 1)
    n = 1
    while (per_shard // n) * shape.seq_len > target_tokens_per_shard \
            and n * 2 <= per_shard and B % (n * 2) == 0:
        n *= 2
    return n


def make_train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    microbatches: Optional[int] = None,
                    remat: bool = True,
                    compress_pod_grads: bool = False) -> Cell:
    """``compress_pod_grads`` (EXPERIMENTAL, default off): on multi-pod
    meshes, take the `pod` axis manual (shard_map with auto data/model) and
    reduce gradients across pods with int8 block-quantized all-gather+sum
    instead of fp32 all-reduce — ~8x less inter-pod (DCN/optical) wire.
    Error feedback is carried in the optimizer state ("ef" tree).

    Status: the compression core (quantize/EF/collective math) is
    unit-tested in tests/test_substrate.py; the integrated path trips an
    XLA SPMD partitioner CHECK (spmd_partitioner_util.cc:504) on this
    jax 0.8.2 CPU build when partial-manual shard_map meets auto-sharded
    constraints — kept behind this flag pending an XLA fix (DESIGN.md §5b).
    """
    ctx = ShardCtx(mesh)
    mod = MA.get_module(cfg)
    aparams = mod.abstract_params(cfg)
    paxes = mod.param_axes(cfg)
    pspecs = tree_specs(ctx, aparams, paxes) if mesh else None
    aopt = adamw.abstract_init(aparams)
    ospecs = adamw.opt_specs(pspecs, aparams, mesh) if mesh else None
    bspecs, baxes = MA.batch_specs(cfg, shape)
    n_micro = microbatches if microbatches is not None else \
        pick_microbatches(cfg, shape, ctx)
    if mesh:
        gspecs = jax.tree.map(lambda s, p: adamw.zero1_spec(s, p.shape, mesh),
                              pspecs, aparams)

    use_compress = (compress_pod_grads and mesh is not None
                    and "pod" in mesh.shape)
    if use_compress:
        # inner context: the pod axis is manual inside shard_map, so batch
        # resolves to data-only there
        inner_rules = dict(ctx.rules)
        inner_rules["batch"] = ("data",)
        inner_ctx = ShardCtx(mesh, inner_rules)
        n_pods = mesh.shape["pod"]
        # error-feedback buffers: per-pod local (leading pod dim)
        aopt = dict(aopt)
        aopt["ef"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((n_pods,) + tuple(p.shape),
                                           jnp.float32), aparams)
        zero_specs = jax.tree.map(
            lambda s, p: adamw.zero1_spec(s, p.shape, mesh), pspecs, aparams)
        ospecs = dict(ospecs)
        ospecs["ef"] = jax.tree.map(
            lambda s: P(*(("pod",) + tuple(s))), zero_specs)

    def compute_grads(params, batch, gctx):
        def micro_loss(p, mb):
            return mod.train_loss(p, mb, cfg, gctx, remat=remat)

        if n_micro == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, grads
        stacked = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                + x.shape[1:]), batch)

        def acc(carry, mb):
            c_loss, c_grads = carry
            if gctx is not None:
                mb = jax.tree.map(
                    lambda x, ax: jax.lax.with_sharding_constraint(
                        x, _ns(mesh, gctx.spec(ax, x.shape))),
                    mb, baxes)
            l, g = jax.value_and_grad(micro_loss)(params, mb)
            if gctx is not None:
                g = jax.tree.map(
                    lambda a, s: jax.lax.with_sharding_constraint(
                        a, _ns(mesh, s)), g, gspecs)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             c_grads, g)
            return (c_loss + l, g), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(
            acc, (jnp.zeros((), jnp.float32), zeros), stacked)
        return loss / n_micro, jax.tree.map(lambda g: g / n_micro, grads)

    def train_step(params, opt, batch):
        def micro_loss(p, mb):
            return mod.train_loss(p, mb, cfg, ctx if mesh else None,
                                  remat=remat)

        if use_compress:
            from jax import shard_map
            from repro.optim.compression import compressed_psum_ef
            ef = opt["ef"]

            def pod_local(p, b, ef_l):
                loss, grads = compute_grads(p, b, inner_ctx)
                pairs = jax.tree.map(
                    lambda g, e: compressed_psum_ef(g, e[0], "pod"),
                    grads, ef_l)
                g_hat = jax.tree.map(lambda t: t[0], pairs,
                                     is_leaf=lambda t: isinstance(t, tuple))
                new_ef = jax.tree.map(lambda t: t[1][None], pairs,
                                      is_leaf=lambda t: isinstance(t, tuple))
                loss = jax.lax.pmean(loss, "pod")
                return loss, g_hat, new_ef

            loss, grads, new_ef = shard_map(
                pod_local, mesh=mesh,
                in_specs=(P(), jax.tree.map(lambda _: P("pod"), batch),
                          jax.tree.map(lambda _: P("pod"), ef)),
                out_specs=(P(), P(), jax.tree.map(lambda _: P("pod"), ef)),
                axis_names={"pod"}, check_vma=False,
            )(params, batch, ef)
            opt = dict(opt)
            params, opt2, metrics = adamw.apply(
                grads, {k: v for k, v in opt.items() if k != "ef"},
                params, opt_cfg)
            opt2["ef"] = new_ef
            metrics["loss"] = loss
            return params, opt2, metrics

        if n_micro == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            stacked = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                c_loss, c_grads = carry
                if mesh:
                    mb = jax.tree.map(
                        lambda x, ax: jax.lax.with_sharding_constraint(
                            x, _ns(mesh, ctx.spec(ax, x.shape))),
                        mb, baxes)
                l, g = jax.value_and_grad(micro_loss)(params, mb)
                if mesh:
                    # reshard to the ZeRO spec in the PARAM dtype first:
                    # slicing over `data` is local; only then upcast. This
                    # avoids materializing a full-model f32 grad transient
                    # (27 GB/device on llama4-scout). EXPERIMENTS.md §Perf.
                    g = jax.tree.map(
                        lambda a, s: jax.lax.with_sharding_constraint(
                            a, _ns(mesh, s)), g, gspecs)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 c_grads, g)
                return (c_loss + l, g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros), stacked)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        params, opt, metrics = adamw.apply(grads, opt, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt, metrics

    in_sh = out_sh = None
    if mesh:
        psh = tree_shardings(ctx, aparams, paxes)
        osh = jax.tree.map(lambda s: _ns(mesh, s), ospecs)
        bsh = jax.tree.map(lambda s, ax: _ns(mesh, ctx.spec(ax, s.shape)),
                           bspecs, baxes)
        in_sh = (psh, osh, bsh)
        msh = {"grad_norm": _ns(mesh, P()), "lr": _ns(mesh, P()),
               "loss": _ns(mesh, P())}
        out_sh = (psh, osh, msh)

    return Cell(cfg, shape, ctx, train_step, (aparams, aopt, bspecs),
                in_sh, out_sh, donate=(0, 1))


def make_prefill_cell(cfg: ArchConfig, shape: ShapeConfig,
                      mesh: Optional[Mesh]) -> Cell:
    ctx = ShardCtx(mesh)
    mod = MA.get_module(cfg)
    aparams = mod.abstract_params(cfg)
    paxes = mod.param_axes(cfg)
    pspecs, _ = MA.prefill_specs(cfg, shape)

    def prefill_step(params, inputs):
        return mod.prefill(params, inputs["tokens"], cfg,
                           ctx if mesh else None,
                           frontend=inputs.get("frontend"))

    in_sh = out_sh = None
    if mesh:
        psh = tree_shardings(ctx, aparams, paxes)
        _, iaxes = MA.prefill_specs(cfg, shape)
        ish = jax.tree.map(lambda s, ax: _ns(mesh, ctx.spec(ax, s.shape)),
                           pspecs, iaxes)
        aout = jax.eval_shape(prefill_step, aparams, pspecs)
        caxes = MA.cache_axes(cfg)
        lsh = _ns(mesh, ctx.spec(("batch", "vocab"), aout[0].shape))
        csh = jax.tree.map(
            lambda s, ax: _ns(mesh, ctx.spec(ax, s.shape)), aout[1], caxes)
        in_sh = (psh, ish)
        out_sh = (lsh, csh)

    return Cell(cfg, shape, ctx, prefill_step, (aparams, pspecs), in_sh, out_sh)


def make_decode_cell(cfg: ArchConfig, shape: ShapeConfig,
                     mesh: Optional[Mesh], unroll: bool = False,
                     cache_mode: str = "slots") -> Cell:
    """serve_step: one new token against a cache holding shape.seq_len context."""
    ctx = ShardCtx(mesh)
    mod = MA.get_module(cfg)
    aparams = mod.abstract_params(cfg)
    paxes = mod.param_axes(cfg)
    acache, caxes = MA.cache_specs(cfg, shape, cache_mode)
    tok_spec, tok_axes = MA.decode_token_specs(cfg, shape)
    extra = {"unroll": True} if unroll else {}

    def serve_step(params, token, cache):
        return mod.decode_step(params, token, cache, cfg,
                               ctx if mesh else None, **extra)

    in_sh = out_sh = None
    if mesh:
        psh = tree_shardings(ctx, aparams, paxes)
        tsh = _ns(mesh, ctx.spec(tok_axes, tok_spec.shape))
        csh = jax.tree.map(lambda s, ax: _ns(mesh, ctx.spec(ax, s.shape)),
                           acache, caxes)
        aout = jax.eval_shape(serve_step, aparams, tok_spec, acache)
        lsh = _ns(mesh, ctx.spec(("batch", "vocab"), aout[0].shape))
        in_sh = (psh, tsh, csh)
        out_sh = (lsh, csh)

    return Cell(cfg, shape, ctx, serve_step, (aparams, tok_spec, acache),
                in_sh, out_sh, donate=(2,))


def make_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
              **kw) -> Cell:
    if shape.kind == "train":
        return make_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_cell(cfg, shape, mesh)
    return make_decode_cell(cfg, shape, mesh, **kw)
