"""Training driver with JIRIAF fault-tolerance semantics.

Runs a real training loop (reduced configs on CPU; production configs on a
TPU fleet) under a JRM walltime lease: checkpoints periodically AND inside
the §4.5.4 drain margin, survives --kill-at-step (simulated node failure:
process aborts; rerunning resumes from the latest checkpoint), and logs
through the Prometheus-analog registry.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 60 --batch 8 --seq 64 --devices 4 --mesh 2x2 \
      --ckpt-dir /tmp/ckpt [--kill-at-step 30] [--walltime 120]
"""
import argparse
import os
import sys


def _pre_jax():
    # device count must be fixed before jax import
    if "--devices" in sys.argv:
        n = sys.argv[sys.argv.index("--devices") + 1]
        os.environ.setdefault("XLA_FLAGS",
                              f"--xla_force_host_platform_device_count={n}")


_pre_jax()

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.checkpoint import checkpointer as ckpt           # noqa: E402
from repro.configs.base import ShapeConfig, get_config      # noqa: E402
from repro.core.jrm import start_vk                         # noqa: E402
from repro.core.metrics import Registry                     # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticDataset  # noqa: E402
from repro.launch.mesh import make_mesh                     # noqa: E402
from repro.launch.steps import make_train_cell              # noqa: E402
from repro.models import model_api as MA                    # noqa: E402
from repro.optim import adamw                               # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--mesh", default="")            # e.g. "2x2"
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--kill-at-step", type=int, default=-1)
    ap.add_argument("--walltime", type=float, default=0.0)
    ap.add_argument("--step-seconds", type=float, default=1.0,
                    help="simulated seconds per step for the lease clock")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)] if len(dims) == 2 else \
            ("pod", "data", "model")[:len(dims)]
        mesh = make_mesh(dims, axes)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                                total_steps=max(args.steps, 10))
    cell = make_train_cell(cfg, shape, mesh, opt_cfg=opt_cfg,
                           microbatches=args.microbatches)
    step_fn = cell.jit()

    mod = MA.get_module(cfg)
    node = start_vk("jrm-train-0", walltime=args.walltime, now=0.0,
                    nodetype="tpu" if mesh else "cpu")
    reg = Registry()

    # ----- init or resume -----
    start_step = 0
    params = mod.init(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    data = SyntheticDataset(DataConfig(
        batch=args.batch, seq=args.seq, vocab=cfg.vocab,
        frontend_seq=cfg.frontend_seq, d_model=cfg.d_model))
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt, dstate), meta = ckpt.restore(
            args.ckpt_dir, (params, opt, {"step": jnp.zeros((), jnp.int32)}))
        data.restore(dstate)
        start_step = int(meta["step"])
        print(f"[restore] resumed from step {start_step}")
    if mesh is not None:
        params = jax.tree.map(jax.device_put, params,
                              cell.in_shardings[0])
        opt = jax.tree.map(jax.device_put, opt, cell.in_shardings[1])

    losses = []
    now = start_step * args.step_seconds
    for step in range(start_step, args.steps):
        now = step * args.step_seconds
        node.tick(now)
        if not node.ready:
            print(f"[lease] walltime expired at step {step}; stopping")
            break
        draining = node.draining(now)
        batch = data.next_batch(
            cell.in_shardings[2] if mesh is not None else None)
        if args.kill_at_step == step:
            print(f"[failure] simulated node loss at step {step}",
                  flush=True)
            os._exit(42)       # no checkpoint, no cleanup — a real crash
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        reg.gauge("train_loss").set(loss)
        reg.counter("train_steps_total").inc()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        must_ckpt = (args.ckpt_dir and
                     (step % args.ckpt_every == args.ckpt_every - 1 or
                      draining or step == args.steps - 1))
        if must_ckpt:
            ckpt.save(args.ckpt_dir, step + 1,
                      (params, opt, {"step": jnp.asarray(data.step)}),
                      meta={"step": step + 1, "arch": args.arch})
            if draining:
                print(f"[drain] checkpointed at step {step + 1} inside "
                      f"walltime margin; exiting for requeue")
                break
    if len(losses) >= 10:
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
