"""Generate the real-cluster launch assets (paper §5.1/§5.2 analogs).

Emits: <out>/nersc-slurm.sh (staggered multi-node JRM bring-up),
<out>/node-setup.sh (per-node env + SSH tunnels + VK start), and
<out>/deploy-serving.sh (helm-style per-node deployment loop), adapted for
a TPU fleet (one JRM per host, each fronting a slice).

Usage: PYTHONPATH=src python -m repro.launch.slurm --nodes 40 --out launch_assets
"""
import argparse
import pathlib
import stat

SLURM_TMPL = """#!/bin/bash
#SBATCH -N {nodes}
#SBATCH -C {constraint}
#SBATCH -q {qos}
#SBATCH -J jiriaf-tpu
#SBATCH -t {walltime}

# Staggered JRM bring-up (paper 5.1): one srun per node, 3s apart, so the
# control plane is not thundering-herded.
for i in $(seq 1 {nodes})
do
  i_padded=$(printf "%02d" $i)
  echo "launching JRM on node $i_padded"
  srun -N1 {workdir}/node-setup.sh $i_padded &
  sleep 3
done
wait
"""

NODE_TMPL = """#!/bin/bash
# Per-node JRM/VK bring-up (paper 5.1 node-setup.sh, TPU adaptation).
set -euo pipefail
IDX="$1"

export CONTROL_PLANE_IP="{control_plane}"
export APISERVER_PORT="{apiserver_port}"
export NODENAME="vk-tpu$IDX"
export KUBECONFIG="$HOME/run-vk/kubeconfig/$CONTROL_PLANE_IP"
export VKUBELET_POD_IP="172.17.0.1"
export KUBELET_PORT="100$IDX"
export JIRIAF_WALLTIME="{jiriaf_walltime}"   # 60s less than Slurm walltime (4.5.4)
export JIRIAF_NODETYPE="tpu"
export JIRIAF_SITE="{site}"

# SSH tunnels: apiserver (local), kubelet + exporters (remote) — Fig. 3.
ssh -NfL $APISERVER_PORT:localhost:$APISERVER_PORT $CONTROL_PLANE_IP
ssh -NfR $KUBELET_PORT:localhost:$KUBELET_PORT $CONTROL_PLANE_IP
ssh -NfR "200$IDX":localhost:2221 $CONTROL_PLANE_IP   # engine exporter
ssh -NfR "300$IDX":localhost:1776 $CONTROL_PLANE_IP   # process exporter
ssh -NfR "400$IDX":localhost:8088 $CONTROL_PLANE_IP   # transport exporter

# Walltime self-termination (4.3): drain margin handled by the workload's
# checkpoint loop; the VK flips NotReady when alivetime hits zero.
(sleep $JIRIAF_WALLTIME && echo "walltime ended" && kill -TERM $$ ) &

exec python -m repro.launch.jrm_agent \\
  --nodename "$NODENAME" --site "$JIRIAF_SITE" \\
  --walltime "$JIRIAF_WALLTIME" --kubelet-port "$KUBELET_PORT"
"""

DEPLOY_TMPL = """#!/bin/bash
# Serving deployment fan-out (paper 5.2 helm loop analog).
set -euo pipefail
for i in $(seq 1 {nodes})
do
  i_padded=$(printf "%02d" $i)
  echo "deploy serving replica $i_padded"
  PYTHONPATH=src python -m repro.launch.serve --arch {arch} \\
    --devices {devices} --tp {tp} --nodes 1 --ticks 20 &
done
wait
"""


def generate(out_dir, *, nodes=40, arch="qwen2-7b", devices=8, tp=2,
             walltime="03:00:00", qos="regular", site="nersc",
             control_plane="jiriaf2302", apiserver_port=38687):
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    h, m, s = (int(x) for x in walltime.split(":"))
    jiriaf_walltime = max(h * 3600 + m * 60 + s - 60, 0)
    files = {
        "nersc-slurm.sh": SLURM_TMPL.format(
            nodes=nodes, constraint="tpu", qos=qos, walltime=walltime,
            workdir=str(out.resolve())),
        "node-setup.sh": NODE_TMPL.format(
            control_plane=control_plane, apiserver_port=apiserver_port,
            jiriaf_walltime=jiriaf_walltime, site=site),
        "deploy-serving.sh": DEPLOY_TMPL.format(
            nodes=nodes, arch=arch, devices=devices, tp=tp),
    }
    for name, text in files.items():
        p = out / name
        p.write_text(text)
        p.chmod(p.stat().st_mode | stat.S_IEXEC)
    return sorted(files)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=40)
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--out", default="launch_assets")
    ap.add_argument("--walltime", default="03:00:00")
    args = ap.parse_args(argv)
    files = generate(args.out, nodes=args.nodes, arch=args.arch,
                     walltime=args.walltime)
    print(f"wrote {files} to {args.out}")


if __name__ == "__main__":
    main()
