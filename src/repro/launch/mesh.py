"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the ``pod``
    axis carries hierarchical data parallelism across the ICI/DCN boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic re-meshing (e.g. (4, 2))."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
