"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """``axis_types`` only exists on newer jax; older installs default to
    Auto axes anyway, so omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """``jax.set_mesh`` appeared in newer jax; on older installs the Mesh
    object itself is the equivalent context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the ``pod``
    axis carries hierarchical data parallelism across the ICI/DCN boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic re-meshing (e.g. (4, 2))."""
    return _mesh(shape, axes)
