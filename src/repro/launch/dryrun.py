import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

MUST be run as its own process (the device-count flag is set before any jax
import). Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.steps import make_cell
from repro.models import model_api as MA
from repro.roofline import analysis as RA

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir=OUT_DIR,
             overrides=None, tag="") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        kw = dict(overrides or {})
        cell = make_cell(cfg, shape, mesh, **kw)
        with set_mesh(mesh):
            lowered = cell.lower()
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            print(compiled.memory_analysis())
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # older API returned [dict]
                cost = cost[0] if cost else {}
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed", "transcendentals")})
            rec.update(RA.from_compiled(compiled))
            rec["n_devices"] = mesh.size
            n_active = MA.active_param_count(cfg)
            rec["n_params"] = MA.param_count(cfg)
            rec["n_active_params"] = n_active
            rec["model_flops_total"] = RA.model_flops(cfg, shape, n_active)
            rec["model_flops_per_device"] = rec["model_flops_total"] / mesh.size
            hf = rec["roofline"]["flops_per_device"]
            rec["useful_flops_ratio"] = (
                rec["model_flops_per_device"] / hf if hf else 0.0)
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - record the failure, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
    rec["total_s"] = round(time.time() - t0, 2)
    d = pathlib.Path(out_dir) / mesh_name
    d.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    (d / f"{arch}__{shape_name}{suffix}.json").write_text(
        json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    print(f"[{mesh_name}] {arch} x {shape_name}{suffix}: {status} "
          f"({rec['total_s']}s)")
    if status == "error":
        print(rec["error"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch == "all") else [args.arch]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in shapes_for(cfg)]
                  if (args.all or args.shape == "all") else [args.shape])
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "pod"
                fp = (pathlib.Path(args.out) / mesh_name
                      / f"{arch}__{shape_name}.json")
                if args.skip_existing and fp.exists():
                    if json.loads(fp.read_text()).get("status") == "ok":
                        continue
                rec = run_cell(arch, shape_name, mp, out_dir=args.out)
                failures += rec["status"] != "ok"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
