"""End-to-end JIRIAF serving driver — the paper's proof-of-concept (§5)
re-done as a TPU streaming-inference deployment with the §6 digital twin
in the control loop.

Flow (declarative control plane): JFE add_wf -> JCS pilot launch
(staggered JRM/VK bring-up, SSH port map) -> nodes registered in the
Cluster store -> JFM feeds heartbeats as NodeStatus -> StreamEngine
declares an "ersap" Deployment -> DeploymentController + Scheduler
converge pods -> real batched prefill+decode -> Prometheus scrapes ->
DBN twin (or reactive HPA) writes desired replicas on the Deployment as
the arrival rate follows the §6.2 ground-truth pressure trajectory. A
``--walltime`` lease makes the NodeLifecycleController drain nodes
mid-run: checkpoint, evict, reschedule — visible in the event trail.

Multi-site federation: ``--sites "jlab:2,nersc:2"`` brings up one pilot
per facility (JFE multi-site workflow -> JCS launch_multi), the scheduler
spreads replicas across sites latency-aware (``--site-latency``), and
``--kill-site SITE --kill-tick T`` batch-drains a whole facility mid-run
— its replicas checkpoint and reschedule cross-site with zero request
loss. ``--reprovision`` lets the JCS top up any site whose walltime
runway drops below projected demand — now also sized from the live
serving queue backlog and capacity-starved pending pods (pair with
``--walltime`` to watch the fleet survive perpetual lease churn).

QoS mixed-workload mode: ``--batch-load N`` runs N preemptible batch
pods (priority class ``batch``, one chip each, with a checkpointable
progress counter) next to the serving Deployment; during pressure
spikes the twin escalates serving to ``latency-critical`` (written via
``cluster.set_priority``) so serving scale-ups preempt batch work —
victims checkpoint, requeue, and resume when the spike passes.
``--priority-class`` sets serving's initial tier; ``--quota`` applies
fair-share caps (e.g. ``"ersap:chips=8,batch:chips=6"``).

Paged-slab extras: ``--prefix-cache`` turns on reference-counted
prefix-sharing admission (matching prompts splice onto in-flight pages,
copy-on-write on divergence); ``--spec-decode K`` drafts K tokens per
row and verifies them in one (K+1)-wide dispatch. Both require
``--paged``; end-of-run stats report hit and accept rates.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --devices 8 \
      --tp 2 --nodes 4 --ticks 80 [--controller hpa] [--walltime 300] \
      [--sites "jlab:2,nersc:2" --site-latency "jlab:nersc:40" \
       --kill-site jlab --kill-tick 40] \
      [--batch-load 6 --quota "ersap:chips=6,batch:chips=6"]
"""
import argparse
import json
import os
import sys


def _pre_jax():
    if "--devices" in sys.argv:
        n = sys.argv[sys.argv.index("--devices") + 1]
        os.environ.setdefault("XLA_FLAGS",
                              f"--xla_force_host_platform_device_count={n}")


_pre_jax()

import jax                                        # noqa: E402
import numpy as np                                # noqa: E402

from repro.configs.base import get_config         # noqa: E402
from repro.core import qos                        # noqa: E402
from repro.core.cluster import Cluster            # noqa: E402
from repro.core.controllers import ControlPlane   # noqa: E402
from repro.core.elastic import ElasticServing     # noqa: E402
from repro.core.hpa import HPA, HPAConfig         # noqa: E402
from repro.core.jcs import CentralService         # noqa: E402
from repro.core.jfe import FrontEnd               # noqa: E402
from repro.core.jfm import FacilityManager        # noqa: E402
from repro.core.jrm import SliceSpec              # noqa: E402
from repro.core.scheduler import Scheduler, SiteTopology  # noqa: E402
from repro.core.digital_twin.queue_model import ground_truth, lam_of_state  # noqa: E402
from repro.data.pipeline import RequestSource     # noqa: E402
from repro.models import model_api as MA          # noqa: E402
from repro.streaming.engine import StreamEngine   # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=80)
    ap.add_argument("--dt", type=float, default=10.0)
    ap.add_argument("--controller", choices=["twin", "hpa"], default="twin")
    ap.add_argument("--lam-scale", type=float, default=0.02,
                    help="arrival rate = lam_of_state(s) * scale req/s")
    ap.add_argument("--walltime", type=float, default=0.0,
                    help="per-node lease (s); >0 exercises the drain ->"
                         " checkpoint -> reschedule loop mid-run")
    ap.add_argument("--sites", default="",
                    help='multi-site pilot spec "site:nnodes,..." (e.g.'
                         ' "jlab:2,nersc:2"); overrides --nodes')
    ap.add_argument("--site-latency", default="",
                    help='inter-site latency matrix "a:b:ms,..." for'
                         " latency-weighted cross-site spreading")
    ap.add_argument("--kill-site", default="",
                    help="batch-drain this whole site at --kill-tick"
                         " (checkpoint/evict wave, cross-site reschedule)")
    ap.add_argument("--kill-tick", type=int, default=-1)
    ap.add_argument("--reprovision", action="store_true",
                    help="JCS proactively launches a fresh pilot when a"
                         " site's walltime runway drops below projected"
                         " demand — sized from live queue backlog and"
                         " capacity-starved pods too (pair with"
                         " --walltime)")
    ap.add_argument("--priority-class", default="standard",
                    choices=["batch", "standard", "latency-critical",
                             "system"],
                    help="serving Deployment's initial QoS tier (the twin"
                         " escalates to latency-critical under pressure)")
    ap.add_argument("--quota", default="",
                    help='fair-share quotas "owner[@site]:chips=N'
                         '[:hbm_gb=G][:kv_pages=P],..." enforced as a'
                         " scheduler filter stage")
    ap.add_argument("--batch-load", type=int, default=0,
                    help="mixed-workload mode: run this many preemptible"
                         " batch pods (priority class batch, 1 chip each,"
                         " checkpointable progress) next to serving")
    ap.add_argument("--no-runtime", action="store_true",
                    help="disable the slot-slab serving runtime (fall back"
                         " to the chunked prefill+decode path)")
    ap.add_argument("--vary-shapes", action="store_true",
                    help="randomize per-request prompt_len/max_new (the"
                         " workload bucketed compilation is built for)")
    ap.add_argument("--kernel-mode", choices=["auto", "pallas", "jnp"],
                    default="auto",
                    help="decode-attention dispatch: auto picks Pallas on"
                         " TPU and the jnp block-skip path elsewhere;"
                         " pallas forces the kernels (interpret off-TPU)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV slab: per-request page allocation, "
                         "decode reads only the live kv bucket — wins when"
                         " capacity is provisioned well beyond typical"
                         " request depth (see bench_paged_decode); the"
                         " dense slab with adaptive block-skip is default")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV entries per physical page of the paged slab")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical KV pages per replica (0 = enough for"
                         " max_batch full-capacity requests)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="prefix-sharing admission for the paged slab:"
                         " prompts whose page-aligned prefix matches an"
                         " in-flight request splice onto the existing pages"
                         " (refcounted, copy-on-write) instead of re-running"
                         " prefill — requires --paged")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="multi-token speculative decode: draft K tokens per"
                         " row and verify them in one (K+1)-wide paged"
                         " dispatch (greedy accept-prefix, token-identical"
                         " to one-at-a-time) — requires --paged")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="traffic shaping: fraction of requests that join a"
                         " shared-prefix template group (makes"
                         " --prefix-cache hits visible from the driver)")
    ap.add_argument("--chaos", default="",
                    help='comma-separated fault schedule "kind:target@at'
                         '[+duration][x<mag>]" (kinds: crash, flap,'
                         " partition, straggler, ckpt_corrupt,"
                         " walltime_cut, surge — surge multiplies the"
                         ' arrival rate by <mag>; target "*" picks a'
                         ' seeded victim), e.g.'
                         ' "partition:n0@120+45,surge:ersap@300+100x6".'
                         " Replaces the heartbeat/JFM block with the"
                         " FaultInjector seam, enables background"
                         " checkpoints, and audits bookkeeping invariants"
                         " every tick")
    ap.add_argument("--event-budget", type=int, default=0,
                    help="cap dirty objects reconciled per controller per"
                         " tick (0 = unbounded); excess carries to the"
                         " next tick — bounds per-tick reconcile latency"
                         " at large fleet sizes")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help='seed for "*" victim selection (same schedule +'
                         " seed => identical fault storm)")
    ap.add_argument("--deadline", type=float, default=0.0, metavar="TTL",
                    help="per-request time-to-live (s): requests carry"
                         " deadline = arrival + TTL and are shed before"
                         " prefill once expired (0 disables)")
    ap.add_argument("--brownout", action="store_true",
                    help="overload protection: bounded arrival queue with"
                         " backpressure, watermark+hysteresis brownout"
                         " (cap max_new, disable spec decode, shed low"
                         " tiers first), and per-replica circuit breakers")
    ap.add_argument("--retry-budget", type=float, default=0.0,
                    metavar="RATE",
                    help="per-tenant retry token-bucket refill rate (/s):"
                         " backpressured retries beyond the budget are"
                         " shed instead of re-queued (0 disables)")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="arrival FIFO bound (0 = unbounded; --brownout"
                         " defaults it to 64 x service capacity)")
    ap.add_argument("--trace", action="store_true",
                    help="request-lifecycle tracing: every hop of every"
                         " request (enqueue/admit/prefill/decode/drain/"
                         "restore/retire + control-plane spans) lands in"
                         " a bounded span ring with an SLO flight"
                         " recorder on top")
    ap.add_argument("--trace-out", default="", metavar="FILE",
                    help="write the flight-recorder dump (span ring +"
                         " events + incidents) as JSON at end of run;"
                         " implies --trace. Render with tools/tracedump.py")
    ap.add_argument("--metrics-out", default="", metavar="FILE",
                    help="dump the full metric pipeline as Prometheus"
                         ' text exposition at end of run ("-" = stdout)')
    ap.add_argument("--incident-dir", default="", metavar="DIR",
                    help="flight recorder auto-dumps incident bundles"
                         " (SLO breach / invariant violation) here")
    ap.add_argument("--slo-p99", type=float, default=0.0, metavar="S",
                    help="latency-critical p99 completion-latency SLO (s):"
                         " a burn-rate breach trips a flight-recorder"
                         " incident (0 disables)")
    ap.add_argument("--site-bandwidth", default="",
                    help='inter-site bandwidth matrix "a:b:gbps,..." for'
                         " the checkpoint transfer-cost model paid by"
                         " drain_site failover and preemption ranking"
                         " (pairs with --site-latency)")
    args = ap.parse_args(argv)
    if (args.prefix_cache or args.spec_decode) and not args.paged:
        ap.error("--prefix-cache/--spec-decode require --paged (they are"
                 " page-table features of the paged KV slab)")
    if args.spec_decode < 0:
        ap.error("--spec-decode must be >= 0")
    if args.kill_site:
        if not (0 <= args.kill_tick < args.ticks):
            ap.error("--kill-site needs --kill-tick in [0, --ticks)")
        known = {part.split(":")[0].strip()
                 for part in args.sites.split(",") if part.strip()}
        if args.kill_site not in known:
            ap.error(f"--kill-site {args.kill_site!r} not in --sites spec")

    cfg = get_config(args.arch).reduced()

    # kernel dispatch is resolved once, before any jit closure is traced
    from repro.kernels import ops as OPS
    OPS.set_kernel_mode(args.kernel_mode)
    print(f"[kernels] mode={args.kernel_mode} "
          f"(resolved {OPS.resolved_mode()}; backend={jax.default_backend()}"
          f"{'' if OPS.on_tpu() else ', pallas would run interpreted'}); "
          f"paged={'on' if args.paged else 'off'} "
          f"prefix_cache={'on' if args.prefix_cache else 'off'} "
          f"spec_decode={args.spec_decode or 'off'}")

    # ---- JIRIAF control plane bring-up (paper §3 component flow) ----
    fe = FrontEnd()
    jcs = CentralService(fe)
    cluster = Cluster()
    if args.sites:
        site_nodes = {s: int(n) for s, n in
                      (part.split(":") for part in args.sites.split(","))}
        n_nodes = sum(site_nodes.values())
        wfs = fe.add_multi_wf("vk-tpu-", site_nodes, nodetype="tpu",
                              walltime=args.walltime)
        pilots = jcs.launch_multi(
            wfs, now=0.0, cluster=cluster,
            slice_spec=SliceSpec(chips=max(args.devices // n_nodes, 1)))
    else:
        wf = fe.add_wf("vk-tpu-", args.nodes, nodetype="tpu", site="tpu-pod",
                       walltime=args.walltime)
        pilots = [jcs.launch_pilot(wf, now=0.0, cluster=cluster,
                                   slice_spec=SliceSpec(
                                       chips=max(args.devices // args.nodes,
                                                 1)))]
    nodes = jcs.node_list()
    for n in nodes:
        cluster.heartbeat(n.name, 0.0)
    fm = FacilityManager()
    fm.feed(cluster, 0.0)
    topo = SiteTopology.parse(args.site_latency, "", args.site_bandwidth) \
        if (args.site_latency or args.site_bandwidth) else None
    plane = ControlPlane(cluster, scheduler=Scheduler(cluster,
                                                      topology=topo),
                         event_budget=args.event_budget)
    for pilot in pilots:
        print(f"[jcs] pilot {pilot.wf_id}: {len(pilot.nodes)} JRM nodes, "
              f"{len(pilot.tunnels)} SSH tunnels")
    for site, view in cluster.site_views(0.0).items():
        print(f"[site] {site}: {view.ready_nodes}/{view.nodes} ready, "
              f"{view.free_chips} free chips, "
              f"runway={view.remaining_walltime:.0f}s")
    print(f"[jfm] pool: {fm.total_free_chips()} free chips on "
          f"{len(fm.available())} ready nodes")

    # ---- model + elastic serving ----
    mod = MA.get_module(cfg)
    host_params = jax.tree.map(np.asarray,
                               mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=args.tp)
    serving.build(1, host_params=host_params)
    # service rate per replica = mu(16 threads) scaled like the arrivals, so
    # one replica is near-critical at high pressure (M/M/1 analog) and the
    # twin's 2x escalation actually drains the queue.
    mu_scaled = 167.0 * args.lam_scale
    src_kw = {}
    if args.prefix_share > 0:
        # share at least one full page so hits splice real KV, not just
        # the intern-table bookkeeping
        src_kw = dict(prefix_share=args.prefix_share,
                      prefix_len=args.page_size, prefix_groups=4)
    if args.deadline > 0:
        src_kw["ttl"] = args.deadline
    source = RequestSource(**src_kw)
    if args.vary_shapes:
        source = RequestSource(prompt_range=(8, 48), max_new_range=(2, 16),
                               **src_kw)
    from repro.streaming.runtime import RuntimeConfig
    engine = StreamEngine(cfg, serving, nodes,
                          service_rate=mu_scaled,
                          use_twin=(args.controller == "twin"),
                          use_runtime=not args.no_runtime,
                          priority_class=args.priority_class,
                          runtime_cfg=RuntimeConfig(
                              paged=args.paged,
                              page_size=args.page_size,
                              pool_pages=args.pool_pages,
                              prefix_cache=args.prefix_cache,
                              spec_decode=args.spec_decode,
                              # spec acceptance is resolved per round on the
                              # host; the fused admission tail would race it
                              admit_tail=0 if args.spec_decode else 4),
                          source=source,
                          hpa=HPA(HPAConfig(target=8.0, max_replicas=
                                            serving.max_replicas(),
                                            scale_down_stabilization=120.0,
                                            occupancy_target=0.85)),
                          cluster=cluster, plane=plane)
    # ---- overload protection layer (opt-in) ----
    if args.brownout:
        engine.brownout = qos.BrownoutController(delay_target_s=3 * args.dt)
        engine.breaker = qos.ReplicaBreaker(probe_after_s=3 * args.dt)
        engine.queue_cap = args.queue_cap or int(64 * mu_scaled * args.dt)
    elif args.queue_cap:
        engine.queue_cap = args.queue_cap
    if args.retry_budget > 0:
        engine.retry_budget = qos.RetryBudget(rate=args.retry_budget)
        if not engine.queue_cap:
            engine.queue_cap = int(64 * mu_scaled * args.dt)
    if engine.queue_cap or args.brownout or args.deadline:
        print(f"[overload] queue_cap={engine.queue_cap or 'off'} "
              f"brownout={'on' if args.brownout else 'off'} "
              f"retry_budget={args.retry_budget or 'off'}/s "
              f"deadline={args.deadline or 'off'}s")
    # the chosen class is the twin policy's *resting* tier (otherwise the
    # first calm control step would demote a user-chosen tier back to
    # "standard"); a class at/above the escalation tier also becomes the
    # escalation target so pressure never demotes it
    engine.policy.prio_low = args.priority_class
    if cluster.resolve_priority(args.priority_class).value >= \
            cluster.resolve_priority(engine.policy.prio_high).value:
        engine.policy.prio_high = args.priority_class
    if args.quota:
        for q in qos.parse_quotas(args.quota):
            cluster.apply_quota(q, 0.0)
            print(f"[qos] quota {q.owner}"
                  f"{'@' + q.site if q.site else ''}: chips={q.chips} "
                  f"hbm={q.hbm_bytes} kv_pages={q.kv_pages}")
    # ---- unified observability plane (opt-in tracing, always-on profiler) --
    from repro.core.observability import FlightRecorder, SLOConfig, \
        TickProfiler
    from repro.core.tracing import Tracer
    profiler = TickProfiler()
    tracer = recorder = None
    if args.trace or args.trace_out or args.incident_dir or args.slo_p99 > 0:
        tracer = Tracer()
        recorder = FlightRecorder(
            tracer, slo=SLOConfig(lc_p99_s=args.slo_p99),
            dump_dir=args.incident_dir or None)
        print(f"[trace] lifecycle tracing on (ring={tracer.cap} spans); "
              f"slo_p99={args.slo_p99 or 'off'} "
              f"incident_dir={args.incident_dir or 'off'}")
    # wired before deploy so initial schedule/bind spans are captured
    engine.enable_observability(tracer=tracer, recorder=recorder,
                                profiler=profiler)
    engine.deploy(0.0)
    print(f"[scheduler] {len(engine.pods)} serving pods bound; "
          f"controller={args.controller} "
          f"priority={args.priority_class}")

    # ---- mixed-workload batch tenant (QoS preemption target) ----
    batch = None
    if args.batch_load:
        batch = qos.BatchTenant(cluster, args.batch_load, now=0.0)
        engine.reconcile(0.0)
        print(f"[qos] batch tenant: {batch.bound}/{args.batch_load}"
              f" preemptible pods bound")

    # ---- chaos fault injection (seeded, declarative schedule) ----
    injector = auditor = None
    if args.chaos:
        import tempfile
        from repro.core.chaos import FaultInjector, InvariantAuditor
        if not plane.nodes.ckpt_dir:
            plane.nodes.ckpt_dir = tempfile.mkdtemp(prefix="serve-chaos-")
        if plane.nodes.bg_checkpoint_every <= 0:
            # periodic snapshots bound how far a crash can roll back
            plane.nodes.bg_checkpoint_every = args.dt
        injector = FaultInjector(
            schedule=[s.strip() for s in args.chaos.split(",") if s.strip()],
            seed=args.chaos_seed, ckpt_dir=plane.nodes.ckpt_dir)
        auditor = InvariantAuditor(cluster, engine, recorder=recorder)
        print(f"[chaos] {len(injector.schedule)} faults scheduled "
              f"(seed={args.chaos_seed}); bg checkpoints every "
              f"{plane.nodes.bg_checkpoint_every:.0f}s -> "
              f"{plane.nodes.ckpt_dir}")

    # ---- drive with the §6.2 pressure trajectory ----
    gt = ground_truth(args.ticks)
    killed_sites = set()
    for t, s in enumerate(gt):
        now = t * args.dt
        lam = lam_of_state(s) * args.lam_scale
        if args.kill_site and t == args.kill_tick:
            print(f"[federation] t={t}: batch-draining site "
                  f"{args.kill_site} ({len(cluster.site_nodes(args.kill_site))}"
                  f" nodes) — cross-site failover")
            plane.drain_site(args.kill_site, now)
            killed_sites.add(args.kill_site)
        if args.reprovision:
            for pilot in jcs.reprovision(
                    cluster, now, horizon=args.walltime or 600.0,
                    walltime=args.walltime or 600.0,
                    queue_backlog=len(engine.queue),
                    # per-replica rate: backlog/rate is pod-seconds of
                    # work, the same unit projected_demand sums
                    service_rate=mu_scaled):
                wf = fe.table[pilot.wf_id]
                print(f"[jcs] t={t}: demand high at {wf.site} — reprovision"
                      f" pilot {pilot.wf_id} ({len(pilot.nodes)} nodes)")
        if injector is not None:
            # one chaos tick: fire due faults, drive heartbeats for every
            # node that can still send them, feed the JFM, overlay flaps
            injector.apply(cluster, now, fm=fm)
            # flash-crowd seam: active surge windows multiply the ersap
            # stream's arrival rate through the real RequestSource
            engine.source.surge = injector.surge_factor("ersap")
        else:
            for name, node in cluster.nodes.items():
                if node.site not in killed_sites:
                    cluster.heartbeat(name, now)
            fm.feed(cluster, now)
        engine.reconcile(now)          # controllers converge every tick
        if batch is not None:
            batch.advance()            # bound pods progress; resumed pods
            #                            recover from their checkpoint
        qlen = engine.tick(now, args.dt, lam)
        if auditor is not None:
            with profiler.phase("tick.audit"):
                auditor.audit(now)     # books must balance on every tick
        if recorder is not None:
            recorder.check(now)        # burn-rate SLO evaluation
        if t % 2 == 1:
            engine.control_step(now)
        if t % 10 == 0:
            print(f"t={t:3d} state={s:.1f} lam={lam:6.1f} queue={qlen:4d} "
                  f"replicas={engine.serving.replicas} "
                  f"control={engine.control} served={engine.total_served}")

    lat = [engine.registries[r].histogram("ersap_latency_s").mean
           for r in engine.registries if
           engine.registries[r].metrics.get("ersap_latency_s")]
    print(f"[done] served={engine.total_served} requests, "
          f"{engine.total_tokens} tokens; "
          f"scale events={engine.serving.scale_events}; "
          f"mean latency={np.mean(lat) if lat else 0:.1f}s; "
          f"final queue={len(engine.queue)}")
    if engine.runtimes:
        rt = next(iter(engine.runtimes.values()))
        tc = rt.kernels.trace_counts
        blocks = sum(r.steps_dispatched for r in engine.runtimes.values())
        print(f"[runtime] slot-slab serving: traces admit={tc['admit']} "
              f"decode={tc['decode']} (bound {rt.kernels.max_traces}); "
              f"fused blocks={blocks}")
        if rt.kernels.rcfg.paged:
            hwm = max(r.pages_hwm for r in engine.runtimes.values())
            rc = rt.kernels.rcfg
            print(f"[runtime] paged KV slab: page_size={rc.page_size} "
                  f"pool={rc.n_pool_pages} pages/replica; "
                  f"high-water={hwm} pages "
                  f"({hwm * rc.page_size} KV entries vs "
                  f"{(rc.max_batch + 1) * rc.capacity} dense)")
            if rc.prefix_cache:
                hits = sum(r.prefix_hits for r in engine.runtimes.values())
                looks = sum(r.prefix_lookups
                            for r in engine.runtimes.values())
                cows = sum(r.cow_events for r in engine.runtimes.values())
                print(f"[runtime] prefix cache: {hits}/{looks} admission "
                      f"hits; {cows} copy-on-write events; "
                      f"traces splice={tc['splice']} window={tc['window']} "
                      f"cow={tc['cow']}")
            if rc.spec_decode:
                drafted = sum(r.spec_drafted
                              for r in engine.runtimes.values())
                accepted = sum(r.spec_accepted
                               for r in engine.runtimes.values())
                rate = accepted / max(drafted, 1)
                print(f"[runtime] speculative decode: k={rc.spec_decode} "
                      f"drafted={drafted} accepted={accepted} "
                      f"(accept rate {rate:.2f})")
    if engine.queue_cap or engine.brownout is not None or \
            engine.retry_budget is not None or args.deadline:
        bl = engine.brownout.level if engine.brownout is not None else 0
        trans = len(engine.brownout.transitions) \
            if engine.brownout is not None else 0
        print(f"[overload] shed={dict(sorted(engine.shed_counts.items()))} "
              f"rejected={engine.rejected_total} "
              f"retried={engine.retried_total} "
              f"brownout_level={bl} transitions={trans} "
              f"transfer_windows={engine.transfer_windows}")
        if engine.breaker is not None and engine.breaker.ejections:
            print(f"[overload] breaker: {engine.breaker.ejections} ejected,"
                  f" {engine.breaker.rejoins} rejoined")
    if len(cluster.site_names()) > 1:
        per_site = {}
        for pod in engine.pods.values():
            node = cluster.nodes.get(pod.node)
            if node is not None:
                per_site[node.site] = per_site.get(node.site, 0) + 1
        print(f"[federation] replicas by site: {dict(sorted(per_site.items()))}")
    trail = {}
    for ev in cluster.events:
        trail[ev.reason] = trail.get(ev.reason, 0) + 1
    print(f"[events] {dict(sorted(trail.items()))}")
    if injector is not None:
        fired = {}
        for _, kind, target in injector.log:
            fired[kind] = fired.get(kind, 0) + 1
        print(f"[chaos] faults fired: {dict(sorted(fired.items()))}; "
              f"audits passed={auditor.checks}; "
              f"fence floors outstanding={len(cluster.fence_epochs)}")
    if batch is not None:
        print(f"[qos] batch: {batch.bound}/{args.batch_load} bound at end, "
              f"{trail.get('Preempted', 0)} preemptions, "
              f"{trail.get('PriorityChanged', 0)} priority writes, "
              f"{len(batch.resumed)} resumed from checkpoint, "
              f"total progress={batch.total_progress}")
        books = cluster.ledger.assert_balanced()
        print(f"[qos] quota books: chips {books['chips_used']} used + "
              f"{books['chips_free']} free == {books['chips_capacity']}")
    prof = profiler.summary()
    if prof:
        top = sorted(prof.items(), key=lambda kv: -kv[1]["total_s"])
        print("[profile] " + " ".join(
            f"{name}={p['total_s']:.3f}s/{p['calls']}" for name, p in top))
    if recorder is not None:
        spans = recorder.tracer.spans
        rids = {s.rid for s in spans if s.rid}
        print(f"[trace] {len(spans)} spans across {len(rids)} requests "
              f"({recorder.tracer.dropped} dropped); "
              f"{len(recorder.incidents)} incidents")
        if args.trace_out:
            with open(args.trace_out, "w") as fh:
                json.dump(recorder.dump(), fh)
            print(f"[trace] flight-recorder dump -> {args.trace_out}")
    if args.metrics_out:
        text = engine.exposition()
        if args.metrics_out == "-":
            print(text, end="")
        else:
            with open(args.metrics_out, "w") as fh:
                fh.write(text)
            print(f"[metrics] prometheus exposition "
                  f"({len(text.splitlines())} lines) -> {args.metrics_out}")
    return engine


if __name__ == "__main__":
    main()
