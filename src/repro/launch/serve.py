"""End-to-end JIRIAF serving driver — the paper's proof-of-concept (§5)
re-done as a TPU streaming-inference deployment with the §6 digital twin
in the control loop.

Flow (declarative control plane): JFE add_wf -> JCS pilot launch
(staggered JRM/VK bring-up, SSH port map) -> nodes registered in the
Cluster store -> JFM feeds heartbeats as NodeStatus -> StreamEngine
declares an "ersap" Deployment -> DeploymentController + Scheduler
converge pods -> real batched prefill+decode -> Prometheus scrapes ->
DBN twin (or reactive HPA) writes desired replicas on the Deployment as
the arrival rate follows the §6.2 ground-truth pressure trajectory. A
``--walltime`` lease makes the NodeLifecycleController drain nodes
mid-run: checkpoint, evict, reschedule — visible in the event trail.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --devices 8 \
      --tp 2 --nodes 4 --ticks 80 [--controller hpa] [--walltime 300]
"""
import argparse
import os
import sys


def _pre_jax():
    if "--devices" in sys.argv:
        n = sys.argv[sys.argv.index("--devices") + 1]
        os.environ.setdefault("XLA_FLAGS",
                              f"--xla_force_host_platform_device_count={n}")


_pre_jax()

import jax                                        # noqa: E402
import numpy as np                                # noqa: E402

from repro.configs.base import get_config         # noqa: E402
from repro.core.cluster import Cluster            # noqa: E402
from repro.core.elastic import ElasticServing     # noqa: E402
from repro.core.hpa import HPA, HPAConfig         # noqa: E402
from repro.core.jcs import CentralService         # noqa: E402
from repro.core.jfe import FrontEnd               # noqa: E402
from repro.core.jfm import FacilityManager        # noqa: E402
from repro.core.jrm import SliceSpec              # noqa: E402
from repro.core.digital_twin.queue_model import ground_truth, lam_of_state  # noqa: E402
from repro.data.pipeline import RequestSource     # noqa: E402
from repro.models import model_api as MA          # noqa: E402
from repro.streaming.engine import StreamEngine   # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=80)
    ap.add_argument("--dt", type=float, default=10.0)
    ap.add_argument("--controller", choices=["twin", "hpa"], default="twin")
    ap.add_argument("--lam-scale", type=float, default=0.02,
                    help="arrival rate = lam_of_state(s) * scale req/s")
    ap.add_argument("--walltime", type=float, default=0.0,
                    help="per-node lease (s); >0 exercises the drain ->"
                         " checkpoint -> reschedule loop mid-run")
    ap.add_argument("--no-runtime", action="store_true",
                    help="disable the slot-slab serving runtime (fall back"
                         " to the chunked prefill+decode path)")
    ap.add_argument("--vary-shapes", action="store_true",
                    help="randomize per-request prompt_len/max_new (the"
                         " workload bucketed compilation is built for)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()

    # ---- JIRIAF control plane bring-up (paper §3 component flow) ----
    fe = FrontEnd()
    wf = fe.add_wf("vk-tpu-", args.nodes, nodetype="tpu", site="tpu-pod",
                   walltime=args.walltime)
    jcs = CentralService(fe)
    pilot = jcs.launch_pilot(wf, now=0.0, slice_spec=SliceSpec(
        chips=max(args.devices // args.nodes, 1)))
    nodes = jcs.node_list()
    cluster = Cluster()
    for n in nodes:
        cluster.register_node(n, 0.0)
        cluster.heartbeat(n.name, 0.0)
    fm = FacilityManager()
    fm.feed(cluster, 0.0)
    print(f"[jcs] pilot {pilot.wf_id}: {len(pilot.nodes)} JRM nodes, "
          f"{len(pilot.tunnels)} SSH tunnels")
    print(f"[jfm] pool: {fm.total_free_chips()} free chips on "
          f"{len(fm.available())} ready nodes")

    # ---- model + elastic serving ----
    mod = MA.get_module(cfg)
    host_params = jax.tree.map(np.asarray,
                               mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=args.tp)
    serving.build(1, host_params=host_params)
    # service rate per replica = mu(16 threads) scaled like the arrivals, so
    # one replica is near-critical at high pressure (M/M/1 analog) and the
    # twin's 2x escalation actually drains the queue.
    mu_scaled = 167.0 * args.lam_scale
    source = RequestSource()
    if args.vary_shapes:
        source = RequestSource(prompt_range=(8, 48), max_new_range=(2, 16))
    engine = StreamEngine(cfg, serving, nodes,
                          service_rate=mu_scaled,
                          use_twin=(args.controller == "twin"),
                          use_runtime=not args.no_runtime,
                          source=source,
                          hpa=HPA(HPAConfig(target=8.0, max_replicas=
                                            serving.max_replicas(),
                                            scale_down_stabilization=120.0)),
                          cluster=cluster)
    engine.deploy(0.0)
    print(f"[scheduler] {len(engine.pods)} serving pods bound; "
          f"controller={args.controller}")

    # ---- drive with the §6.2 pressure trajectory ----
    gt = ground_truth(args.ticks)
    for t, s in enumerate(gt):
        now = t * args.dt
        lam = lam_of_state(s) * args.lam_scale
        for n in nodes:
            cluster.heartbeat(n.name, now)
        fm.feed(cluster, now)
        engine.reconcile(now)          # controllers converge every tick
        qlen = engine.tick(now, args.dt, lam)
        if t % 2 == 1:
            engine.control_step(now)
        if t % 10 == 0:
            print(f"t={t:3d} state={s:.1f} lam={lam:6.1f} queue={qlen:4d} "
                  f"replicas={engine.serving.replicas} "
                  f"control={engine.control} served={engine.total_served}")

    lat = [engine.registries[r].histogram("ersap_latency_s").mean
           for r in engine.registries if
           engine.registries[r].metrics.get("ersap_latency_s")]
    print(f"[done] served={engine.total_served} requests, "
          f"{engine.total_tokens} tokens; "
          f"scale events={engine.serving.scale_events}; "
          f"mean latency={np.mean(lat) if lat else 0:.1f}s; "
          f"final queue={len(engine.queue)}")
    if engine.runtimes:
        rt = next(iter(engine.runtimes.values()))
        tc = rt.kernels.trace_counts
        blocks = sum(r.steps_dispatched for r in engine.runtimes.values())
        print(f"[runtime] slot-slab serving: traces admit={tc['admit']} "
              f"decode={tc['decode']} (bound {rt.kernels.max_traces}); "
              f"fused blocks={blocks}")
    trail = {}
    for ev in cluster.events:
        trail[ev.reason] = trail.get(ev.reason, 0) + 1
    print(f"[events] {dict(sorted(trail.items()))}")
    return engine


if __name__ == "__main__":
    main()
