"""End-to-end JIRIAF serving driver — the paper's proof-of-concept (§5)
re-done as a TPU streaming-inference deployment with the §6 digital twin
in the control loop.

Flow: JFE add_wf -> JCS pilot launch (staggered JRM/VK bring-up, SSH port
map) -> JFM scrape -> JMS binds serving pods -> StreamEngine serves real
batched prefill+decode -> Prometheus scrapes -> DBN twin (or reactive HPA)
drives elastic replica scaling as the arrival rate follows the §6.2
ground-truth pressure trajectory.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --devices 8 \
      --tp 2 --nodes 4 --ticks 80 [--controller hpa]
"""
import argparse
import os
import sys


def _pre_jax():
    if "--devices" in sys.argv:
        n = sys.argv[sys.argv.index("--devices") + 1]
        os.environ.setdefault("XLA_FLAGS",
                              f"--xla_force_host_platform_device_count={n}")


_pre_jax()

import jax                                        # noqa: E402
import numpy as np                                # noqa: E402

from repro.configs.base import get_config         # noqa: E402
from repro.core.elastic import ElasticServing     # noqa: E402
from repro.core.hpa import HPA, HPAConfig         # noqa: E402
from repro.core.jcs import CentralService         # noqa: E402
from repro.core.jfe import FrontEnd               # noqa: E402
from repro.core.jfm import FacilityManager        # noqa: E402
from repro.core.jms import MatchingService        # noqa: E402
from repro.core.jrm import SliceSpec              # noqa: E402
from repro.core.digital_twin.queue_model import ground_truth, lam_of_state  # noqa: E402
from repro.models import model_api as MA          # noqa: E402
from repro.streaming.engine import StreamEngine   # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=80)
    ap.add_argument("--dt", type=float, default=10.0)
    ap.add_argument("--controller", choices=["twin", "hpa"], default="twin")
    ap.add_argument("--lam-scale", type=float, default=0.02,
                    help="arrival rate = lam_of_state(s) * scale req/s")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()

    # ---- JIRIAF control plane bring-up (paper §3 component flow) ----
    fe = FrontEnd()
    wf = fe.add_wf("vk-tpu-", args.nodes, nodetype="tpu", site="tpu-pod",
                   walltime=0.0)
    jcs = CentralService(fe)
    pilot = jcs.launch_pilot(wf, now=0.0, slice_spec=SliceSpec(
        chips=max(args.devices // args.nodes, 1)))
    nodes = jcs.node_list()
    fm = FacilityManager()
    jms = MatchingService(fm)
    for n in nodes:
        n.tick(0.0)
    fm.scrape(nodes, 0.0)
    print(f"[jcs] pilot {pilot.wf_id}: {len(pilot.nodes)} JRM nodes, "
          f"{len(pilot.tunnels)} SSH tunnels")
    print(f"[jfm] pool: {fm.total_free_chips()} free chips on "
          f"{len(fm.available())} ready nodes")

    # ---- model + elastic serving ----
    mod = MA.get_module(cfg)
    host_params = jax.tree.map(np.asarray,
                               mod.init(jax.random.PRNGKey(0), cfg))
    serving = ElasticServing(cfg, tp=args.tp)
    serving.build(1, host_params=host_params)
    # service rate per replica = mu(16 threads) scaled like the arrivals, so
    # one replica is near-critical at high pressure (M/M/1 analog) and the
    # twin's 2x escalation actually drains the queue.
    mu_scaled = 167.0 * args.lam_scale
    engine = StreamEngine(cfg, serving, nodes,
                          service_rate=mu_scaled,
                          use_twin=(args.controller == "twin"),
                          hpa=HPA(HPAConfig(target=8.0, max_replicas=
                                            serving.max_replicas(),
                                            scale_down_stabilization=120.0)))
    engine.deploy(0.0)
    print(f"[jms] {len(engine.pods)} serving pods bound; "
          f"controller={args.controller}")

    # ---- drive with the §6.2 pressure trajectory ----
    gt = ground_truth(args.ticks)
    for t, s in enumerate(gt):
        now = t * args.dt
        lam = lam_of_state(s) * args.lam_scale
        qlen = engine.tick(now, args.dt, lam)
        if t % 2 == 1:
            engine.control_step(now)
        for n in nodes:
            n.tick(now)
        fm.scrape(nodes, now)
        if t % 10 == 0:
            served = sum(st.served for st in engine.stats.values())
            print(f"t={t:3d} state={s:.1f} lam={lam:6.1f} queue={qlen:4d} "
                  f"replicas={engine.serving.replicas} "
                  f"control={engine.control} served={served}")

    served = sum(st.served for st in engine.stats.values())
    toks = sum(st.tokens for st in engine.stats.values())
    lat = [engine.registries[r].histogram("ersap_latency_s").mean
           for r in engine.registries if
           engine.registries[r].metrics.get("ersap_latency_s")]
    print(f"[done] served={served} requests, {toks} tokens; "
          f"scale events={engine.serving.scale_events}; "
          f"mean latency={np.mean(lat) if lat else 0:.1f}s; "
          f"final queue={len(engine.queue)}")
    return engine


if __name__ == "__main__":
    main()
