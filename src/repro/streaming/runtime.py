"""Continuous-batching decode runtime: slot slab + bucketed compilation.

One ``DecodeRuntime`` per serving replica replaces the chunked
prefill-then-Python-decode path:

- **Paged KV slab** (default): KV lives in a shared pool of fixed-size
  physical pages (``model_api.init_paged_cache``); each slot owns a row
  of the host-side page table. Admission allocates exactly the pages a
  request's lifetime needs (``RuntimeConfig.page_footprint``) and
  ``pump`` frees them at retirement, so HBM per request tracks its
  actual length and the decode dispatch reads only the smallest
  ``kv_ladder`` bucket covering the deepest live row — an 8-token
  request no longer pays a 128-token request's attention cost.
  ``paged=False`` keeps the PR-2 dense slab: ``max_batch`` slots x
  ``capacity`` entries (``model_api.init_slab_cache``). Either way,
  nothing is ever re-allocated or grown per chunk.
- **Bucketed compilation**: prompts pad to power-of-two length buckets and
  admissions to power-of-two batch buckets, so the number of distinct jit
  traces is O(#length-buckets x #batch-buckets) + 1 fused decode trace,
  independent of the observed request mix. ``RuntimeKernels.trace_counts``
  exposes the actual trace tally for regression tests.
- **Fused decode**: ``decode_block`` greedy steps run as one
  ``jax.lax.scan`` dispatch with the slab donated (``model_api.fused_decode``)
  instead of one Python-loop dispatch per token.
- **Continuous batching**: after every block the host harvests finished
  slots, frees them, and admits pending requests immediately — a short
  request no longer rides along for its chunk-mates' ``max_new``.

Kernels (the jitted closures) are shared across replicas and cached per
mesh topology by ``ElasticServing.runtime_kernels``; the slab itself is
per-replica state. The slot table round-trips through the drain ->
checkpoint -> reschedule path as plain numpy arrays (``state()`` /
``restore()``), so in-flight requests survive a node eviction.

Request content store: each request's prompt tokens are materialized
once — deterministically from (rid, length bucket), independent of its
admission chunk-mates — kept in ``DecodeRuntime.content``, and carried
through ``state()``/``restore()``. A restored rid therefore replays its
*exact* prompt tokens on the successor replica, so greedy output across a
drain is token-identical to an undisturbed run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import Request
from repro.models import model_api as MA


def requests_from_state(state) -> List[Request]:
    """Decode a checkpointed slot table back into Request objects."""
    rids = np.asarray(state.get("inflight_rid", ()))
    if rids.size == 0:
        return []
    arrival = np.asarray(state["inflight_arrival"])
    plen = np.asarray(state["inflight_plen"])
    rem = np.asarray(state["inflight_remaining"])
    # prefix identity ships too (absent in pre-prefix-cache checkpoints):
    # a restored rid whose content row is missing re-mints its shared
    # prefix bit-identically, so the successor re-interns and rebuilds
    # page sharing instead of forking private copies
    grp = np.asarray(state.get("inflight_group", np.zeros(rids.size)))
    pfx = np.asarray(state.get("inflight_pfxlen", np.zeros(rids.size)))
    # QoS columns (absent in pre-overload checkpoints): the deadline and
    # tier survive the drain -> restore round trip, so a restored request
    # is still sheddable/protected exactly like a never-moved one
    ddl = np.asarray(state.get("inflight_deadline", np.zeros(rids.size)))
    pri = np.asarray(state.get("inflight_priority",
                               np.full(rids.size, 10)))
    # trace context (absent in pre-observability checkpoints): the span
    # chain keeps its identity across fault incarnations
    trc = np.asarray(state.get("inflight_trace", np.zeros(rids.size)))
    return [Request(int(rids[i]), float(arrival[i]), int(plen[i]),
                    int(rem[i]), prefix_group=int(grp[i]),
                    prefix_len=int(pfx[i]), deadline=float(ddl[i]),
                    priority=int(pri[i]), trace_id=int(trc[i]))
            for i in range(rids.size)]


@dataclass(frozen=True)
class RuntimeConfig:
    """Static shape policy — one kernels cache entry per distinct value.

    ``paged=True`` stores KV in a shared pool of ``page_size``-entry
    physical pages instead of one full-capacity row per slot: admission
    allocates each request ceil((prompt_bucket + max_new + 1) /
    page_size) pages, retirement frees them, and decode reads only the
    smallest ``kv_ladder`` bucket covering the deepest live row — HBM
    and attention cost track actual request lengths, so ``max_batch``
    can grow for short-request mixes under the same pool
    (``pool_pages``; 0 sizes the pool so every slot can hold a
    full-capacity request, i.e. no admission ever blocks on pages).
    It pays when capacity is provisioned well beyond the typical live
    depth (long-context posture, or the TPU Pallas per-row-exit path);
    with a tightly-sized slab the dense layout's single fused attention
    is faster on CPU — see ``bench_paged_decode`` for the crossover.

    The dense slab keeps its own length-proportionality lever:
    ``block_skip`` streams decode KV in blocks and the host engages it
    per dispatch whenever the deepest live row leaves at least half the
    capacity dead (0 disables — the PR-2 plain full-width attention)."""
    max_batch: int = 8            # slots in the slab
    min_prompt_bucket: int = 8
    max_prompt_bucket: int = 64
    max_new_cap: int = 64         # capacity headroom for generation
    decode_block: int = 16        # max fused steps per scan dispatch
    admit_tail: int = 4           # decode steps fused into each admission
    paged: bool = False           # paged KV pool vs dense per-slot slab
    page_size: int = 16           # KV entries per physical page
    pool_pages: int = 0           # pool size; 0 -> max_batch * pages_per_slot
    # dense-slab jnp decode: KV block size for runtime block skipping
    # (engaged per dispatch while live depth <= capacity/2); 0 restores
    # the PR-2 plain full-capacity attention everywhere
    block_skip: int = 32
    # prefix-sharing copy-on-write (paged only): admission interns each
    # prompt's page-aligned prefix; a later identical prefix splices the
    # existing pages (refcount++) instead of re-running prefill, and the
    # first write into a shared page copies it first (CoW)
    prefix_cache: bool = False
    # multi-token speculative decode (paged only): an n-gram drafter
    # proposes k tokens per row and one k+1-wide dispatch verifies them
    # (greedy accept-prefix — token-identical to one-at-a-time). 0 = off.
    spec_decode: int = 0
    # bounded pending queue (0 = unbounded): ``submit`` admits up to the
    # cap and returns the overflow so the engine can apply backpressure
    # (reject-with-retry-after) instead of letting the queue grow forever
    pending_cap: int = 0

    @property
    def capacity(self) -> int:
        # every admitted request fits without ring-wrapping; speculative
        # verify writes up to spec_decode draft positions past the last
        # accepted token, so its headroom joins the footprint
        return self.max_prompt_bucket + self.max_new_cap + 1 + self.spec_decode

    @property
    def pages_per_slot(self) -> int:
        return -(-self.capacity // self.page_size)

    @property
    def padded_capacity(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def n_pool_pages(self) -> int:
        return self.pool_pages or self.max_batch * self.pages_per_slot

    @property
    def prompt_buckets(self) -> Tuple[int, ...]:
        return MA.bucket_ladder(self.min_prompt_bucket, self.max_prompt_bucket)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return MA.bucket_ladder(1, self.max_batch)

    @property
    def block_ladder(self) -> Tuple[int, ...]:
        # fused-step buckets: the host picks the smallest block covering the
        # longest live request, so tail ticks don't over-run 16 steps deep
        return MA.bucket_ladder(min(4, self.decode_block), self.decode_block)

    @property
    def kv_ladder(self) -> Tuple[int, ...]:
        # logical KV-read buckets for paged decode: every page multiple,
        # not powers of two — a row at depth 33 reads 48 entries, not 64.
        # The ladder is page-granular because reads gather whole pages;
        # its length (= pages_per_slot) is the kv factor in max_traces.
        return tuple(self.page_size * (p + 1)
                     for p in range(self.pages_per_slot))

    def page_footprint(self, plen_bucket: int, max_new: int) -> int:
        """Physical pages a request owns for its whole life: prompt bucket
        + generation + the frozen-row write slot (mirrors capacity's +1)
        + speculative-draft overshoot when spec_decode is on."""
        return -(-(plen_bucket + max_new + 1 + self.spec_decode)
                 // self.page_size)

    def cow_reserve(self, plen_bucket: int) -> int:
        """Extra pages granted at admission for copy-on-write headroom.
        Only a prompt page can ever be shared, and a row's writes overlap
        the prompt region only in the page containing ``plen_bucket`` when
        that bucket is not page-aligned — so at most one CoW per row, and
        pre-granting its target page means CoW never allocates from a
        possibly-empty pool (no deadlock against retirement)."""
        return 1 if (self.prefix_cache and plen_bucket % self.page_size) else 0

    def fits(self, req: Request) -> bool:
        if req.prompt_len > self.max_prompt_bucket:
            return False
        plen = MA.pow2_bucket(req.prompt_len, self.min_prompt_bucket,
                              self.max_prompt_bucket)
        if plen + req.max_new + 1 + self.spec_decode > self.capacity:
            return False
        return (not self.paged
                or self.page_footprint(plen, req.max_new)
                + self.cow_reserve(plen) <= self.n_pool_pages)


class PageAllocator:
    """Reference-counted free list over the physical KV page pool (unit
    granularity — a "fragment" is just a reusable page, so mid-stream
    retirement never strands capacity). Page 0 is reserved as the null
    page: pad rows, retired slots and frozen rows write there; nothing
    reads it. ``share`` lets several slots reference the same immutable
    prefix page (prefix cache); ``free`` decrements and only returns a
    page to the free list when its last reference drops.

    Invariants (asserted by tests/test_paged_runtime.py and
    tests/test_prefix_cache.py):
      - page 0 is never handed out;
      - used + free == pool size at every step (a shared page is one
        *physical* page, counted used once no matter how many holders);
      - no write access to refcount>1 pages (the runtime CoWs first —
        the PR-4 "one owner" rule generalized to "one writer");
      - ``alloc`` is all-or-nothing (no partial grants to unwind).
    """

    def __init__(self, pool_pages: int):
        self.pool_pages = pool_pages
        # LIFO: freshly freed pages are reused first (warm in cache)
        self._free = list(range(pool_pages, 0, -1))
        # refcount[p]: holders of physical page p (0 = on the free list)
        self.refcount = np.zeros(pool_pages + 1, np.int32)

    @property
    def n_pages(self) -> int:          # physical pool incl. the null page
        return self.pool_pages + 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.pool_pages - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Physical pages currently referenced by more than one holder."""
        return int(np.sum(self.refcount > 1))

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self.refcount[out] = 1
        return out

    def share(self, pages) -> None:
        """Add one reference to each page (prefix splice). Pages must be
        live — sharing a free page would resurrect it under two owners."""
        for p in pages:
            assert self.refcount[p] > 0, f"share of free page {p}"
            self.refcount[p] += 1

    def free(self, pages) -> List[int]:
        """Drop one reference per page; pages whose count hits zero return
        to the free list. Returns the pages actually released (so the
        runtime can evict stale prefix-cache entries pointing at them)."""
        released = []
        for p in pages:
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                released.append(p)
        return released


class RuntimeKernels:
    """Jitted admission + fused-decode functions with a trace-count guard.

    The python bodies below execute only while jax traces them, so the
    ``trace_counts`` increments tally *compilations*, not calls — the
    bucketing contract ("O(#buckets) traces under any request mix") is a
    plain integer assertion away.
    """

    def __init__(self, cfg: ArchConfig, rcfg: RuntimeConfig, ctx=None):
        if not MA.supports_slots(cfg):
            raise ValueError(f"family {cfg.family!r} has no slot-slab decode")
        if (rcfg.prefix_cache or rcfg.spec_decode) and not rcfg.paged:
            raise ValueError("prefix_cache / spec_decode require the paged "
                             "KV slab (paged=True)")
        if rcfg.spec_decode and rcfg.admit_tail:
            raise ValueError("spec_decode needs admit_tail=0 (acceptance is "
                             "decided host-side between dispatches, so the "
                             "device-resident fused tail would desync)")
        self.cfg, self.rcfg, self.ctx = cfg, rcfg, ctx
        self.trace_counts = {"admit": 0, "decode": 0, "splice": 0,
                             "window": 0, "cow": 0}
        self._admit = {}                 # (batch_bucket, len_bucket) -> fn
        self._decode = {}                # fused steps -> fn
        self._splice = {}                # batch_bucket -> fn
        self._window = {}                # (bb, W, kvb, P, stamp) -> fn
        self._cow = {}                   # pair-count bucket -> fn

    @property
    def max_traces(self) -> int:
        """Bucketing contract: traces stay O(#buckets) under any request
        mix. Paged decode adds the kv-read-bucket dimension (which logical
        prefix of the page table a dispatch visits), so the bound picks up
        a ``kv_ladder`` factor — still shape-policy-static. The prefix
        cache adds splice stamps, tail-prefill windows (one per (batch,
        prompt bucket, shared-page count)) and CoW copy batches;
        speculative decode adds the k+1-wide verify windows."""
        n_bb = len(self.rcfg.batch_buckets)
        n_admit = n_bb * len(self.rcfg.prompt_buckets)
        n_decode = len(self.rcfg.block_ladder)
        extra = 0
        if self.rcfg.paged:
            n_kv = len(self.rcfg.kv_ladder)
            # admissions with a fused tail also carry a kv bucket
            if self.rcfg.admit_tail:
                n_admit *= n_kv
            n_decode *= n_kv
            if self.rcfg.prefix_cache:
                # tail-less admit variants (waves containing cache hits)
                extra += n_bb * len(self.rcfg.prompt_buckets)
                extra += n_bb                        # full-hit splices
                extra += (n_bb * len(self.rcfg.prompt_buckets)
                          * self.rcfg.pages_per_slot)  # tail windows
                extra += n_bb                        # CoW copy batches
            if self.rcfg.spec_decode:
                extra += n_bb * n_kv                 # verify windows
        elif self.rcfg.block_skip:
            n_decode *= 2          # plain + block-skip variants per steps
        return n_admit + n_decode + extra

    def admit_fn(self, bb: int, lb: int, kvb: int = 0):
        key = (bb, lb, kvb)
        if key in self._admit:
            return self._admit[key]
        cfg, ctx = self.cfg, self.ctx
        mod = MA.get_module(cfg)
        rcfg = self.rcfg
        tail = rcfg.admit_tail

        def admit(params, tokens, cache, tok, active, remaining,
                  slot_idx, max_new, pages=None, prompt_pages=None):
            self.trace_counts["admit"] += 1
            logits, pcache = mod.prefill(params, tokens, cfg, ctx)
            if rcfg.paged:
                cache = MA.scatter_prefill_paged(
                    cfg, cache, pcache, slot_idx, tokens.shape[1],
                    prompt_pages, rcfg.page_size)
            else:
                cache = MA.scatter_prefill(cfg, cache, pcache, slot_idx,
                                           tokens.shape[1])
            first = jnp.argmax(logits, -1).astype(jnp.int32)
            tok = tok.at[slot_idx].set(first[:, None])
            # pad rows (batch bucket > group size) target the overflow row
            # with max_new = 0: they go inert after one masked step
            active = active.at[slot_idx].set(max_new > 0)
            remaining = remaining.at[slot_idx].set(max_new)
            # paged admissions built with kvb=0 are explicitly tail-less
            # (prefix-cache waves containing hits: spliced rows must not
            # be advanced by a fused ride they were never stamped onto)
            if tail and (kvb or not rcfg.paged):
                # fused decode tail: admission and the first few steps of
                # the whole slab ride one dispatch (half the sync points)
                # tail steps run plain on the dense slab (a freshly
                # admitted bucket usually fills a good share of capacity;
                # skipping is the decode blocks' per-dispatch decision)
                tok, cache, active, remaining, _ = MA.fused_decode(
                    params, tok, cache, active, remaining, cfg, ctx,
                    steps=tail, pages=pages,
                    kv_bucket=kvb if rcfg.paged else None,
                    block_skip=None if rcfg.paged else 0)
            return cache, tok, active, remaining, first

        fn = jax.jit(admit, donate_argnums=(2, 3, 4, 5))
        self._admit[key] = fn
        return fn

    def decode_fn(self, steps: int, kvb: int = 0, skip: bool = False):
        key = (steps, kvb, skip)
        if key in self._decode:
            return self._decode[key]
        cfg, ctx = self.cfg, self.ctx
        rcfg = self.rcfg

        def block(params, tok, cache, active, remaining, pages=None):
            self.trace_counts["decode"] += 1
            return MA.fused_decode(params, tok, cache, active, remaining,
                                   cfg, ctx, steps=steps, pages=pages,
                                   kv_bucket=kvb if rcfg.paged else None,
                                   block_skip=(None if rcfg.paged else
                                               (rcfg.block_skip if skip
                                                else 0)))

        fn = jax.jit(block, donate_argnums=(1, 2, 3, 4))
        self._decode[key] = fn
        return fn

    def splice_fn(self, bb: int):
        """Full prefix hit: no model evaluation at all — stamp the spliced
        rows' device state (first token from the interned entry, position
        = prompt bucket) and the admission is done. The page-table write
        itself is host-side; this is the only device work a hit costs."""
        if bb in self._splice:
            return self._splice[bb]

        def splice(cache, tok, active, remaining, idx, first, max_new, pos):
            self.trace_counts["splice"] += 1
            cache = dict(cache)
            cache["pos"] = cache["pos"].at[idx].set(pos)
            tok = tok.at[idx].set(first[:, None])
            active = active.at[idx].set(max_new > 0)
            remaining = remaining.at[idx].set(max_new)
            return cache, tok, active, remaining

        fn = jax.jit(splice, donate_argnums=(0, 1, 2, 3))
        self._splice[bb] = fn
        return fn

    def window_fn(self, bb: int, W: int, kvb: int, P: int, stamp: bool):
        """W-token decode window over a (bb,)-row subset of the paged slab:
        writes KV for all W tokens at positions pos..pos+W-1 and returns
        the greedy argmax at every offset. Two users share the trace
        family: the tail prefill of a partial prefix hit (``stamp=True``
        also stamps tok/active/remaining/pos for the admitted rows) and
        the speculative-decode verify step (``stamp=False`` — the host
        decides acceptance before device state may advance)."""
        key = (bb, W, kvb, P, stamp)
        if key in self._window:
            return self._window[key]
        cfg, ctx = self.cfg, self.ctx

        def window(params, tokens, cache, tok, active, remaining, pos,
                   pages_sub, idx, max_new):
            self.trace_counts["window"] += 1
            logits, cache = MA.decode_window(params, tokens, cache, cfg,
                                             ctx, pages=pages_sub, pos=pos,
                                             kv_bucket=kvb)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)      # (bb, W)
            if stamp:
                cache = dict(cache)
                cache["pos"] = cache["pos"].at[idx].set(pos + W)
                tok = tok.at[idx].set(toks[:, -1:])
                active = active.at[idx].set(max_new > 0)
                remaining = remaining.at[idx].set(max_new)
            return cache, tok, active, remaining, toks

        fn = jax.jit(window, donate_argnums=(2, 3, 4, 5))
        self._window[key] = fn
        return fn

    def cow_fn(self, n: int):
        """Copy-on-write transfer: duplicate ``n`` physical pages inside
        the pool (src -> dst, every layer/part) in one dispatch, before a
        write dispatch would touch a refcount>1 page. Pad pairs are
        (0, 0): the null page copied onto itself."""
        if n in self._cow:
            return self._cow[n]

        def cow(cache, src, dst):
            self.trace_counts["cow"] += 1
            new = dict(cache)
            for part in ("dense", "moe"):
                if part not in cache:
                    continue
                d = dict(cache[part])
                for nm in ("k", "v"):
                    buf = d[nm]                    # (L, n_pages, ps, kvh, dh)
                    d[nm] = buf.at[:, dst].set(buf[:, src])
                new[part] = d
            return new

        fn = jax.jit(cow, donate_argnums=(0,))
        self._cow[n] = fn
        return fn

    def put(self, tree):
        """Commit arrays to the serving mesh (replicated). Mixing
        mesh-committed params with uncommitted slab buffers makes every
        dispatch re-shard its inputs (~15x per-call overhead on CPU), so
        all runtime state goes through here."""
        if self.ctx is None or self.ctx.mesh is None:
            return jax.tree.map(jnp.asarray, tree)
        sh = jax.sharding.NamedSharding(self.ctx.mesh,
                                        jax.sharding.PartitionSpec())
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


@dataclass
class _Slot:
    req: Optional[Request] = None
    remaining: int = 0
    lb: int = 0                       # prompt-length bucket at admission
    pages: Tuple[int, ...] = ()       # physical pages referenced (paged mode)
    # pre-granted CoW target (prefix cache, unaligned prompt bucket):
    # consumed by the row's single possible copy-on-write, freed at
    # retirement if never used
    reserve: Optional[int] = None
    # speculative decode host mirrors: emitted token history (drafter
    # input), the last emitted-but-not-yet-written token, and the full
    # prompt's content key into the shared paved-stream table
    history: Optional[list] = None
    last_tok: int = 0
    skey: Optional[bytes] = None

    @property
    def busy(self) -> bool:
        return self.req is not None

    @property
    def pos(self) -> int:
        """Current cache depth (host mirror of the device pos vector)."""
        return self.lb + (self.req.max_new - self.remaining)


@dataclass
class Finished:
    req: Request
    tokens: int                       # generated this runtime (<= req.max_new)


@dataclass
class DecodeRuntime:
    """Per-replica serving state: the slab + a host-side slot table."""
    kernels: RuntimeKernels
    params: object
    gen: int = 0                      # ElasticServing build generation
    pending: List[Request] = field(default_factory=list)
    slots: List[_Slot] = field(default_factory=list)
    # request content store: rid -> prompt tokens (length-bucket shaped);
    # checkpointed with the slot table so restored rids replay exactly
    content: Dict[int, np.ndarray] = field(default_factory=dict)
    steps_dispatched: int = 0         # fused blocks run (for perf telemetry)
    # pressure window: busy-slot / held-page peaks since the last
    # ``reset_pressure`` — ``pump()`` runs to quiescence, so end-of-tick
    # instantaneous readings would always be zero; the peak is what the
    # slab actually had to absorb this tick
    peak_slots: int = 0
    peak_pages: int = 0
    record_tokens: bool = False       # keep per-request token ids (tests)
    token_log: Dict[int, list] = field(default_factory=dict)
    # ring cap per rid on the greedy log (0 = unbounded): long soaks keep
    # the newest ``token_log_cap`` ids; ``token_log_dropped[rid]`` counts
    # the trimmed head — the explicit truncation marker that lets audits
    # align a capped log against an oracle's tail instead of its prefix
    token_log_cap: int = 0
    token_log_dropped: Dict[int, int] = field(default_factory=dict)
    # engine degrade knob: False routes decode through the plain block
    # path even when rcfg.spec_decode is configured (brownout levels >= 1
    # shed the speculative-decode luxury before shedding any request)
    spec_enabled: bool = True
    # prefix-cache telemetry (cumulative since construction)
    prefix_lookups: int = 0
    prefix_hits: int = 0
    cow_events: int = 0
    # speculative-decode telemetry
    spec_rounds: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    spec_emitted: int = 0
    # observability plane (all optional; None = zero-cost disabled path).
    # ``name`` is the replica/pod identity stamped on spans; ``sim_now``
    # mirrors the engine clock so runtime-emitted spans carry sim-time.
    name: str = ""
    tracer: object = None
    metrics: object = None            # per-pod Registry (TTFT histogram)
    profiler: object = None           # TickProfiler (pump phase timing)
    sim_now: float = 0.0

    def __post_init__(self):
        rcfg = self.kernels.rcfg
        if self.record_tokens and rcfg.admit_tail:
            raise ValueError("record_tokens needs admit_tail=0 (tail-step "
                             "token ids never leave the admission dispatch)")
        self.slots = [_Slot() for _ in range(rcfg.max_batch)]
        # one extra overflow row: admissions pad their batch up to a
        # power-of-two bucket and aim the pad rows here, so a group of 7
        # costs one (8, L) prefill instead of three (4/2/1, L) dispatches
        rows = rcfg.max_batch + 1
        if rcfg.paged:
            self.alloc = PageAllocator(rcfg.n_pool_pages)
            # host-owned page table, shipped with every dispatch: row ->
            # physical pages (0 = null). Freed rows are re-pointed at the
            # null page *before* their pages can be re-granted, so a
            # frozen row's idempotent KV write can never corrupt a
            # successor request's page.
            self.page_table = np.zeros((rows, rcfg.pages_per_slot), np.int32)
            self.pages_hwm = 0                  # pool high-water (telemetry)
            self._pages_dev = None              # mesh-committed copy
            self._pages_dirty = True
            self.cache = self.kernels.put(MA.init_paged_cache(
                self.kernels.cfg, rows, self.alloc.n_pages, rcfg.page_size))
        else:
            self.cache = self.kernels.put(MA.init_slab_cache(
                self.kernels.cfg, rows, rcfg.capacity))
        self.tok = self.kernels.put(jnp.zeros((rows, 1), jnp.int32))
        self.active = self.kernels.put(jnp.zeros((rows,), bool))
        self.remaining = self.kernels.put(jnp.zeros((rows,), jnp.int32))
        # prefix intern table: ("p", j, bytes) -> j page-aligned prefix
        # pages; ("f", lb, bytes) -> full prompt pages + first greedy
        # token. Entries hold no reference of their own — they stay valid
        # exactly while some slot holds the pages (refcount >= 1), and
        # are evicted the moment a release returns a listed page to the
        # pool (before any re-grant could repurpose it).
        self._intern: Dict[tuple, dict] = {}
        # paved-stream table (speculative decode): full-prompt bytes ->
        # greedy tokens some row already emitted for that exact prompt.
        # Greedy decode is deterministic in the prompt, so a later
        # identical request's tokens are known ahead of verification —
        # the drafter reads them and acceptance is ~100% (replay /
        # duplicate traffic); unseen prompts fall back to the n-gram
        # drafter. Purely an accelerator: bounded, never checkpointed.
        self._stream: Dict[bytes, list] = {}

    @property
    def _paged(self) -> bool:
        return self.kernels.rcfg.paged

    @property
    def pages_in_use(self) -> int:
        return self.alloc.used_pages if self._paged else 0

    @property
    def shared_pages(self) -> int:
        """Physical pages currently referenced by >1 slot (the
        ``ersap_shared_pages`` gauge)."""
        return self.alloc.shared_pages if self._paged else 0

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of drafted tokens accepted by verification."""
        return self.spec_accepted / self.spec_drafted \
            if self.spec_drafted else 0.0

    @property
    def slots_in_use(self) -> int:
        """Busy slab slots (the dense-path pressure gauge,
        ``ersap_slab_slots_used``)."""
        return sum(s.busy for s in self.slots)

    @property
    def occupancy(self) -> float:
        """Memory-pressure fraction in [0, 1] — the HPA / twin signal:
        page-pool share when paged (HBM actually held), busy-slot share
        on the dense slab (whose HBM is fixed; slots are what run out).
        Peak over the current pressure window (see ``reset_pressure``)."""
        if self._paged:
            return max(self.pages_in_use, self.peak_pages) / \
                max(self.alloc.pool_pages, 1)
        return max(self.slots_in_use, self.peak_slots) / \
            max(len(self.slots), 1)

    def reset_pressure(self) -> None:
        """Start a new pressure-measurement window (one engine tick)."""
        self.peak_slots = self.slots_in_use
        self.peak_pages = self.pages_in_use

    def _device_pages(self):
        """Mesh-committed page table, refreshed only when the host table
        mutated (admission/retirement) — uncommitted per-dispatch inputs
        would re-shard the whole argument list (see ``RuntimeKernels.put``)."""
        if self._pages_dirty:
            self._pages_dev = self.kernels.put(jnp.asarray(self.page_table))
            self._pages_dirty = False
        return self._pages_dev

    def _kv_bucket(self, steps: int, incoming=()) -> int:
        """Smallest kv-read bucket covering every live row's cache depth at
        the end of a ``steps``-deep fused block (busy slots advance by at
        most min(steps, remaining); ``incoming`` rows are (lb, max_new)
        pairs about to be admitted at depth lb)."""
        need = 1
        for s in self.slots:
            if s.busy:
                need = max(need, s.pos + min(steps, s.remaining))
        for lb, max_new in incoming:
            need = max(need, lb + min(steps, max_new))
        ladder = self.kernels.rcfg.kv_ladder
        return next((b for b in ladder if b >= need), ladder[-1])

    # -------------------------------------------------------------- intake
    def submit(self, requests: List[Request],
               force: bool = False) -> List[Request]:
        """Enqueue requests; returns the overflow rejected by the bounded
        pending queue (empty when ``pending_cap`` is 0 or everything
        fits). ``force=True`` bypasses the cap — checkpoint-restored and
        drain-carried work was already admitted once and must never be
        bounced back into the arrival stream."""
        cap = self.kernels.rcfg.pending_cap
        if force or cap <= 0:
            self.pending.extend(requests)
            return []
        room = max(cap - len(self.pending), 0)
        self.pending.extend(requests[:room])
        return list(requests[room:])

    def fits(self, req: Request) -> bool:
        return self.kernels.rcfg.fits(req)

    def _log_tokens(self, rid: int, toks: list) -> None:
        """Append to the per-rid greedy log, trimming the oldest entries
        past ``token_log_cap`` and counting the drop."""
        log = self.token_log.setdefault(rid, [])
        log.extend(toks)
        cap = self.token_log_cap
        if cap and len(log) > cap:
            drop = len(log) - cap
            del log[:drop]
            self.token_log_dropped[rid] = \
                self.token_log_dropped.get(rid, 0) + drop

    @property
    def inflight(self) -> int:
        return sum(s.busy for s in self.slots) + len(self.pending)

    # ---------------------------------------------------------- admission
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.busy]

    def _admit_some(self) -> List[Finished]:
        """Admit pending requests into free slots: group by prompt-length
        bucket (largest group first), one padded prefill dispatch per
        group (with a fused decode tail — see ``RuntimeKernels.admit_fn``).
        Hysteresis: while decode is mid-stream, wait until a couple of
        slots are free rather than paying one prefill dispatch per freed
        slot (admission is still within one decode block of arrival)."""
        if not self.pending:
            return []
        rcfg = self.kernels.rcfg
        free = self._free_slots()
        busy = rcfg.max_batch - len(free)
        if busy and len(free) < min(len(self.pending),
                                    max(2, rcfg.max_batch // 2)):
            return []
        done: List[Finished] = []
        while free and self.pending:
            groups: Dict[tuple, List[Request]] = {}
            for r in self.pending:
                lb = MA.pow2_bucket(r.prompt_len, rcfg.min_prompt_bucket,
                                    rcfg.max_prompt_bucket)
                # depth-segregated admission: co-schedule rows whose
                # generation depth shares a pow2 bucket, so one deep row
                # doesn't pin the block/kv ladder (and the CoW working
                # set) for a wave of short batch-mates
                db = MA.pow2_bucket(max(r.max_new, 1), 1, rcfg.max_new_cap)
                groups.setdefault((lb, db), []).append(r)
            (lb, _), group = max(groups.items(), key=lambda kv: len(kv[1]))
            # within the depth bucket, longest-first keeps fused blocks tight
            group = sorted(group, key=lambda r: -r.max_new)[:len(free)]
            grants: Dict[int, dict] = {}
            if self._paged:
                # all-or-nothing page grant per request; a request the pool
                # cannot hold right now stays pending until a retirement
                # frees pages (fits() guarantees it can be held eventually)
                granted = []
                wave: Dict[tuple, dict] = {}    # same-wave leader full keys
                for r in group:
                    g = self._plan_grant(r, lb, wave)
                    if g is None:
                        break
                    granted.append(r)
                    grants[id(r)] = g
                group = granted
                if not group:
                    break
                self.pages_hwm = max(self.pages_hwm, self.alloc.used_pages)
                self.peak_pages = max(self.peak_pages, self.alloc.used_pages)
            taken = set(id(r) for r in group)
            self.pending = [r for r in self.pending if id(r) not in taken]
            take, free = free[:len(group)], free[len(group):]
            done.extend(self._admit_batch(group, take, lb, grants))
        return done

    # ---------------------------------------------------- prefix interning
    def _lookup_prefix(self, tokens: np.ndarray, lb: int,
                       wave: Optional[Dict[tuple, dict]] = None):
        """Longest known prefix of ``tokens``: the full prompt first
        (splice-only admission), then page-aligned prefixes longest-first
        (splice + short tail prefill). ``wave`` holds prefixes granted
        earlier in the same admission wave but not yet prefilled — safe
        to share because misses dispatch before tail groups and tail
        groups dispatch in ascending j (a sharer's pages are always
        written by an earlier dispatch of the same wave)."""
        ps = self.kernels.rcfg.page_size
        e = self._intern.get(("f", lb, tokens.tobytes()))
        if e is not None:
            return ("full", e)
        # partials must leave a non-empty tail: a page-aligned prompt whose
        # whole content matches a longer prompt's head (j*ps == lb) has no
        # remainder to prefill and no recorded first token — treat as miss
        for j in range((lb - 1) // ps, 0, -1):
            key = ("p", j, tokens[:j * ps].tobytes())
            e = self._intern.get(key)
            if e is None and wave is not None:
                e = wave.get(key)
            if e is not None:
                return ("tail", j, e)
        return ("miss",)

    def _register_intern(self, tokens: np.ndarray, pages, first_tok: int,
                         lb: int) -> None:
        """Publish a freshly prefilled prompt's page-aligned prefixes and
        its full key. ``setdefault`` keeps the first publisher — its pages
        are the ones later holders already share."""
        ps = self.kernels.rcfg.page_size
        for j in range(1, lb // ps + 1):
            self._intern.setdefault(("p", j, tokens[:j * ps].tobytes()),
                                    {"pages": tuple(pages[:j]),
                                     "first": None})
        n_prompt = -(-lb // ps)
        self._intern.setdefault(("f", lb, tokens.tobytes()),
                                {"pages": tuple(pages[:n_prompt]),
                                 "first": int(first_tok)})

    def _evict_intern(self, released) -> None:
        """Drop intern entries listing any just-released page — eagerly,
        before a re-grant could repurpose the page under a stale entry."""
        rel = set(released)
        dead = [k for k, e in self._intern.items()
                if rel.intersection(e["pages"])]
        for k in dead:
            del self._intern[k]

    def _plan_grant(self, r: Request, lb: int, wave: Dict[tuple, dict]):
        """Page grant + prefix classification for one admission candidate.
        Returns None when the pool cannot hold it right now (all-or-
        nothing, like PR-4). Mutates the allocator: private pages are
        alloc'd, shared prefix pages refcount++."""
        rcfg = self.kernels.rcfg
        fp = rcfg.page_footprint(lb, r.max_new)
        if not rcfg.prefix_cache:
            pgs = self.alloc.alloc(fp)
            if pgs is None:
                return None
            return {"kind": "miss", "pages": pgs, "reserve": None}
        res = rcfg.cow_reserve(lb)
        tokens = self._prompt_tokens(r, lb)
        self.prefix_lookups += 1
        n_prompt = -(-lb // rcfg.page_size)
        fkey = ("f", lb, tokens.tobytes())

        def grant(kind, shared, extra):
            pgs = self.alloc.alloc(fp - len(shared) + res)
            if pgs is None:
                return None
            self.alloc.share(shared)
            reserve = pgs.pop() if res else None
            g = {"kind": kind, "pages": list(shared) + pgs,
                 "reserve": reserve}
            g.update(extra)
            return g

        lead = wave.get(fkey)
        if lead is not None:             # same-wave duplicate: follow it
            g = grant("follow", lead["pages"][:n_prompt], {"lead": lead})
            if g is not None:
                self.prefix_hits += 1
            return g
        hit = self._lookup_prefix(tokens, lb, wave)
        if hit[0] == "full":
            g = grant("full", hit[1]["pages"], {"first": hit[1]["first"]})
            if g is not None:
                self.prefix_hits += 1
            return g
        if hit[0] == "tail":
            j = hit[1]
            g = grant("tail", hit[2]["pages"], {"j": j})
            if g is not None:
                self.prefix_hits += 1
                self._wave_publish(wave, tokens, g, lb, fkey)
            return g
        g = grant("miss", (), {})
        if g is not None:
            self._wave_publish(wave, tokens, g, lb, fkey)
        return g

    def _wave_publish(self, wave: Dict[tuple, dict], tokens: np.ndarray,
                      g: dict, lb: int, fkey: tuple) -> None:
        """Make a just-granted miss/tail visible to later candidates of
        the same wave: the full key (exact-duplicate followers splice it)
        and every page-aligned partial (shared-prefix mates share the
        leading pages and tail-prefill only their remainder). Partial
        entries slice the leader's prompt pages at plan time — the KV for
        those pages is written by the leader's own dispatch, which the
        wave's dispatch order guarantees runs first."""
        wave[fkey] = g
        ps = self.kernels.rcfg.page_size
        for j in range(1, lb // ps + 1):
            wave.setdefault(("p", j, tokens[:j * ps].tobytes()),
                            {"pages": tuple(g["pages"][:j])})

    def _prompt_tokens(self, r: Request, lb: int) -> np.ndarray:
        """Content-store lookup: a request's prompt tokens are minted once
        (deterministic in (rid, length bucket) — never in the admission
        grouping) and replayed verbatim on every later admission,
        including after a checkpoint/restore on another replica. A
        request carrying a prefix identity gets its group's common tokens
        up front (deterministic in the group alone, so sharing survives
        drain/restore and is independent of which replica mints first)."""
        tok = self.content.get(r.rid)
        if tok is None or tok.shape[0] != lb:
            rng = np.random.default_rng(hash((r.rid, lb)) % (2 ** 31))
            tok = rng.integers(0, self.kernels.cfg.vocab, lb).astype(np.int32)
            pfx = min(r.prefix_len, lb) if r.prefix_group else 0
            if pfx:
                grng = np.random.default_rng(
                    hash(("prefix", r.prefix_group)) % (2 ** 31))
                tok[:pfx] = grng.integers(0, self.kernels.cfg.vocab, pfx)
            self.content[r.rid] = tok
        return tok

    def _note_admission(self, reqs: List[Request],
                        kind_of: Dict[int, str], lb: int) -> None:
        """Observability tail of an admission wave: per-rid ``admit``
        spans (with the grant kind), one block-level ``prefill`` span,
        and the TTFT histogram (sim-time from arrival to first token,
        which admission produces)."""
        if self.metrics is not None:
            h = self.metrics.histogram("ersap_ttft_s")
            for r in reqs:
                h.observe(max(self.sim_now - r.arrival, 0.0))
        if self.tracer is None:
            return
        for r in reqs:
            self.tracer.span("admit", self.sim_now, rid=r.rid,
                             kind=kind_of.get(id(r), "miss"),
                             replica=self.name, lb=lb)
        self.tracer.span("prefill", self.sim_now, replica=self.name,
                         lb=lb, rids=tuple(r.rid for r in reqs))

    def _admit_batch(self, reqs: List[Request], slot_idx: List[int],
                     lb: int, grants: Dict[int, dict]) -> List[Finished]:
        rcfg = self.kernels.rcfg
        if (self._paged and rcfg.prefix_cache
                and any(grants[id(r)]["kind"] != "miss" for r in reqs)):
            return self._admit_batch_prefix(reqs, slot_idx, lb, grants)
        bb = MA.pow2_bucket(len(reqs), 1, rcfg.max_batch)
        n_pad = bb - len(reqs)
        # synthetic workload: the prompt is per-request noise from the
        # content store; right-pad to the length bucket and the pad joins
        # the (synthetic) context. Batch pads to the bucket too — pad rows
        # land in the overflow row, so their token values are irrelevant.
        tokens = np.stack([self._prompt_tokens(r, lb) for r in reqs]
                          + [np.zeros(lb, np.int32)] * n_pad)
        max_new = np.asarray([r.max_new for r in reqs] + [0] * n_pad,
                             np.int32)
        idx = np.asarray(list(slot_idx) + [rcfg.max_batch] * n_pad, np.int32)
        if self._paged:
            # publish the grants in the page table (pad rows -> null page)
            npg_prompt = -(-lb // rcfg.page_size)
            prompt_pages = np.zeros((bb, npg_prompt), np.int32)
            for j, (r, i) in enumerate(zip(reqs, slot_idx)):
                pgs = grants[id(r)]["pages"]
                self.page_table[i] = 0
                self.page_table[i, :len(pgs)] = pgs
                prompt_pages[j] = pgs[:npg_prompt]
            self._pages_dirty = True
            if rcfg.admit_tail:
                # the fused tail writes into already-busy rows too: any
                # shared page in their write range is copied first
                self._cow_before_write(
                    [(i, s.pos + min(rcfg.admit_tail, s.remaining))
                     for i, s in enumerate(self.slots) if s.busy])
            kvb = self._kv_bucket(rcfg.admit_tail,
                                  incoming=[(lb, int(r.max_new))
                                            for r in reqs])
            fn = self.kernels.admit_fn(bb, lb,
                                       kvb if rcfg.admit_tail else 0)
            self.cache, self.tok, self.active, self.remaining, first = fn(
                self.params, tokens, self.cache, self.tok,
                self.active, self.remaining, idx, max_new,
                pages=self._device_pages(), prompt_pages=prompt_pages)
        else:
            fn = self.kernels.admit_fn(bb, lb)
            # small host inputs commit inside the dispatch; only the
            # persistent slab state must live pre-committed on the mesh
            # (see kernels.put)
            self.cache, self.tok, self.active, self.remaining, first = fn(
                self.params, tokens, self.cache, self.tok,
                self.active, self.remaining, idx, max_new)
        if rcfg.prefix_cache or rcfg.spec_decode or self.record_tokens:
            first = np.asarray(first)            # (bb,) prefill argmaxes
        for j, (r, i) in enumerate(zip(reqs, slot_idx)):
            g = grants.get(id(r), {})
            s = _Slot(req=r, remaining=int(r.max_new), lb=lb,
                      pages=tuple(g.get("pages", ())),
                      reserve=g.get("reserve"))
            if rcfg.spec_decode:
                self._spec_init(s, int(first[j]))
            self.slots[i] = s
            if rcfg.prefix_cache:
                self._register_intern(self.content[r.rid], s.pages,
                                      int(first[j]), lb)
            if self.record_tokens:               # first token (prefill argmax)
                self._log_tokens(r.rid, [int(first[j])])
        self.peak_slots = max(self.peak_slots, self.slots_in_use)
        self._note_admission(reqs, {id(r): grants.get(id(r), {}).get(
            "kind", "miss") for r in reqs}, lb)
        # the fused tail advanced every live row (old and new) tail steps
        return self._harvest(rcfg.admit_tail)

    def _admit_batch_prefix(self, reqs: List[Request], slot_idx: List[int],
                            lb: int,
                            grants: Dict[int, dict]) -> List[Finished]:
        """Admission wave containing prefix-cache hits. Misses prefill
        first (publishing their prefixes for same-wave followers), then
        partial hits run their short tail prefill, then full hits and
        followers are spliced with a host-side page-table write plus one
        device stamp — no prefill compute at all. No fused tail: hit rows
        are stamped after the miss dispatch, so a tail would advance rows
        asymmetrically; the next decode block picks everyone up."""
        rcfg = self.kernels.rcfg
        ps = rcfg.page_size
        n_prompt = -(-lb // ps)
        kinds = {"miss": [], "tail": [], "full": [], "follow": []}
        row_of: Dict[int, int] = {}      # id(grant) -> slab row
        first_of: Dict[int, int] = {}    # slab row -> first greedy token
        for r, i in zip(reqs, slot_idx):
            g = grants[id(r)]
            kinds[g["kind"]].append((r, i, g))
            row_of[id(g)] = i
            pgs = g["pages"]
            self.page_table[i] = 0
            self.page_table[i, :len(pgs)] = pgs
        self._pages_dirty = True

        ms = kinds["miss"]
        if ms:
            bb = MA.pow2_bucket(len(ms), 1, rcfg.max_batch)
            n_pad = bb - len(ms)
            tokens = np.stack([self.content[r.rid] for r, _, _ in ms]
                              + [np.zeros(lb, np.int32)] * n_pad)
            max_new = np.asarray([r.max_new for r, _, _ in ms]
                                 + [0] * n_pad, np.int32)
            idx = np.asarray([i for _, i, _ in ms]
                             + [rcfg.max_batch] * n_pad, np.int32)
            prompt_pages = np.zeros((bb, n_prompt), np.int32)
            for j, (r, i, g) in enumerate(ms):
                prompt_pages[j] = g["pages"][:n_prompt]
            fn = self.kernels.admit_fn(bb, lb, 0)        # tail-less
            self.cache, self.tok, self.active, self.remaining, first = fn(
                self.params, tokens, self.cache, self.tok, self.active,
                self.remaining, idx, max_new, pages=self._device_pages(),
                prompt_pages=prompt_pages)
            first = np.asarray(first)
            for j, (r, i, g) in enumerate(ms):
                first_of[i] = int(first[j])
                self._register_intern(self.content[r.rid], g["pages"],
                                      int(first[j]), lb)

        # partial hits: splice the shared full pages, prefill only the
        # non-shared remainder [j*page_size, lb) at its page-aligned offset
        for jv in sorted({g["j"] for _, _, g in kinds["tail"]}):
            grp = [t for t in kinds["tail"] if t[2]["j"] == jv]
            W = lb - jv * ps
            bb = MA.pow2_bucket(len(grp), 1, rcfg.max_batch)
            n_pad = bb - len(grp)
            toks_in = np.zeros((bb, W), np.int32)
            pos = np.zeros(bb, np.int32)
            pages_sub = np.zeros((bb, n_prompt), np.int32)
            idx = np.asarray([i for _, i, _ in grp]
                             + [rcfg.max_batch] * n_pad, np.int32)
            max_new = np.asarray([r.max_new for r, _, _ in grp]
                                 + [0] * n_pad, np.int32)
            for j2, (r, i, g) in enumerate(grp):
                toks_in[j2] = self.content[r.rid][jv * ps:lb]
                pos[j2] = jv * ps
                pages_sub[j2] = g["pages"][:n_prompt]
            fn = self.kernels.window_fn(bb, W, n_prompt * ps, n_prompt,
                                        stamp=True)
            self.cache, self.tok, self.active, self.remaining, toks = fn(
                self.params, toks_in, self.cache, self.tok, self.active,
                self.remaining, pos, pages_sub, idx, max_new)
            toks = np.asarray(toks)
            for j2, (r, i, g) in enumerate(grp):
                first_of[i] = int(toks[j2, -1])
                self._register_intern(self.content[r.rid], g["pages"],
                                      int(toks[j2, -1]), lb)

        fl = kinds["full"] + kinds["follow"]
        if fl:
            for r, i, g in fl:
                first_of[i] = first_of[row_of[id(g["lead"])]] \
                    if g["kind"] == "follow" else int(g["first"])
            bb = MA.pow2_bucket(len(fl), 1, rcfg.max_batch)
            n_pad = bb - len(fl)
            idx = np.asarray([i for _, i, _ in fl]
                             + [rcfg.max_batch] * n_pad, np.int32)
            first = np.asarray([first_of[i] for _, i, _ in fl]
                               + [0] * n_pad, np.int32)
            max_new = np.asarray([r.max_new for r, _, _ in fl]
                                 + [0] * n_pad, np.int32)
            pos = np.asarray([lb] * len(fl) + [0] * n_pad, np.int32)
            fn = self.kernels.splice_fn(bb)
            self.cache, self.tok, self.active, self.remaining = fn(
                self.cache, self.tok, self.active, self.remaining, idx,
                first, max_new, pos)

        for r, i in zip(reqs, slot_idx):
            g = grants[id(r)]
            s = _Slot(req=r, remaining=int(r.max_new), lb=lb,
                      pages=tuple(g["pages"]), reserve=g["reserve"])
            if rcfg.spec_decode:
                self._spec_init(s, first_of[i])
            self.slots[i] = s
            if self.record_tokens:
                self._log_tokens(r.rid, [first_of[i]])
        self.peak_slots = max(self.peak_slots, self.slots_in_use)
        self._note_admission(reqs, {id(r): grants[id(r)]["kind"]
                                    for r in reqs}, lb)
        return self._harvest(0)

    # ------------------------------------------------------- copy-on-write
    def _cow_before_write(self, writes) -> None:
        """Before any dispatch that writes KV for rows holding shared
        pages: for each (row, upper) pair — upper = the deepest position
        the dispatch may write — copy every refcount>1 page in the write
        range into the row's pre-granted reserve page and swap the table
        entry. A shared page's content is immutable from the moment a
        second holder splices it, so the writer forks, never the readers.
        At most one CoW can ever fire per row (see RuntimeConfig.
        cow_reserve), hence one reserve page suffices for a slot's life."""
        rcfg = self.kernels.rcfg
        if not rcfg.prefix_cache:
            return
        ps = rcfg.page_size
        pairs = []
        for i, upper in writes:
            s = self.slots[i]
            if not s.busy or not s.pages:
                continue
            hi = min((upper - 1) // ps, len(s.pages) - 1)
            for lp in range(s.pos // ps, hi + 1):
                old = s.pages[lp]
                if self.alloc.refcount[old] <= 1:
                    continue
                new = s.reserve
                assert new is not None, "CoW without a reserve page"
                s.reserve = None
                pg = list(s.pages)
                pg[lp] = new
                s.pages = tuple(pg)
                self.page_table[i, lp] = new
                self._pages_dirty = True
                pairs.append((old, new))
                self.alloc.free([old])     # refcount>1: drops a holder only
                self.cow_events += 1
        if pairs:
            n = MA.pow2_bucket(len(pairs), 1, rcfg.max_batch)
            pairs += [(0, 0)] * (n - len(pairs))   # null page onto itself
            src = np.asarray([p[0] for p in pairs], np.int32)
            dst = np.asarray([p[1] for p in pairs], np.int32)
            self.cache = self.kernels.cow_fn(n)(self.cache, src, dst)

    # -------------------------------------------------------------- decode
    def _retire_slot(self, i: int) -> None:
        """Free slot ``i``: in paged mode its pages go back to the pool and
        its page-table row re-points at the null page, so the retired
        row's frozen KV write can never land in a re-granted page."""
        s = self.slots[i]
        if self._paged and (s.pages or s.reserve is not None):
            self.page_table[i] = 0
            self._pages_dirty = True
            held = list(s.pages)
            if s.reserve is not None:       # unused CoW reserve goes back too
                held.append(s.reserve)
            released = self.alloc.free(held)
            if released and self._intern:
                self._evict_intern(released)
        self.slots[i] = _Slot()

    def _harvest(self, steps: int) -> List[Finished]:
        # nested profiler phase: retirement runs inside pump.admit /
        # pump.decode (the fused tail finishes rows) — counted both places
        t0 = time.perf_counter() if self.profiler is not None else 0.0
        done = []
        for i, s in enumerate(self.slots):
            if not s.busy:
                continue
            s.remaining -= min(steps, s.remaining)
            if s.remaining == 0:
                done.append(Finished(s.req, s.req.max_new))
                self._retire_slot(i)
                # content store follows the live request set (re-mintable
                # deterministically) — no monotonic growth across a stream
                self.content.pop(s.req.rid, None)
        if self.profiler is not None:
            self.profiler.add("pump.retire", time.perf_counter() - t0)
        return done

    def _decode_block(self) -> List[Finished]:
        rcfg = self.kernels.rcfg
        if rcfg.spec_decode and self.spec_enabled:
            return self._spec_block()
        maxrem = max((s.remaining for s in self.slots if s.busy), default=0)
        steps = next((b for b in self.kernels.rcfg.block_ladder
                      if b >= maxrem), self.kernels.rcfg.decode_block)
        if self._paged:
            self._cow_before_write(
                [(i, s.pos + min(steps, s.remaining))
                 for i, s in enumerate(self.slots) if s.busy])
            fn = self.kernels.decode_fn(steps, self._kv_bucket(steps))
            kw = {"pages": self._device_pages()}
        else:
            # engage dense block skipping only when the deepest live row
            # leaves at least half the slab capacity dead this block —
            # with a well-utilized slab the single fused attention wins
            depth = max((s.pos + min(steps, s.remaining)
                         for s in self.slots if s.busy), default=0)
            skip = bool(rcfg.block_skip) and 2 * depth <= rcfg.capacity
            fn = self.kernels.decode_fn(steps, skip=skip)
            kw = {}
        before = {i: s.remaining for i, s in enumerate(self.slots) if s.busy}
        if self.tracer is not None:
            self.tracer.span("decode", self.sim_now, replica=self.name,
                             steps=steps,
                             rids=tuple(self.slots[i].req.rid
                                        for i in before))
        self.tok, self.cache, self.active, self.remaining, toks = fn(
            self.params, self.tok, self.cache, self.active, self.remaining,
            **kw)
        self.steps_dispatched += 1
        if self.record_tokens or rcfg.spec_decode:  # syncs per block
            arr = np.asarray(toks)
            for i, rem in before.items():
                s = self.slots[i]
                emitted = [int(t) for t in arr[:min(steps, rem), i]]
                if self.record_tokens:
                    self._log_tokens(s.req.rid, emitted)
                if rcfg.spec_decode and emitted and s.history is not None:
                    # keep the drafter's host mirrors current while spec
                    # is browned out, so re-enabling it later verifies
                    # against the true last token instead of a stale one
                    s.history.extend(emitted)
                    s.last_tok = emitted[-1]
        return self._harvest(steps)

    # ------------------------------------------------------ spec decode
    def _spec_init(self, s: _Slot, first: int) -> None:
        """Host mirrors for a freshly admitted spec-decode row: the token
        history (drafter input), the content key into the paved-stream
        table, and the stream's first entry if this prompt is unseen."""
        s.history = self.content[s.req.rid].tolist() + [first]
        s.last_tok = first
        s.skey = self.content[s.req.rid].tobytes()
        st = self._stream.setdefault(s.skey, [])
        if not st:
            st.append(first)
            while len(self._stream) > 256:      # bound the table
                self._stream.pop(next(iter(self._stream)))

    def _draft(self, s: _Slot, k: int) -> list:
        """Two-tier drafter. Tier 1: the paved-stream table — if some row
        already emitted further along this exact prompt's greedy stream,
        its tokens ARE this row's future (greedy decode is deterministic
        in the prompt), so propose them directly. Tier 2: self-
        speculative n-gram — latest earlier occurrence of the trailing
        bigram (then unigram) in the row's own history proposes its
        continuation; loops/templates in greedy output make it land.
        Always returns exactly k tokens (bad guesses only cost
        acceptance, never correctness)."""
        hist = s.history
        eidx = len(hist) - s.lb              # tokens this row emitted
        st = self._stream.get(s.skey)
        out: list = []
        if st and len(st) > eidx:
            out = st[eidx:eidx + k]
        n = len(hist)
        if not out and n >= 2:
            for i in range(n - 3, -1, -1):
                if hist[i] == hist[-2] and hist[i + 1] == hist[-1]:
                    out = hist[i + 2:i + 2 + k]
                    break
        if not out and n >= 1:
            for i in range(n - 2, -1, -1):
                if hist[i] == hist[-1]:
                    out = hist[i + 1:i + 1 + k]
                    break
        if not out:
            out = [hist[-1] if hist else 0]
        while len(out) < k:
            out.append(out[-1])
        return out[:k]

    def _spec_block(self) -> List[Finished]:
        """One speculative round: draft k tokens per live row, verify all
        of them in a single (k+1)-wide window dispatch, accept the longest
        draft prefix matching the greedy argmaxes host-side. Exact
        greedy equivalence: position t's argmax is conditioned only on
        truly-emitted tokens once drafts 1..t-1 matched, so every emitted
        token equals what one-token-at-a-time would have produced; the
        mismatch position itself still yields one correct token (the
        argmax the drafts never influenced), so each round emits >= 1.
        Rejected drafts leave stale KV past the accepted depth — the next
        round's window rewrites those positions before any read."""
        rcfg = self.kernels.rcfg
        k = rcfg.spec_decode
        W = k + 1
        rows = [(i, s) for i, s in enumerate(self.slots) if s.busy]
        if not rows:
            return []
        if self.tracer is not None:
            self.tracer.span("decode", self.sim_now, replica=self.name,
                             steps=W, spec=True,
                             rids=tuple(s.req.rid for _, s in rows))
        self._cow_before_write([(i, s.pos + W) for i, s in rows])
        bb = MA.pow2_bucket(len(rows), 1, rcfg.max_batch)
        n_pad = bb - len(rows)
        toks_in = np.zeros((bb, W), np.int32)
        pos = np.zeros(bb, np.int32)
        for j, (i, s) in enumerate(rows):
            toks_in[j, 0] = s.last_tok
            toks_in[j, 1:] = self._draft(s, k)
            pos[j] = s.pos
        idx = np.asarray([i for i, _ in rows]
                         + [rcfg.max_batch] * n_pad, np.int32)
        need = int(pos.max()) + W
        ladder = rcfg.kv_ladder
        kvb = next((b for b in ladder if b >= need), ladder[-1])
        pages_sub = self.page_table[idx]
        fn = self.kernels.window_fn(bb, W, kvb, rcfg.pages_per_slot,
                                    stamp=False)
        self.cache, self.tok, self.active, self.remaining, toks = fn(
            self.params, toks_in, self.cache, self.tok, self.active,
            self.remaining, pos, pages_sub, idx,
            np.zeros(bb, np.int32))
        self.steps_dispatched += 1
        out = np.asarray(toks)
        done: List[Finished] = []
        self.spec_rounds += 1
        for j, (i, s) in enumerate(rows):
            g = out[j]                          # greedy argmaxes, (W,)
            m = 0
            while m < k and g[m] == toks_in[j, 1 + m]:
                m += 1
            e = min(m + 1, s.remaining)
            emitted = [int(t) for t in g[:e]]
            self.spec_drafted += k
            self.spec_accepted += m
            self.spec_emitted += e
            if self.record_tokens:
                self._log_tokens(s.req.rid, emitted)
            eidx = len(s.history) - s.lb        # emitted before this round
            st = self._stream.get(s.skey)
            if st is not None and eidx + e > len(st):
                # this row is the stream's frontier: pave for later twins
                st.extend(emitted[len(st) - eidx:])
            s.history.extend(emitted)
            s.last_tok = emitted[-1]
            s.remaining -= e
            if s.remaining == 0:
                done.append(Finished(s.req, s.req.max_new))
                self._retire_slot(i)
                self.content.pop(s.req.rid, None)
        return done

    def pump(self) -> List[Finished]:
        """Run to quiescence: admit -> fused block -> harvest -> admit ...
        Finished slots free mid-stream; arrivals join the very next block.
        Loops on pending too: when a whole admission finishes inside its
        fused tail, the slots it freed must be refilled before returning."""
        done = self._timed_admit()
        while any(s.busy for s in self.slots) or self.pending:
            if any(s.busy for s in self.slots):
                done.extend(self._timed_decode())
            done.extend(self._timed_admit())
        return done

    def step(self) -> List[Finished]:
        """One admission + one fused block (partial progress — lets callers
        interleave checkpoints or new arrivals between blocks)."""
        done = self._timed_admit()
        if not any(s.busy for s in self.slots):
            return done
        done.extend(self._timed_decode())
        done.extend(self._timed_admit())
        return done

    def _timed_admit(self) -> List[Finished]:
        if self.profiler is None:
            return self._admit_some()
        t0 = time.perf_counter()
        out = self._admit_some()
        self.profiler.add("pump.admit", time.perf_counter() - t0)
        return out

    def _timed_decode(self) -> List[Finished]:
        if self.profiler is None:
            return self._decode_block()
        t0 = time.perf_counter()
        out = self._decode_block()
        self.profiler.add("pump.decode", time.perf_counter() - t0)
        return out

    # --------------------------------------------------------- checkpoint
    def partial_tokens(self) -> int:
        """Tokens generated for still-running requests (credited into the
        checkpointed counters so finish-time credit of the remainder on
        the successor replica sums to exactly ``max_new`` per request)."""
        return sum(s.req.max_new - s.remaining for s in self.slots if s.busy)

    def state(self) -> Dict[str, np.ndarray]:
        """Slot table + pending queue as flat numpy arrays (what the drain
        controller can save through ``repro.checkpoint``). Restoration
        re-prefills — KV is derivable state; the request ledger and the
        content store (exact prompt tokens) are not, so both ship.
        Physical page ids are replica-local and deliberately absent: the
        successor's admission re-allocates from its own pool and rebuilds
        its page table, replaying identical tokens (the §4.5.4 page-table
        round-trip is logical, not physical)."""
        live = [(s.req.rid, s.req.arrival, s.req.prompt_len, s.remaining,
                 s.req.prefix_group, s.req.prefix_len,
                 s.req.deadline, s.req.priority, s.req.trace_id)
                for s in self.slots if s.busy and s.remaining > 0]
        live += [(r.rid, r.arrival, r.prompt_len, r.max_new,
                  r.prefix_group, r.prefix_len, r.deadline, r.priority,
                  r.trace_id)
                 for r in self.pending]
        arr = np.asarray(live, np.float64).reshape(-1, 9)
        rids = arr[:, 0].astype(np.int64)
        # content rows for the in-flight rids, padded to one rectangle
        toks = [self.content.get(int(rid), np.zeros(0, np.int32))
                for rid in rids]
        width = max((t.shape[0] for t in toks), default=0)
        content = np.zeros((len(toks), width), np.int32)
        for i, t in enumerate(toks):
            content[i, :t.shape[0]] = t
        return {
            "inflight_rid": rids,
            "inflight_arrival": arr[:, 1],
            "inflight_plen": arr[:, 2].astype(np.int64),
            "inflight_remaining": arr[:, 3].astype(np.int64),
            "inflight_group": arr[:, 4].astype(np.int64),
            "inflight_pfxlen": arr[:, 5].astype(np.int64),
            "inflight_deadline": arr[:, 6],
            "inflight_priority": arr[:, 7].astype(np.int64),
            "inflight_trace": arr[:, 8].astype(np.int64),
            "content_len": np.asarray([t.shape[0] for t in toks], np.int64),
            "content_tokens": content,
        }

    def ingest_content(self, state) -> None:
        """Adopt a checkpoint's content-store rows: restored rids replay
        their exact prompt tokens instead of re-randomizing."""
        rids = np.asarray(state.get("inflight_rid", ()))
        lens = np.asarray(state.get("content_len", ()))
        toks = np.asarray(state.get("content_tokens", ()))
        for i in range(min(rids.size, lens.size)):
            if lens[i] > 0:
                self.content[int(rids[i])] = \
                    toks[i, :int(lens[i])].astype(np.int32)

    def restore(self, state: Dict[str, np.ndarray]):
        """Re-enqueue checkpointed in-flight requests (counted tokens were
        already credited by the predecessor; ``max_new`` = what remains)."""
        self.ingest_content(state)
        self.pending.extend(requests_from_state(state))

    def drain(self) -> List[Request]:
        """Give back every in-flight request (runtime retirement path).
        The content store empties with it: whichever runtime re-admits a
        drained rid re-mints the identical tokens."""
        out = list(self.pending)
        self.pending = []
        for i, s in enumerate(self.slots):
            if s.busy:
                out.append(Request(s.req.rid, s.req.arrival,
                                   s.req.prompt_len, s.remaining,
                                   prefix_group=s.req.prefix_group,
                                   prefix_len=s.req.prefix_len,
                                   deadline=s.req.deadline,
                                   priority=s.req.priority,
                                   trace_id=s.req.trace_id))
                self._retire_slot(i)
        self.content.clear()
        return out
