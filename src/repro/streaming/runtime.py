"""Continuous-batching decode runtime: slot slab + bucketed compilation.

One ``DecodeRuntime`` per serving replica replaces the chunked
prefill-then-Python-decode path:

- **Paged KV slab** (default): KV lives in a shared pool of fixed-size
  physical pages (``model_api.init_paged_cache``); each slot owns a row
  of the host-side page table. Admission allocates exactly the pages a
  request's lifetime needs (``RuntimeConfig.page_footprint``) and
  ``pump`` frees them at retirement, so HBM per request tracks its
  actual length and the decode dispatch reads only the smallest
  ``kv_ladder`` bucket covering the deepest live row — an 8-token
  request no longer pays a 128-token request's attention cost.
  ``paged=False`` keeps the PR-2 dense slab: ``max_batch`` slots x
  ``capacity`` entries (``model_api.init_slab_cache``). Either way,
  nothing is ever re-allocated or grown per chunk.
- **Bucketed compilation**: prompts pad to power-of-two length buckets and
  admissions to power-of-two batch buckets, so the number of distinct jit
  traces is O(#length-buckets x #batch-buckets) + 1 fused decode trace,
  independent of the observed request mix. ``RuntimeKernels.trace_counts``
  exposes the actual trace tally for regression tests.
- **Fused decode**: ``decode_block`` greedy steps run as one
  ``jax.lax.scan`` dispatch with the slab donated (``model_api.fused_decode``)
  instead of one Python-loop dispatch per token.
- **Continuous batching**: after every block the host harvests finished
  slots, frees them, and admits pending requests immediately — a short
  request no longer rides along for its chunk-mates' ``max_new``.

Kernels (the jitted closures) are shared across replicas and cached per
mesh topology by ``ElasticServing.runtime_kernels``; the slab itself is
per-replica state. The slot table round-trips through the drain ->
checkpoint -> reschedule path as plain numpy arrays (``state()`` /
``restore()``), so in-flight requests survive a node eviction.

Request content store: each request's prompt tokens are materialized
once — deterministically from (rid, length bucket), independent of its
admission chunk-mates — kept in ``DecodeRuntime.content``, and carried
through ``state()``/``restore()``. A restored rid therefore replays its
*exact* prompt tokens on the successor replica, so greedy output across a
drain is token-identical to an undisturbed run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import Request
from repro.models import model_api as MA


def requests_from_state(state) -> List[Request]:
    """Decode a checkpointed slot table back into Request objects."""
    rids = np.asarray(state.get("inflight_rid", ()))
    if rids.size == 0:
        return []
    arrival = np.asarray(state["inflight_arrival"])
    plen = np.asarray(state["inflight_plen"])
    rem = np.asarray(state["inflight_remaining"])
    return [Request(int(rids[i]), float(arrival[i]), int(plen[i]),
                    int(rem[i])) for i in range(rids.size)]


@dataclass(frozen=True)
class RuntimeConfig:
    """Static shape policy — one kernels cache entry per distinct value.

    ``paged=True`` stores KV in a shared pool of ``page_size``-entry
    physical pages instead of one full-capacity row per slot: admission
    allocates each request ceil((prompt_bucket + max_new + 1) /
    page_size) pages, retirement frees them, and decode reads only the
    smallest ``kv_ladder`` bucket covering the deepest live row — HBM
    and attention cost track actual request lengths, so ``max_batch``
    can grow for short-request mixes under the same pool
    (``pool_pages``; 0 sizes the pool so every slot can hold a
    full-capacity request, i.e. no admission ever blocks on pages).
    It pays when capacity is provisioned well beyond the typical live
    depth (long-context posture, or the TPU Pallas per-row-exit path);
    with a tightly-sized slab the dense layout's single fused attention
    is faster on CPU — see ``bench_paged_decode`` for the crossover.

    The dense slab keeps its own length-proportionality lever:
    ``block_skip`` streams decode KV in blocks and the host engages it
    per dispatch whenever the deepest live row leaves at least half the
    capacity dead (0 disables — the PR-2 plain full-width attention)."""
    max_batch: int = 8            # slots in the slab
    min_prompt_bucket: int = 8
    max_prompt_bucket: int = 64
    max_new_cap: int = 64         # capacity headroom for generation
    decode_block: int = 16        # max fused steps per scan dispatch
    admit_tail: int = 4           # decode steps fused into each admission
    paged: bool = False           # paged KV pool vs dense per-slot slab
    page_size: int = 16           # KV entries per physical page
    pool_pages: int = 0           # pool size; 0 -> max_batch * pages_per_slot
    # dense-slab jnp decode: KV block size for runtime block skipping
    # (engaged per dispatch while live depth <= capacity/2); 0 restores
    # the PR-2 plain full-capacity attention everywhere
    block_skip: int = 32

    @property
    def capacity(self) -> int:
        # every admitted request fits without ring-wrapping
        return self.max_prompt_bucket + self.max_new_cap + 1

    @property
    def pages_per_slot(self) -> int:
        return -(-self.capacity // self.page_size)

    @property
    def padded_capacity(self) -> int:
        return self.pages_per_slot * self.page_size

    @property
    def n_pool_pages(self) -> int:
        return self.pool_pages or self.max_batch * self.pages_per_slot

    @property
    def prompt_buckets(self) -> Tuple[int, ...]:
        return MA.bucket_ladder(self.min_prompt_bucket, self.max_prompt_bucket)

    @property
    def batch_buckets(self) -> Tuple[int, ...]:
        return MA.bucket_ladder(1, self.max_batch)

    @property
    def block_ladder(self) -> Tuple[int, ...]:
        # fused-step buckets: the host picks the smallest block covering the
        # longest live request, so tail ticks don't over-run 16 steps deep
        return MA.bucket_ladder(min(4, self.decode_block), self.decode_block)

    @property
    def kv_ladder(self) -> Tuple[int, ...]:
        # logical KV-read buckets for paged decode: every page multiple,
        # not powers of two — a row at depth 33 reads 48 entries, not 64.
        # The ladder is page-granular because reads gather whole pages;
        # its length (= pages_per_slot) is the kv factor in max_traces.
        return tuple(self.page_size * (p + 1)
                     for p in range(self.pages_per_slot))

    def page_footprint(self, plen_bucket: int, max_new: int) -> int:
        """Physical pages a request owns for its whole life: prompt bucket
        + generation + the frozen-row write slot (mirrors capacity's +1)."""
        return -(-(plen_bucket + max_new + 1) // self.page_size)

    def fits(self, req: Request) -> bool:
        if req.prompt_len > self.max_prompt_bucket:
            return False
        plen = MA.pow2_bucket(req.prompt_len, self.min_prompt_bucket,
                              self.max_prompt_bucket)
        if plen + req.max_new + 1 > self.capacity:
            return False
        return (not self.paged
                or self.page_footprint(plen, req.max_new) <= self.n_pool_pages)


class PageAllocator:
    """Free list over the physical KV page pool (unit granularity — a
    "fragment" is just a reusable page, so mid-stream retirement never
    strands capacity). Page 0 is reserved as the null page: pad rows,
    retired slots and frozen rows write there; nothing reads it.

    Invariants (asserted by tests/test_paged_runtime.py):
      - page 0 is never handed out;
      - a page is owned by at most one slot at a time;
      - used + free == pool size at every step;
      - ``alloc`` is all-or-nothing (no partial grants to unwind).
    """

    def __init__(self, pool_pages: int):
        self.pool_pages = pool_pages
        # LIFO: freshly freed pages are reused first (warm in cache)
        self._free = list(range(pool_pages, 0, -1))

    @property
    def n_pages(self) -> int:          # physical pool incl. the null page
        return self.pool_pages + 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.pool_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        self._free.extend(pages)


class RuntimeKernels:
    """Jitted admission + fused-decode functions with a trace-count guard.

    The python bodies below execute only while jax traces them, so the
    ``trace_counts`` increments tally *compilations*, not calls — the
    bucketing contract ("O(#buckets) traces under any request mix") is a
    plain integer assertion away.
    """

    def __init__(self, cfg: ArchConfig, rcfg: RuntimeConfig, ctx=None):
        if not MA.supports_slots(cfg):
            raise ValueError(f"family {cfg.family!r} has no slot-slab decode")
        self.cfg, self.rcfg, self.ctx = cfg, rcfg, ctx
        self.trace_counts = {"admit": 0, "decode": 0}
        self._admit = {}                 # (batch_bucket, len_bucket) -> fn
        self._decode = {}                # fused steps -> fn

    @property
    def max_traces(self) -> int:
        """Bucketing contract: traces stay O(#buckets) under any request
        mix. Paged decode adds the kv-read-bucket dimension (which logical
        prefix of the page table a dispatch visits), so the bound picks up
        a ``kv_ladder`` factor — still shape-policy-static."""
        n_admit = len(self.rcfg.batch_buckets) * len(self.rcfg.prompt_buckets)
        n_decode = len(self.rcfg.block_ladder)
        if self.rcfg.paged:
            n_kv = len(self.rcfg.kv_ladder)
            # admissions with a fused tail also carry a kv bucket
            if self.rcfg.admit_tail:
                n_admit *= n_kv
            n_decode *= n_kv
        elif self.rcfg.block_skip:
            n_decode *= 2          # plain + block-skip variants per steps
        return n_admit + n_decode

    def admit_fn(self, bb: int, lb: int, kvb: int = 0):
        key = (bb, lb, kvb)
        if key in self._admit:
            return self._admit[key]
        cfg, ctx = self.cfg, self.ctx
        mod = MA.get_module(cfg)
        rcfg = self.rcfg
        tail = rcfg.admit_tail

        def admit(params, tokens, cache, tok, active, remaining,
                  slot_idx, max_new, pages=None, prompt_pages=None):
            self.trace_counts["admit"] += 1
            logits, pcache = mod.prefill(params, tokens, cfg, ctx)
            if rcfg.paged:
                cache = MA.scatter_prefill_paged(
                    cfg, cache, pcache, slot_idx, tokens.shape[1],
                    prompt_pages, rcfg.page_size)
            else:
                cache = MA.scatter_prefill(cfg, cache, pcache, slot_idx,
                                           tokens.shape[1])
            first = jnp.argmax(logits, -1).astype(jnp.int32)
            tok = tok.at[slot_idx].set(first[:, None])
            # pad rows (batch bucket > group size) target the overflow row
            # with max_new = 0: they go inert after one masked step
            active = active.at[slot_idx].set(max_new > 0)
            remaining = remaining.at[slot_idx].set(max_new)
            if tail:
                # fused decode tail: admission and the first few steps of
                # the whole slab ride one dispatch (half the sync points)
                # tail steps run plain on the dense slab (a freshly
                # admitted bucket usually fills a good share of capacity;
                # skipping is the decode blocks' per-dispatch decision)
                tok, cache, active, remaining, _ = MA.fused_decode(
                    params, tok, cache, active, remaining, cfg, ctx,
                    steps=tail, pages=pages,
                    kv_bucket=kvb if rcfg.paged else None,
                    block_skip=None if rcfg.paged else 0)
            return cache, tok, active, remaining

        fn = jax.jit(admit, donate_argnums=(2, 3, 4, 5))
        self._admit[key] = fn
        return fn

    def decode_fn(self, steps: int, kvb: int = 0, skip: bool = False):
        key = (steps, kvb, skip)
        if key in self._decode:
            return self._decode[key]
        cfg, ctx = self.cfg, self.ctx
        rcfg = self.rcfg

        def block(params, tok, cache, active, remaining, pages=None):
            self.trace_counts["decode"] += 1
            return MA.fused_decode(params, tok, cache, active, remaining,
                                   cfg, ctx, steps=steps, pages=pages,
                                   kv_bucket=kvb if rcfg.paged else None,
                                   block_skip=(None if rcfg.paged else
                                               (rcfg.block_skip if skip
                                                else 0)))

        fn = jax.jit(block, donate_argnums=(1, 2, 3, 4))
        self._decode[key] = fn
        return fn

    def put(self, tree):
        """Commit arrays to the serving mesh (replicated). Mixing
        mesh-committed params with uncommitted slab buffers makes every
        dispatch re-shard its inputs (~15x per-call overhead on CPU), so
        all runtime state goes through here."""
        if self.ctx is None or self.ctx.mesh is None:
            return jax.tree.map(jnp.asarray, tree)
        sh = jax.sharding.NamedSharding(self.ctx.mesh,
                                        jax.sharding.PartitionSpec())
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


@dataclass
class _Slot:
    req: Optional[Request] = None
    remaining: int = 0
    lb: int = 0                       # prompt-length bucket at admission
    pages: Tuple[int, ...] = ()       # physical pages owned (paged mode)

    @property
    def busy(self) -> bool:
        return self.req is not None

    @property
    def pos(self) -> int:
        """Current cache depth (host mirror of the device pos vector)."""
        return self.lb + (self.req.max_new - self.remaining)


@dataclass
class Finished:
    req: Request
    tokens: int                       # generated this runtime (<= req.max_new)


@dataclass
class DecodeRuntime:
    """Per-replica serving state: the slab + a host-side slot table."""
    kernels: RuntimeKernels
    params: object
    gen: int = 0                      # ElasticServing build generation
    pending: List[Request] = field(default_factory=list)
    slots: List[_Slot] = field(default_factory=list)
    # request content store: rid -> prompt tokens (length-bucket shaped);
    # checkpointed with the slot table so restored rids replay exactly
    content: Dict[int, np.ndarray] = field(default_factory=dict)
    steps_dispatched: int = 0         # fused blocks run (for perf telemetry)
    # pressure window: busy-slot / held-page peaks since the last
    # ``reset_pressure`` — ``pump()`` runs to quiescence, so end-of-tick
    # instantaneous readings would always be zero; the peak is what the
    # slab actually had to absorb this tick
    peak_slots: int = 0
    peak_pages: int = 0
    record_tokens: bool = False       # keep per-request token ids (tests)
    token_log: Dict[int, list] = field(default_factory=dict)

    def __post_init__(self):
        rcfg = self.kernels.rcfg
        if self.record_tokens and rcfg.admit_tail:
            raise ValueError("record_tokens needs admit_tail=0 (tail-step "
                             "token ids never leave the admission dispatch)")
        self.slots = [_Slot() for _ in range(rcfg.max_batch)]
        # one extra overflow row: admissions pad their batch up to a
        # power-of-two bucket and aim the pad rows here, so a group of 7
        # costs one (8, L) prefill instead of three (4/2/1, L) dispatches
        rows = rcfg.max_batch + 1
        if rcfg.paged:
            self.alloc = PageAllocator(rcfg.n_pool_pages)
            # host-owned page table, shipped with every dispatch: row ->
            # physical pages (0 = null). Freed rows are re-pointed at the
            # null page *before* their pages can be re-granted, so a
            # frozen row's idempotent KV write can never corrupt a
            # successor request's page.
            self.page_table = np.zeros((rows, rcfg.pages_per_slot), np.int32)
            self.pages_hwm = 0                  # pool high-water (telemetry)
            self._pages_dev = None              # mesh-committed copy
            self._pages_dirty = True
            self.cache = self.kernels.put(MA.init_paged_cache(
                self.kernels.cfg, rows, self.alloc.n_pages, rcfg.page_size))
        else:
            self.cache = self.kernels.put(MA.init_slab_cache(
                self.kernels.cfg, rows, rcfg.capacity))
        self.tok = self.kernels.put(jnp.zeros((rows, 1), jnp.int32))
        self.active = self.kernels.put(jnp.zeros((rows,), bool))
        self.remaining = self.kernels.put(jnp.zeros((rows,), jnp.int32))

    @property
    def _paged(self) -> bool:
        return self.kernels.rcfg.paged

    @property
    def pages_in_use(self) -> int:
        return self.alloc.used_pages if self._paged else 0

    @property
    def slots_in_use(self) -> int:
        """Busy slab slots (the dense-path pressure gauge,
        ``ersap_slab_slots_used``)."""
        return sum(s.busy for s in self.slots)

    @property
    def occupancy(self) -> float:
        """Memory-pressure fraction in [0, 1] — the HPA / twin signal:
        page-pool share when paged (HBM actually held), busy-slot share
        on the dense slab (whose HBM is fixed; slots are what run out).
        Peak over the current pressure window (see ``reset_pressure``)."""
        if self._paged:
            return max(self.pages_in_use, self.peak_pages) / \
                max(self.alloc.pool_pages, 1)
        return max(self.slots_in_use, self.peak_slots) / \
            max(len(self.slots), 1)

    def reset_pressure(self) -> None:
        """Start a new pressure-measurement window (one engine tick)."""
        self.peak_slots = self.slots_in_use
        self.peak_pages = self.pages_in_use

    def _device_pages(self):
        """Mesh-committed page table, refreshed only when the host table
        mutated (admission/retirement) — uncommitted per-dispatch inputs
        would re-shard the whole argument list (see ``RuntimeKernels.put``)."""
        if self._pages_dirty:
            self._pages_dev = self.kernels.put(jnp.asarray(self.page_table))
            self._pages_dirty = False
        return self._pages_dev

    def _kv_bucket(self, steps: int, incoming=()) -> int:
        """Smallest kv-read bucket covering every live row's cache depth at
        the end of a ``steps``-deep fused block (busy slots advance by at
        most min(steps, remaining); ``incoming`` rows are (lb, max_new)
        pairs about to be admitted at depth lb)."""
        need = 1
        for s in self.slots:
            if s.busy:
                need = max(need, s.pos + min(steps, s.remaining))
        for lb, max_new in incoming:
            need = max(need, lb + min(steps, max_new))
        ladder = self.kernels.rcfg.kv_ladder
        return next((b for b in ladder if b >= need), ladder[-1])

    # -------------------------------------------------------------- intake
    def submit(self, requests: List[Request]):
        self.pending.extend(requests)

    def fits(self, req: Request) -> bool:
        return self.kernels.rcfg.fits(req)

    @property
    def inflight(self) -> int:
        return sum(s.busy for s in self.slots) + len(self.pending)

    # ---------------------------------------------------------- admission
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.busy]

    def _admit_some(self) -> List[Finished]:
        """Admit pending requests into free slots: group by prompt-length
        bucket (largest group first), one padded prefill dispatch per
        group (with a fused decode tail — see ``RuntimeKernels.admit_fn``).
        Hysteresis: while decode is mid-stream, wait until a couple of
        slots are free rather than paying one prefill dispatch per freed
        slot (admission is still within one decode block of arrival)."""
        if not self.pending:
            return []
        rcfg = self.kernels.rcfg
        free = self._free_slots()
        busy = rcfg.max_batch - len(free)
        if busy and len(free) < min(len(self.pending),
                                    max(2, rcfg.max_batch // 2)):
            return []
        done: List[Finished] = []
        while free and self.pending:
            groups: Dict[int, List[Request]] = {}
            for r in self.pending:
                lb = MA.pow2_bucket(r.prompt_len, rcfg.min_prompt_bucket,
                                    rcfg.max_prompt_bucket)
                groups.setdefault(lb, []).append(r)
            lb, group = max(groups.items(), key=lambda kv: len(kv[1]))
            # co-schedule similar generation lengths: a homogeneous round
            # lets the block ladder pick tight fused blocks (a lone
            # max_new=16 request would otherwise pin 16-step blocks while
            # its 7 batch-mates idle after step 4)
            group = sorted(group, key=lambda r: -r.max_new)[:len(free)]
            pages: Dict[int, List[int]] = {}
            if self._paged:
                # all-or-nothing page grant per request; a request the pool
                # cannot hold right now stays pending until a retirement
                # frees pages (fits() guarantees it can be held eventually)
                granted = []
                for r in group:
                    pgs = self.alloc.alloc(
                        rcfg.page_footprint(lb, r.max_new))
                    if pgs is None:
                        break
                    granted.append(r)
                    pages[id(r)] = pgs
                group = granted
                if not group:
                    break
                self.pages_hwm = max(self.pages_hwm, self.alloc.used_pages)
                self.peak_pages = max(self.peak_pages, self.alloc.used_pages)
            taken = set(id(r) for r in group)
            self.pending = [r for r in self.pending if id(r) not in taken]
            take, free = free[:len(group)], free[len(group):]
            done.extend(self._admit_batch(group, take, lb, pages))
        return done

    def _prompt_tokens(self, rid: int, lb: int) -> np.ndarray:
        """Content-store lookup: a request's prompt tokens are minted once
        (deterministic in (rid, length bucket) — never in the admission
        grouping) and replayed verbatim on every later admission,
        including after a checkpoint/restore on another replica."""
        tok = self.content.get(rid)
        if tok is None or tok.shape[0] != lb:
            rng = np.random.default_rng(hash((rid, lb)) % (2 ** 31))
            tok = rng.integers(0, self.kernels.cfg.vocab, lb).astype(np.int32)
            self.content[rid] = tok
        return tok

    def _admit_batch(self, reqs: List[Request], slot_idx: List[int],
                     lb: int, pages: Dict[int, List[int]]) -> List[Finished]:
        rcfg = self.kernels.rcfg
        bb = MA.pow2_bucket(len(reqs), 1, rcfg.max_batch)
        n_pad = bb - len(reqs)
        # synthetic workload: the prompt is per-request noise from the
        # content store; right-pad to the length bucket and the pad joins
        # the (synthetic) context. Batch pads to the bucket too — pad rows
        # land in the overflow row, so their token values are irrelevant.
        tokens = np.stack([self._prompt_tokens(r.rid, lb) for r in reqs]
                          + [np.zeros(lb, np.int32)] * n_pad)
        max_new = np.asarray([r.max_new for r in reqs] + [0] * n_pad,
                             np.int32)
        idx = np.asarray(list(slot_idx) + [rcfg.max_batch] * n_pad, np.int32)
        if self._paged:
            # publish the grants in the page table (pad rows -> null page)
            npg_prompt = -(-lb // rcfg.page_size)
            prompt_pages = np.zeros((bb, npg_prompt), np.int32)
            for j, (r, i) in enumerate(zip(reqs, slot_idx)):
                pgs = pages[id(r)]
                self.page_table[i] = 0
                self.page_table[i, :len(pgs)] = pgs
                prompt_pages[j] = pgs[:npg_prompt]
            self._pages_dirty = True
            kvb = self._kv_bucket(rcfg.admit_tail,
                                  incoming=[(lb, int(r.max_new))
                                            for r in reqs])
            fn = self.kernels.admit_fn(bb, lb,
                                       kvb if rcfg.admit_tail else 0)
            self.cache, self.tok, self.active, self.remaining = fn(
                self.params, tokens, self.cache, self.tok,
                self.active, self.remaining, idx, max_new,
                pages=self._device_pages(), prompt_pages=prompt_pages)
        else:
            fn = self.kernels.admit_fn(bb, lb)
            # small host inputs commit inside the dispatch; only the
            # persistent slab state must live pre-committed on the mesh
            # (see kernels.put)
            self.cache, self.tok, self.active, self.remaining = fn(
                self.params, tokens, self.cache, self.tok,
                self.active, self.remaining, idx, max_new)
        for r, i in zip(reqs, slot_idx):
            self.slots[i] = _Slot(req=r, remaining=int(r.max_new), lb=lb,
                                  pages=tuple(pages.get(id(r), ())))
        self.peak_slots = max(self.peak_slots, self.slots_in_use)
        if self.record_tokens:                  # first token (prefill argmax)
            first = np.asarray(self.tok)[:, 0]
            for r, i in zip(reqs, slot_idx):
                self.token_log.setdefault(r.rid, []).append(int(first[i]))
        # the fused tail advanced every live row (old and new) tail steps
        return self._harvest(rcfg.admit_tail)

    # -------------------------------------------------------------- decode
    def _retire_slot(self, i: int) -> None:
        """Free slot ``i``: in paged mode its pages go back to the pool and
        its page-table row re-points at the null page, so the retired
        row's frozen KV write can never land in a re-granted page."""
        s = self.slots[i]
        if self._paged and s.pages:
            self.page_table[i] = 0
            self._pages_dirty = True
            self.alloc.free(s.pages)
        self.slots[i] = _Slot()

    def _harvest(self, steps: int) -> List[Finished]:
        done = []
        for i, s in enumerate(self.slots):
            if not s.busy:
                continue
            s.remaining -= min(steps, s.remaining)
            if s.remaining == 0:
                done.append(Finished(s.req, s.req.max_new))
                self._retire_slot(i)
                # content store follows the live request set (re-mintable
                # deterministically) — no monotonic growth across a stream
                self.content.pop(s.req.rid, None)
        return done

    def _decode_block(self) -> List[Finished]:
        maxrem = max((s.remaining for s in self.slots if s.busy), default=0)
        steps = next((b for b in self.kernels.rcfg.block_ladder
                      if b >= maxrem), self.kernels.rcfg.decode_block)
        rcfg = self.kernels.rcfg
        if self._paged:
            fn = self.kernels.decode_fn(steps, self._kv_bucket(steps))
            kw = {"pages": self._device_pages()}
        else:
            # engage dense block skipping only when the deepest live row
            # leaves at least half the slab capacity dead this block —
            # with a well-utilized slab the single fused attention wins
            depth = max((s.pos + min(steps, s.remaining)
                         for s in self.slots if s.busy), default=0)
            skip = bool(rcfg.block_skip) and 2 * depth <= rcfg.capacity
            fn = self.kernels.decode_fn(steps, skip=skip)
            kw = {}
        before = {i: s.remaining for i, s in enumerate(self.slots) if s.busy}
        self.tok, self.cache, self.active, self.remaining, toks = fn(
            self.params, self.tok, self.cache, self.active, self.remaining,
            **kw)
        self.steps_dispatched += 1
        if self.record_tokens:                  # test hook: syncs per block
            arr = np.asarray(toks)
            for i, rem in before.items():
                self.token_log.setdefault(self.slots[i].req.rid, []).extend(
                    arr[:min(steps, rem), i].tolist())
        return self._harvest(steps)

    def pump(self) -> List[Finished]:
        """Run to quiescence: admit -> fused block -> harvest -> admit ...
        Finished slots free mid-stream; arrivals join the very next block.
        Loops on pending too: when a whole admission finishes inside its
        fused tail, the slots it freed must be refilled before returning."""
        done = self._admit_some()
        while any(s.busy for s in self.slots) or self.pending:
            if any(s.busy for s in self.slots):
                done.extend(self._decode_block())
            done.extend(self._admit_some())
        return done

    def step(self) -> List[Finished]:
        """One admission + one fused block (partial progress — lets callers
        interleave checkpoints or new arrivals between blocks)."""
        done = self._admit_some()
        if not any(s.busy for s in self.slots):
            return done
        done.extend(self._decode_block())
        done.extend(self._admit_some())
        return done

    # --------------------------------------------------------- checkpoint
    def partial_tokens(self) -> int:
        """Tokens generated for still-running requests (credited into the
        checkpointed counters so finish-time credit of the remainder on
        the successor replica sums to exactly ``max_new`` per request)."""
        return sum(s.req.max_new - s.remaining for s in self.slots if s.busy)

    def state(self) -> Dict[str, np.ndarray]:
        """Slot table + pending queue as flat numpy arrays (what the drain
        controller can save through ``repro.checkpoint``). Restoration
        re-prefills — KV is derivable state; the request ledger and the
        content store (exact prompt tokens) are not, so both ship.
        Physical page ids are replica-local and deliberately absent: the
        successor's admission re-allocates from its own pool and rebuilds
        its page table, replaying identical tokens (the §4.5.4 page-table
        round-trip is logical, not physical)."""
        live = [(s.req.rid, s.req.arrival, s.req.prompt_len, s.remaining)
                for s in self.slots if s.busy and s.remaining > 0]
        live += [(r.rid, r.arrival, r.prompt_len, r.max_new)
                 for r in self.pending]
        arr = np.asarray(live, np.float64).reshape(-1, 4)
        rids = arr[:, 0].astype(np.int64)
        # content rows for the in-flight rids, padded to one rectangle
        toks = [self.content.get(int(rid), np.zeros(0, np.int32))
                for rid in rids]
        width = max((t.shape[0] for t in toks), default=0)
        content = np.zeros((len(toks), width), np.int32)
        for i, t in enumerate(toks):
            content[i, :t.shape[0]] = t
        return {
            "inflight_rid": rids,
            "inflight_arrival": arr[:, 1],
            "inflight_plen": arr[:, 2].astype(np.int64),
            "inflight_remaining": arr[:, 3].astype(np.int64),
            "content_len": np.asarray([t.shape[0] for t in toks], np.int64),
            "content_tokens": content,
        }

    def ingest_content(self, state) -> None:
        """Adopt a checkpoint's content-store rows: restored rids replay
        their exact prompt tokens instead of re-randomizing."""
        rids = np.asarray(state.get("inflight_rid", ()))
        lens = np.asarray(state.get("content_len", ()))
        toks = np.asarray(state.get("content_tokens", ()))
        for i in range(min(rids.size, lens.size)):
            if lens[i] > 0:
                self.content[int(rids[i])] = \
                    toks[i, :int(lens[i])].astype(np.int32)

    def restore(self, state: Dict[str, np.ndarray]):
        """Re-enqueue checkpointed in-flight requests (counted tokens were
        already credited by the predecessor; ``max_new`` = what remains)."""
        self.ingest_content(state)
        self.pending.extend(requests_from_state(state))

    def drain(self) -> List[Request]:
        """Give back every in-flight request (runtime retirement path).
        The content store empties with it: whichever runtime re-admits a
        drained rid re-mints the identical tokens."""
        out = list(self.pending)
        self.pending = []
        for i, s in enumerate(self.slots):
            if s.busy:
                out.append(Request(s.req.rid, s.req.arrival,
                                   s.req.prompt_len, s.remaining))
                self._retire_slot(i)
        self.content.clear()
        return out
