"""ERSAP-analog streaming inference engine (paper §5 workload + §6 queue).

Pipeline: RequestSource (Poisson sender) -> FIFO queue -> batcher ->
serving replicas (real prefill+decode on the mesh) -> sink. Each replica
is a JIRIAF pod on a VirtualNode, exports metrics (queue depth, served,
latency) through the §4.6 monitoring stack, and the control loop couples
the §4.4 HPA and the §6 digital twin to elastic replica scaling.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hpa import HPA, HPAConfig, MetricSample
from repro.core.jrm import VirtualNode
from repro.core.metrics import (Endpoint, Prometheus, Registry, Service,
                                ServiceMonitor)
from repro.core.state_machine import Container, Pod
from repro.core.digital_twin.control import ControlPolicy, replicas_for_control
from repro.core.digital_twin.dbn import DigitalTwin
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA


@dataclass
class ReplicaStats:
    served: int = 0
    tokens: int = 0


@dataclass
class StreamEngine:
    cfg: ArchConfig
    serving: object                   # ElasticServing
    nodes: List[VirtualNode]
    max_batch: int = 8
    service_rate: float = 40.0        # requests/s one replica can absorb
    queue: List[Request] = field(default_factory=list)
    source: RequestSource = field(default_factory=RequestSource)
    pods: Dict[str, Pod] = field(default_factory=dict)
    registries: Dict[str, Registry] = field(default_factory=dict)
    prom: Prometheus = field(default_factory=Prometheus)
    stats: Dict[str, ReplicaStats] = field(default_factory=dict)
    completed: list = field(default_factory=list)
    control: int = 16
    twin: DigitalTwin = field(default_factory=DigitalTwin)
    policy: ControlPolicy = field(default_factory=ControlPolicy)
    hpa: Optional[HPA] = None
    base_replicas: int = 1
    use_twin: bool = True
    history: list = field(default_factory=list)

    # ------------------------------------------------------------ setup
    def deploy(self, now: float = 0.0):
        """Create one pod per current replica on the least-loaded nodes and
        wire the monitoring stack (Service + ServiceMonitor + Prometheus)."""
        svc = Service("ersap-metrics", selector={"app": "ersap"},
                      labels={"monitored": "true"})
        for i in range(self.serving.replicas):
            name = f"ersap-{i}"
            if name in self.pods:
                continue
            pod = Pod(name=name,
                      containers=[Container(name="ersap-engine")],
                      labels={"app": "ersap"},
                      tolerations=[{"key": "virtual-kubelet.io/provider",
                                    "value": "mock"}],
                      request_chips=self.serving.tp)
            node = min(self.nodes, key=lambda n: n.used_chips())
            node.create_pod(pod, now)
            self.pods[name] = pod
            reg = Registry(port=2221)
            self.registries[name] = reg
            self.stats[name] = ReplicaStats()
            svc.add_endpoint(Endpoint(
                pod=name, pod_ip=node.pod_ip, port=2221,
                cp_port=20000 + i, registry=reg))
        # retire pods beyond replica count (scale down)
        for i in range(self.serving.replicas, len(self.pods)):
            name = f"ersap-{i}"
            pod = self.pods.pop(name, None)
            if pod and pod.node:
                node = next(n for n in self.nodes if n.name == pod.node)
                node.delete_pod(name, now)
                self.registries.pop(name, None)
        self.prom.services = [svc]
        if not self.prom.monitors:
            self.prom.monitors = [ServiceMonitor(
                "ersap-mon", service_selector={"monitored": "true"})]

    # ------------------------------------------------------------- tick
    def tick(self, now: float, dt: float, lam: float):
        """One engine step of simulated time dt with arrival rate lam."""
        self.queue.extend(self.source.arrivals(now, dt, lam))
        # per-replica service capacity this tick (mu * dt, M/M/1 analog —
        # doubling replicas doubles capacity, the paper's 16->32 threads)
        budget = int(self.service_rate * dt)
        for i in range(self.serving.replicas):
            name = f"ersap-{i}"
            reg = self.registries.get(name)
            if reg is None:
                continue
            n_take = min(len(self.queue), budget)
            took, self.queue = self.queue[:n_take], self.queue[n_take:]
            for j in range(0, len(took), self.max_batch):
                chunk = took[j:j + self.max_batch]
                self._process(chunk, name, now)
            reg.gauge("ersap_queue_len").set(len(self.queue))
            reg.counter("ersap_served_total")
        self.prom.scrape(now)
        self.history.append((now, len(self.queue), self.serving.replicas,
                             self.control))
        return len(self.queue)

    def _process(self, requests: List[Request], replica: str, now: float):
        """Actually run the model: batched prefill + greedy decode."""
        if not requests:
            return
        B = len(requests)
        plen = requests[0].prompt_len
        rng = np.random.default_rng(int(now * 1000) % (2**31))
        toks = rng.integers(0, self.cfg.vocab, (B, plen)).astype(np.int32)
        logits, cache = self.serving.prefill_fn(self.serving.params, toks)
        cache = MA.grow_cache(self.cfg, cache,
                              plen + (self.cfg.n_meta_tokens or 0)
                              + max(r.max_new for r in requests) + 1)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        n_new = max(r.max_new for r in requests)
        for _ in range(n_new):
            logits, cache = self.serving.decode_fn(self.serving.params, tok,
                                                   cache)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        reg = self.registries[replica]
        st = self.stats[replica]
        st.served += B
        st.tokens += B * n_new
        reg.counter("ersap_served_total").inc(B)
        reg.counter("ersap_tokens_total").inc(B * n_new)
        for r in requests:
            reg.histogram("ersap_latency_s").observe(max(now - r.arrival, 0.0))
            self.completed.append((r.rid, now))

    # ---------------------------------------------------------- control
    def control_step(self, now: float):
        """Assimilate queue depth into the twin; recommend capacity; apply
        via elastic scaling. HPA path available for the reactive baseline."""
        qlen = max(len(self.queue), 1e-3)
        self.twin.assimilate(qlen, self.control)
        if self.use_twin:
            self.control = self.policy.recommend(self.twin, self.control, now)
            desired = replicas_for_control(self.control, self.base_replicas)
        else:
            samples = {name: MetricSample(qlen / max(len(self.pods), 1), now)
                       for name in self.pods}
            desired = self.hpa.evaluate(list(self.pods.values()), samples, now)
        desired = min(desired, self.serving.max_replicas())
        if desired != self.serving.replicas:
            self.serving.scale_to(desired, now)
            self.deploy(now)
        return desired
