"""ERSAP-analog streaming inference engine (paper §5 workload + §6 queue).

Pipeline: RequestSource (Poisson sender) -> FIFO queue -> per-replica
**decode runtimes** (slot-slab continuous batching,
``repro.streaming.runtime``) -> sink.

Serving path (PR 2, paged in PR 4): each bound replica owns a
``DecodeRuntime`` — a paged KV slab (``max_batch`` slots over a shared
pool of fixed-size pages, page-aware admission/retirement, decode cost
proportional to live tokens) with bucketed-compilation admission and a
fused ``lax.scan`` decode block. ``ersap_kv_pages`` gauges per-replica
pool occupancy. ``tick()`` meters
requests off the FIFO queue by a fractional service budget (no more
integer-truncation starvation at low rates), submits them to the
replica's runtime, and pumps it to quiescence: finished requests free
their slots mid-stream and pending ones are admitted immediately, so the
number of jit traces stays O(#buckets) and short requests stop riding
along for their chunk-mates' ``max_new``. Families without a slot-slab
decode (recurrent caches) and oversized requests fall back to the legacy
chunked path. The runtime's slot table is part of the replica's
checkpoint state, so in-flight requests survive the §4.5.4 drain ->
checkpoint -> evict -> reschedule loop.

Declarative control plane: the engine declares a ``Deployment`` ("ersap")
in the Cluster store; the DeploymentController converges
``spec.replicas`` -> pods, the Scheduler places them (spread across
nodes, straggler-averse), and the NodeLifecycleController drains
walltime-expiring nodes — checkpointing each replica's runtime state via
``repro.checkpoint`` so the rescheduled replica resumes its counters and
its slot table. The HPA and the digital-twin policy are both
*desired-replica writers*: ``control_step`` computes a target and writes
``Deployment.replicas``; reconciliation does the rest. Metrics (queue
depth, served, latency) flow through the §4.6 monitoring stack, whose
Service endpoints (and control-plane port map) are rebuilt from live pods
every sync — retired replicas leave no stale scrape targets or ports.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import qos
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.hpa import HPA, HPAConfig, PressureSignals
from repro.core.jrm import VirtualNode
from repro.core.metrics import (COUNT_BUCKETS, Endpoint, Prometheus,
                                Registry, Service, ServiceMonitor,
                                split_series)
from repro.core.observability import render_exposition
from repro.core.state_machine import Pod
from repro.core.digital_twin.control import ControlPolicy, replicas_for_control
from repro.core.digital_twin.dbn import DigitalTwin
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA
from repro.streaming.runtime import (DecodeRuntime, RuntimeConfig,
                                     requests_from_state)

DEPLOYMENT = "ersap"


@dataclass
class ReplicaStats:
    served: int = 0
    tokens: int = 0


@dataclass
class StreamEngine:
    cfg: ArchConfig
    serving: object                   # ElasticServing
    nodes: List[VirtualNode]
    max_batch: int = 8
    service_rate: float = 40.0        # requests/s one replica can absorb
    queue: List[Request] = field(default_factory=list)
    source: RequestSource = field(default_factory=RequestSource)
    registries: Dict[str, Registry] = field(default_factory=dict)
    prom: Prometheus = field(default_factory=Prometheus)
    stats: Dict[str, ReplicaStats] = field(default_factory=dict)
    completed: list = field(default_factory=list)
    control: int = 16
    twin: DigitalTwin = field(default_factory=DigitalTwin)
    policy: ControlPolicy = field(default_factory=ControlPolicy)
    hpa: Optional[HPA] = None
    base_replicas: int = 1
    use_twin: bool = True
    priority_class: str = "standard"  # serving Deployment's initial tier
    use_runtime: bool = True          # slot-slab runtime (when family allows)
    runtime_cfg: Optional[RuntimeConfig] = None
    # per-rid greedy token logs on every replica runtime (needs a
    # runtime_cfg with admit_tail=0): the chaos bench's oracle-comparison
    # evidence that recovery is token-identical, never duplicated
    record_tokens: bool = False
    history: list = field(default_factory=list)
    # declarative control plane (built from ``nodes`` unless injected)
    cluster: Optional[Cluster] = None
    plane: Optional[ControlPlane] = None
    total_served: int = 0
    total_tokens: int = 0
    tokens_rate: float = 0.0          # tokens/s over the last tick (HPA signal)
    runtimes: Dict[str, DecodeRuntime] = field(default_factory=dict)
    _cp_ports: Dict[str, int] = field(default_factory=dict)
    _next_cp_port: int = 20000
    _budget_frac: float = 0.0         # fractional service budget carry
    # last known node per replica: when a pod vanishes from the store we
    # still need to know whether its node was reachable (partition vs
    # graceful retire) to pick the right recovery path in _sync
    _pod_nodes: Dict[str, str] = field(default_factory=dict)
    # ------------- overload protection & graceful degradation ----------
    # bounded arrival FIFO (0 = unbounded): overflow is backpressured to
    # the RequestSource (reject-with-retry-after) instead of growing
    queue_cap: int = 0
    brownout: Optional[qos.BrownoutController] = None
    retry_budget: Optional[qos.RetryBudget] = None
    breaker: Optional[qos.ReplicaBreaker] = None
    # per-rid greedy-log ring cap handed to every runtime (0 = unbounded)
    token_log_cap: int = 0
    # cost-modeled failover: while now < degrade_until (set by the
    # drain_site transfer window) the engine serves at least at this
    # brownout level — shed the batch tier, protect latency-critical
    transfer_degrade_level: int = 2
    degrade_until: float = 0.0
    transfer_windows: int = 0
    shed: list = field(default_factory=list)        # (rid, reason, now)
    _level: int = 0               # effective brownout level this tick
    _last_dt: float = 1.0
    # ---------------------- observability plane ------------------------
    # engine-level registry (pod label "_engine" in the exposition):
    # queue/brownout gauges, queue-wait histogram, shed/reject/retry
    # counters — the one place overload accounting lives (the old ad-hoc
    # shed_counts/rejected_total/retried_total are compat views below)
    metrics: Registry = field(default_factory=Registry)
    tracer: object = None             # repro.core.tracing.Tracer
    recorder: object = None           # repro.core.observability.FlightRecorder
    profiler: object = None           # repro.core.observability.TickProfiler
    # rid -> sim-time its drain span landed (restore-latency burn input)
    _drain_t: Dict[int, float] = field(default_factory=dict)

    # ------------------- overload accounting (compat) ------------------
    @property
    def shed_counts(self) -> Dict[str, int]:
        """Per-reason shed counts, read back from the labeled
        ``ersap_shed_total`` counter series (compat shim for the old
        ad-hoc dict)."""
        out: Dict[str, int] = {}
        for key, m in self.metrics.metrics.items():
            base, lbl = split_series(key)
            if base == "ersap_shed_total" and lbl:
                reason = lbl[1:-1].split("=", 1)[1].strip('"')
                out[reason] = int(m.value)
        return out

    @property
    def rejected_total(self) -> int:
        return int(self.metrics.counter("ersap_rejected_total").value)

    @property
    def retried_total(self) -> int:
        return int(self.metrics.counter("ersap_retried_total").value)

    # ------------------------------------------------------------ setup
    @property
    def pods(self) -> Dict[str, Pod]:
        """Live bound pods of the ersap Deployment (status view)."""
        if self.cluster is None:
            return {}
        return {r.name: r.pod
                for r in self.cluster.pods_of(DEPLOYMENT) if r.bound}

    def _ensure_plane(self, now: float):
        if self.cluster is None:
            self.cluster = Cluster()
        for n in self.nodes:
            if n.name not in self.cluster.nodes:
                self.cluster.register_node(n, now)
        if self.plane is None:
            self.plane = ControlPlane(self.cluster)
        self._wire_plane_obs()
        if self.plane.on_transfer is None:
            # drain_site reports its checkpoint-transfer window here so
            # the engine serves degraded while state crosses facilities
            self.plane.on_transfer = self._on_transfer
        if self.runtime_cfg is None:
            self.runtime_cfg = RuntimeConfig(max_batch=self.max_batch)

    def _replica_state(self, name: str) -> Optional[dict]:
        st = self.stats.get(name)
        if st is None:
            return None
        state = {"served": st.served, "tokens": st.tokens}
        rt = self.runtimes.get(name)
        if rt is not None:
            # credit partial generation now; the successor replica credits
            # only the checkpointed remainder at finish, so per-request
            # token totals stay exact across a reschedule
            state["tokens"] = st.tokens + rt.partial_tokens()
            state.update(rt.state())
        return state

    def deploy(self, now: float = 0.0):
        """Declare (or re-declare) the serving Deployment at the current
        replica count and reconcile until pods and monitoring converge."""
        self._ensure_plane(now)
        if DEPLOYMENT not in self.cluster.deployments:
            self.cluster.apply_deployment(Deployment(
                DEPLOYMENT, self.serving.replicas,
                template=PodTemplate(
                    labels={"app": "ersap"},
                    tolerations=[{"key": "virtual-kubelet.io/provider",
                                  "value": "mock"}],
                    request_chips=self.serving.tp,
                    priority_class=self.priority_class,
                    # declared KV footprint per replica: what the
                    # kv_pages quota dimension charges at schedule time
                    request_kv_pages=(self.runtime_cfg.n_pool_pages
                                      if self.runtime_cfg.paged else 0),
                    checkpoint_state=self._replica_state)), now)
        else:
            self.cluster.scale(DEPLOYMENT, self.serving.replicas, now,
                               source="engine")
        self.reconcile(now)

    def reconcile(self, now: float):
        """One control-plane step + engine-side sync (registries, stats,
        runtimes, Service endpoints follow the pod set — nothing leaks on
        retire)."""
        self._ensure_plane(now)
        self.plane.step(now)
        self._sync(now)

    # ----------------------------------------------------------- runtimes
    def _make_runtime(self, name: str) -> Optional[DecodeRuntime]:
        if not (self.use_runtime and MA.supports_slots(self.cfg)):
            return None
        kernels = self.serving.runtime_kernels(self.runtime_cfg)
        return DecodeRuntime(kernels, self.serving.params,
                             gen=self.serving.build_gen,
                             record_tokens=self.record_tokens,
                             token_log_cap=self.token_log_cap,
                             name=name, tracer=self.tracer,
                             metrics=self.registries.get(name),
                             profiler=self.profiler)

    def _credit_partial(self, name: str, rt: DecodeRuntime):
        """Credit partial generation of in-flight slots before their
        requests are requeued with max_new = remaining, so partial +
        finish-time credit sums to exactly the original max_new."""
        partial = rt.partial_tokens()
        if not partial:
            return
        st = self.stats.get(name)
        if st is not None:
            st.tokens += partial
        self.total_tokens += partial

    def _known_rids(self) -> set:
        """Request ids already accounted for somewhere in the engine."""
        rids = {r.rid for r in self.queue}
        for rt in self.runtimes.values():
            rids.update(r.rid for r in rt.pending)
            rids.update(s.req.rid for s in rt.slots if s.busy)
        rids.update(rid for rid, _ in self.completed)
        return rids

    def _refresh_runtime(self, name: str) -> Optional[DecodeRuntime]:
        """Replica's runtime, rebuilt (in-flight preserved) whenever the
        serving mesh was re-built underneath it."""
        rt = self.runtimes.get(name)
        if rt is not None and rt.gen != self.serving.build_gen:
            self._credit_partial(name, rt)
            carried = rt.drain()
            rt = self._make_runtime(name)
            if rt is not None:
                rt.submit(carried, force=True)
                self.runtimes[name] = rt
            else:
                self.queue = carried + self.queue
                self.runtimes.pop(name, None)
        return rt

    def _node_reachable(self, name: str) -> bool:
        """Whether the replica's (last known) node is control-plane
        reachable. Unknown nodes count as reachable."""
        node = self._pod_nodes.get(name)
        st = self.cluster.node_status.get(node) if node else None
        return st is None or st.reachable

    def _sync(self, now: float):
        live = {r.name: r for r in self.cluster.pods_of(DEPLOYMENT)
                if r.bound}
        for name, rec in live.items():
            if rec.pod.node:
                self._pod_nodes[name] = rec.pod.node
        for name in list(self.registries):
            if name not in live:
                rt = self.runtimes.pop(name, None)
                if rt is not None:
                    if self._node_reachable(name):
                        # graceful retire: credit partial output, hand
                        # back in-flight with max_new = remaining
                        self._credit_partial(name, rt)
                    # else: partition — the replica's streamed output is
                    # unobservable, so nothing is credited; the frontend
                    # re-issues its in-flight requests (zero loss even
                    # for rids admitted after the last checkpoint) and
                    # they replay from the prompt. Checkpoint-restored
                    # copies of the same rids dedupe against these queue
                    # entries below, and the orphaned replica itself is
                    # epoch-fenced on rejoin, so nothing double-emits.
                    drained = rt.drain()
                    for r in drained:
                        self._drain_t[r.rid] = now
                        if self.tracer is not None:
                            self.tracer.span("drain", now, rid=r.rid,
                                             replica=name)
                    self.queue = drained + self.queue
                self.registries.pop(name, None)
                self.stats.pop(name, None)
                self._pod_nodes.pop(name, None)
        # prune the §4.6.3 control-plane port map with the registries —
        # ports stay stable for live pods but no longer grow monotonically
        # across evict/reschedule cycles
        for name in list(self._cp_ports):
            if name not in live:
                self._cp_ports.pop(name)
        for name, rec in sorted(live.items()):
            if name in self.registries:
                continue
            self.registries[name] = Registry(port=2221)
            st = ReplicaStats()
            rt = self._make_runtime(name)
            if rt is not None:
                self.runtimes[name] = rt
            if rec.restored_state:
                st.served = int(rec.restored_state.get("served", 0))
                st.tokens = int(rec.restored_state.get("tokens", 0))
                # slot table survives drain -> checkpoint -> reschedule:
                # in-flight requests resume on the replacement replica.
                # Dedupe against requests already handed back through the
                # retire path above (the evicted replica's runtime drains
                # into the queue AND its checkpoint names the same rids —
                # each request must be served exactly once).
                known = self._known_rids()
                from_ckpt = requests_from_state(rec.restored_state)
                for r in from_ckpt:
                    t0 = self._drain_t.pop(r.rid, now)
                    if self.tracer is not None:
                        # restore spans bump the rid's incarnation in the
                        # tracer, so post-restore hops are distinguishable
                        self.tracer.span("restore", now, rid=r.rid,
                                         replica=name)
                    if self.recorder is not None:
                        self.recorder.note_restore(now, now - t0)
                restored = [r for r in from_ckpt if r.rid not in known]
                if rt is not None:
                    # content store rides the checkpoint: restored rids
                    # replay their exact prompt tokens
                    rt.ingest_content(rec.restored_state)
                    rt.submit(restored, force=True)
                else:
                    self.queue = restored + self.queue
            self.stats[name] = st
        # Service endpoints rebuilt from live pods only (§4.6.3 port remap
        # stays unique per pod even though all VK pods share one pod IP)
        svc = Service("ersap-metrics", selector={"app": "ersap"},
                      labels={"monitored": "true"})
        for name, rec in sorted(live.items()):
            node = self.cluster.nodes.get(rec.pod.node)
            if node is None:
                continue
            if name not in self._cp_ports:
                self._cp_ports[name] = self._next_cp_port
                self._next_cp_port += 1
            svc.add_endpoint(Endpoint(
                pod=name, pod_ip=node.pod_ip, port=2221,
                cp_port=self._cp_ports[name], registry=self.registries[name]))
        self.prom.services = [svc]
        if not self.prom.monitors:
            self.prom.monitors = [ServiceMonitor(
                "ersap-mon", service_selector={"monitored": "true"})]

    # --------------------------------------------- overload protection
    def _on_transfer(self, now: float, window: float):
        """drain_site failover hook: the checkpoint-transfer window just
        started — serve degraded (shed batch, protect latency-critical)
        until the state has physically arrived at the destination site."""
        self.degrade_until = max(self.degrade_until, now + window)
        self.transfer_windows += 1
        # span emission lives in ControlPlane.drain_site (site context);
        # here we only feed the flight recorder's burn-rate windows
        if self.recorder is not None:
            self.recorder.event(now, "transfer", f"window={window:.2f}s")
            self.recorder.note_restore(now, window)

    def _shed(self, req: Request, reason: str, now: float):
        self.shed.append((req.rid, reason, now))
        self.metrics.counter("ersap_shed_total",
                             labels={"reason": reason}).inc()
        if self.tracer is not None:
            self.tracer.span("shed", now, rid=req.rid, reason=reason)
        if self.recorder is not None:
            self.recorder.note_shed(now)

    def _backpressure(self, overflow: List[Request], now: float):
        """Bounded-queue rejection: estimate retry-after from backlog vs
        capacity, then per request either shed (deadline unreachable, or
        the tenant's retry budget is dry — no retry storms) or defer back
        through the RequestSource for a client-side retry."""
        self.metrics.counter("ersap_rejected_total").inc(len(overflow))
        cap = self.service_rate * max(len(self.registries), 1)
        retry_after = max(self._last_dt,
                          len(self.queue) / max(cap, 1e-9))
        for r in overflow:
            if r.deadline > 0 and now + retry_after > r.deadline:
                self._shed(r, "deadline", now)
            elif self.retry_budget is not None and not self.retry_budget \
                    .allow(qos.tier_label(r.priority), now):
                self._shed(r, "retry-budget", now)
            else:
                self.source.defer([r], now + retry_after)
                self.metrics.counter("ersap_retried_total").inc()

    def _police_queue(self, now: float):
        """Deadline-aware admission + brownout shedding, applied to the
        whole FIFO *before* any request reaches prefill: expired requests
        and tiers below the current shed floor never burn compute."""
        floor = qos.shed_floor_for_level(self._level)
        shed0 = len(self.shed)
        keep: List[Request] = []
        for r in self.queue:
            if r.deadline > 0 and now > r.deadline:
                self._shed(r, "deadline", now)
            elif floor and r.priority < floor:
                self._shed(r, "brownout", now)
            else:
                keep.append(r)
        self.queue = keep
        if self.tracer is not None and len(self.shed) > shed0:
            self.tracer.span("police", now, kept=len(keep),
                             shed=len(self.shed) - shed0)

    def _degrade_cap(self) -> int:
        return (self.brownout.degrade_max_new if self.brownout is not None
                else qos.BrownoutController.degrade_max_new)

    # ------------------------------------------------------------- tick
    def tick(self, now: float, dt: float, lam: float):
        """One engine step of simulated time dt with arrival rate lam.
        Capacity follows the *actual* replica set in the cluster store."""
        self._last_dt = dt
        arrivals = self.source.arrivals(now, dt, lam)
        if self.queue_cap > 0 and \
                len(self.queue) + len(arrivals) > self.queue_cap:
            room = max(self.queue_cap - len(self.queue), 0)
            # reject lowest-tier-first: latency-critical arrivals take
            # the remaining room before any lower tier is admitted
            # (stable sort keeps FIFO order within a tier)
            ranked = sorted(arrivals, key=lambda r: -r.priority)
            self.queue.extend(ranked[:room])
            self._backpressure(ranked[room:], now)
        else:
            self.queue.extend(arrivals)
        # brownout level: slab occupancy + queue-delay EWMA watermarks
        # with hysteresis; a drain_site transfer window forces at least
        # ``transfer_degrade_level`` for its duration
        level = 0
        if self.brownout is not None:
            # arrival stamps land inside (now, now+dt), so clamp ages at 0;
            # deferred re-releases keep their original stamp and age truly.
            # Occupancy input: backlog share of the bounded queue when one
            # is configured — the slab's per-tick *peak* saturates at 1.0
            # whenever a single batch fills, which says nothing about
            # sustained overload — else the slab share.
            ages = [max(now - r.arrival, 0.0) for r in self.queue]
            delay = float(np.mean(ages)) if ages else 0.0
            occ = (len(self.queue) / self.queue_cap if self.queue_cap > 0
                   else self.slab_pressure())
            level = self.brownout.update(now, occ, delay)
        if now < self.degrade_until:
            level = max(level, self.transfer_degrade_level)
        self._level = level
        self._police_queue(now)
        # per-replica service capacity this tick (mu * dt, M/M/1 analog —
        # doubling replicas doubles capacity, the paper's 16->32 threads).
        # The fractional part carries across ticks so mu*dt < 1 meters
        # slowly instead of truncating to a permanently stalled queue.
        self._budget_frac += self.service_rate * dt
        budget = int(self._budget_frac)
        self._budget_frac -= budget
        cap = self._degrade_cap() if level >= 1 else 0
        tokens_before = self.total_tokens
        for name in sorted(self.registries):
            reg = self.registries[name]
            if not self._node_reachable(name):
                # partitioned replica: the frontend can't route to it nor
                # observe its output — freeze it (no metering, no pump)
                # until the lifecycle controller re-serves its work
                # elsewhere and the rejoining node is epoch-fenced
                reg.gauge("ersap_queue_len").set(len(self.queue))
                continue
            allow = -1
            if self.breaker is not None:
                allow = self.breaker.allow(name, now)
                if allow == 0:
                    # ejected replica: route around it entirely until the
                    # cool-off elapses and probe traffic passes
                    reg.gauge("ersap_queue_len").set(len(self.queue))
                    continue
            n_take = min(len(self.queue), budget)
            if allow >= 0:
                n_take = min(n_take, allow)       # half-open: probes only
            took, self.queue = self.queue[:n_take], self.queue[n_take:]
            if took:
                # queue-wait distribution: time each request spent in the
                # FIFO before reaching a replica (deferred retries age too)
                h = self.metrics.histogram("ersap_queue_wait_s")
                for r in took:
                    h.observe(max(now - r.arrival, 0.0))
            if self.breaker is not None and allow >= 0:
                self.breaker.note_probe(name, len(took))
            if cap:
                # polite degradation: cap generation length before
                # dropping anyone (greedy decode is deterministic in the
                # prompt, so capped output is a prefix of the full one)
                took = [replace(r, max_new=min(r.max_new, cap))
                        if r.max_new > cap else r for r in took]
            rt = self.runtimes.get(name)
            if rt is not None:
                rt.reset_pressure()    # per-tick slab-pressure window
                rt.sim_now = now       # runtime spans carry sim-time
                rt.spec_enabled = (level == 0)
            st0 = self.stats.get(name)
            tokens0 = st0.tokens if st0 is not None else 0
            self._process(took, name, now)
            if self.breaker is not None:
                st1 = self.stats.get(name)
                self.breaker.observe(
                    name, now, (st1.tokens if st1 is not None else 0)
                    - tokens0, had_work=bool(took))
            reg.gauge("ersap_queue_len").set(len(self.queue))
            reg.gauge("ersap_brownout_level").set(level)
            rt = self.runtimes.get(name)
            if rt is not None:
                # slab pressure, both layouts: busy slots always (the
                # dense path's only exhaustible resource), plus held KV
                # pages when paged (pool high-water mark is the
                # capacity-planning signal for sizing pool_pages). Both
                # feed the HPA/twin memory-pressure input (slab_pressure)
                # and scrape the per-tick *peak* — pump() runs to
                # quiescence, so the instantaneous value here is 0.
                reg.gauge("ersap_slab_slots_used").set(rt.peak_slots)
                # the per-tick peaks also land in histograms so the
                # HPA/twin and the exporter see the *distribution* of
                # occupancy peaks, not the last-write-wins gauge value
                reg.histogram("ersap_slab_slots_peak",
                              buckets=COUNT_BUCKETS).observe(rt.peak_slots)
                if rt.kernels.rcfg.paged:
                    reg.gauge("ersap_kv_pages").set(rt.peak_pages)
                    reg.histogram("ersap_kv_pages_peak",
                                  buckets=COUNT_BUCKETS).observe(
                                      rt.peak_pages)
                    reg.gauge("ersap_pages_hwm").set(rt.pages_hwm)
                # prefix-cache / speculative-decode effectiveness gauges
                # (cumulative hit count + live shared pages; accept rate
                # over all drafts so far) — scraped alongside pool
                # occupancy so capacity dashboards see both how much HBM
                # sharing is saving and how much verify bandwidth the
                # drafter converts into emitted tokens
                if rt.kernels.rcfg.prefix_cache:
                    reg.gauge("ersap_prefix_hits").set(rt.prefix_hits)
                    reg.gauge("ersap_shared_pages").set(rt.shared_pages)
                if rt.kernels.rcfg.spec_decode:
                    reg.gauge("ersap_spec_accept_rate").set(
                        rt.spec_accept_rate)
        self.tokens_rate = (self.total_tokens - tokens_before) / max(dt, 1e-9)
        self.metrics.gauge("ersap_queue_len").set(len(self.queue))
        self.metrics.gauge("ersap_brownout_level").set(level)
        self.prom.scrape(now)
        self.history.append((now, len(self.queue), self.serving.replicas,
                             self.control))
        return len(self.queue)

    def slab_pressure(self) -> float:
        """Mean per-replica slab occupancy in [0, 1] (paged: page-pool
        share; dense: busy-slot share) — the memory-pressure signal the
        multi-signal HPA and the twin's priority escalation consume.
        The mean (not max) so the control loop converges: a scale-up
        adds empty slabs and visibly lowers the signal, whereas one
        pinned hot replica under a max would keep proposing more
        replicas that cannot relieve it (its KV does not migrate)."""
        if not self.runtimes:
            return 0.0
        return sum(rt.occupancy for rt in self.runtimes.values()) / \
            len(self.runtimes)

    def _process(self, requests: List[Request], replica: str, now: float):
        """Serve ``requests`` on ``replica``: slot-slab continuous batching
        when available, legacy chunked prefill+decode otherwise."""
        if not requests:
            rt = self._refresh_runtime(replica)
            if rt is not None and rt.inflight:
                for fin in rt.pump():       # restored in-flight work
                    self._finish(replica, fin.req, fin.tokens, now)
            return
        rt = self._refresh_runtime(replica)
        if rt is None:
            for j in range(0, len(requests), self.max_batch):
                self._process_chunked(requests[j:j + self.max_batch],
                                      replica, now)
            return
        fitting = [r for r in requests if rt.fits(r)]
        oversize = [r for r in requests if not rt.fits(r)]
        bounced = rt.submit(fitting)
        if bounced:
            # the runtime's bounded pending queue pushed back — return
            # the overflow to the source with retry-after (never dropped
            # silently, never queued unboundedly)
            self._backpressure(bounced, now)
        for fin in rt.pump():
            self._finish(replica, fin.req, fin.tokens, now)
        for j in range(0, len(oversize), self.max_batch):
            self._process_chunked(oversize[j:j + self.max_batch],
                                  replica, now)

    def _finish(self, replica: str, req: Request, n_tokens: int, now: float):
        reg = self.registries[replica]
        st = self.stats[replica]
        st.served += 1
        st.tokens += n_tokens
        self.total_served += 1
        self.total_tokens += n_tokens
        reg.counter("ersap_served_total").inc(1)
        reg.counter("ersap_tokens_total").inc(n_tokens)
        lat = max(now - req.arrival, 0.0)
        reg.histogram("ersap_latency_s").observe(lat)
        reg.histogram("ersap_per_token_s").observe(lat / max(n_tokens, 1))
        self.completed.append((req.rid, now))
        self._drain_t.pop(req.rid, None)
        if self.tracer is not None:
            self.tracer.span("retire", now, rid=req.rid, replica=replica,
                             tokens=n_tokens)
        if self.recorder is not None:
            self.recorder.note_latency(now, lat, req.priority)
            self.recorder.note_served(now)

    def _process_chunked(self, requests: List[Request], replica: str,
                         now: float):
        """Pre-PR path (kept for recurrent families + oversize requests):
        one prefill per chunk shape, Python-loop decode, every request
        over-decoded to the chunk's max_new."""
        if not requests:
            return
        B = len(requests)
        plen = requests[0].prompt_len
        rng = np.random.default_rng(int(now * 1000) % (2**31))
        toks = rng.integers(0, self.cfg.vocab, (B, plen)).astype(np.int32)
        logits, cache = self.serving.prefill_fn(self.serving.params, toks)
        cache = MA.grow_cache(self.cfg, cache,
                              plen + (self.cfg.n_meta_tokens or 0)
                              + max(r.max_new for r in requests) + 1)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        n_new = max(r.max_new for r in requests)
        for _ in range(n_new):
            logits, cache = self.serving.decode_fn(self.serving.params, tok,
                                                   cache)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for r in requests:
            self._finish(replica, r, n_new, now)

    # ---------------------------------------------------- observability
    def _wire_plane_obs(self) -> None:
        """Propagate the engine's tracer/profiler into the control plane
        (idempotent; called whenever the plane might be fresh)."""
        if self.plane is None:
            return
        if self.tracer is not None:
            if getattr(self.plane, "tracer", None) is None:
                self.plane.tracer = self.tracer
            if getattr(self.plane.scheduler, "tracer", None) is None:
                self.plane.scheduler.tracer = self.tracer
            if getattr(self.plane.nodes, "tracer", None) is None:
                self.plane.nodes.tracer = self.tracer
        if self.profiler is not None and \
                getattr(self.plane, "profiler", None) is None:
            self.plane.profiler = self.profiler

    def enable_observability(self, tracer=None, recorder=None,
                             profiler=None) -> None:
        """Wire the observability plane through every layer: request
        source (enqueue spans), QoS machines (brownout/breaker spans),
        control plane + scheduler + lifecycle controller (schedule/
        preempt/checkpoint/drain spans, tick phase profile), and every
        live runtime (admit/prefill/decode spans, TTFT, pump profile).
        Safe to call before or after ``deploy``; later-built runtimes
        and planes inherit via ``_make_runtime`` / ``_ensure_plane``."""
        if tracer is not None:
            self.tracer = tracer
            self.source.tracer = tracer
            if self.brownout is not None:
                self.brownout.tracer = tracer
            if self.breaker is not None:
                self.breaker.tracer = tracer
        if recorder is not None:
            self.recorder = recorder
        if profiler is not None:
            self.profiler = profiler
        self._wire_plane_obs()
        for name, rt in self.runtimes.items():
            rt.name = name
            if tracer is not None:
                rt.tracer = tracer
            if profiler is not None:
                rt.profiler = profiler

    def exposition(self) -> str:
        """Prometheus text exposition of the whole metric pipeline: the
        engine registry (pod label ``_engine``) plus every per-replica
        registry (``serve.py --metrics-out``)."""
        return render_exposition({"_engine": self.metrics,
                                  **self.registries})

    # ---------------------------------------------------------- control
    def control_step(self, now: float):
        """Assimilate queue depth into the twin; the twin policy and the
        reactive HPA are *spec writers* on the Deployment — desired
        replicas, and (twin path) the priority class, Fig. 8's control
        regions extended to a (replicas, priority) action space. The
        slab-pressure gauge feeds both: the multi-signal HPA as its
        memory signal, the twin as a priority-escalation trigger. The
        controllers/scheduler converge the pod set — escalated serving
        preempts batch work instead of queueing behind it."""
        qlen = max(len(self.queue), 1e-3)
        self.twin.assimilate(qlen, self.control)
        occupancy = self.slab_pressure()
        pclass = None
        if self.use_twin:
            self.control, pclass = self.policy.recommend_action(
                self.twin, self.control, now, occupancy=occupancy)
            desired = replicas_for_control(self.control, self.base_replicas)
            source = "digital-twin"
        else:
            sig = PressureSignals(queue_depth=len(self.queue),
                                  tokens_per_s=self.tokens_rate,
                                  slab_occupancy=occupancy)
            desired = self.hpa.evaluate_signals(
                max(len(self.pods), 1), sig, now)
            source = "hpa"
        # the Deployment spec may exceed the mesh's device budget (pods
        # are simulated serving replicas; scale_to clamps the actual
        # mesh build to max_replicas itself)
        desired = max(1, desired)
        if min(desired, self.serving.max_replicas()) != self.serving.replicas:
            self.serving.scale_to(desired, now)
        if self.cluster is not None and DEPLOYMENT in self.cluster.deployments:
            if pclass is not None:
                self.cluster.set_priority(DEPLOYMENT, pclass, now,
                                          source=source)
            self.cluster.scale(DEPLOYMENT, desired, now, source=source)
            self.reconcile(now)
        return desired
