"""ERSAP-analog streaming inference engine (paper §5 workload + §6 queue).

Pipeline: RequestSource (Poisson sender) -> FIFO queue -> batcher ->
serving replicas (real prefill+decode on the mesh) -> sink.

Declarative control plane: the engine no longer hand-creates pods by
naming convention. It declares a ``Deployment`` ("ersap") in the Cluster
store; the DeploymentController converges ``spec.replicas`` -> pods, the
Scheduler places them (spread across nodes, straggler-averse), and the
NodeLifecycleController drains walltime-expiring nodes — checkpointing
each replica's runtime state via ``repro.checkpoint`` so the rescheduled
replica resumes its counters. The HPA and the digital-twin policy are
both *desired-replica writers*: ``control_step`` computes a target and
writes ``Deployment.replicas``; reconciliation does the rest. Metrics
(queue depth, served, latency) flow through the §4.6 monitoring stack,
whose Service endpoints are rebuilt from live pods every sync (retired
replicas leave no stale scrape targets).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cluster import Cluster, Deployment, PodTemplate
from repro.core.controllers import ControlPlane
from repro.core.hpa import HPA, HPAConfig, MetricSample
from repro.core.jrm import VirtualNode
from repro.core.metrics import (Endpoint, Prometheus, Registry, Service,
                                ServiceMonitor)
from repro.core.state_machine import Pod
from repro.core.digital_twin.control import ControlPolicy, replicas_for_control
from repro.core.digital_twin.dbn import DigitalTwin
from repro.data.pipeline import Request, RequestSource
from repro.models import model_api as MA

DEPLOYMENT = "ersap"


@dataclass
class ReplicaStats:
    served: int = 0
    tokens: int = 0


@dataclass
class StreamEngine:
    cfg: ArchConfig
    serving: object                   # ElasticServing
    nodes: List[VirtualNode]
    max_batch: int = 8
    service_rate: float = 40.0        # requests/s one replica can absorb
    queue: List[Request] = field(default_factory=list)
    source: RequestSource = field(default_factory=RequestSource)
    registries: Dict[str, Registry] = field(default_factory=dict)
    prom: Prometheus = field(default_factory=Prometheus)
    stats: Dict[str, ReplicaStats] = field(default_factory=dict)
    completed: list = field(default_factory=list)
    control: int = 16
    twin: DigitalTwin = field(default_factory=DigitalTwin)
    policy: ControlPolicy = field(default_factory=ControlPolicy)
    hpa: Optional[HPA] = None
    base_replicas: int = 1
    use_twin: bool = True
    history: list = field(default_factory=list)
    # declarative control plane (built from ``nodes`` unless injected)
    cluster: Optional[Cluster] = None
    plane: Optional[ControlPlane] = None
    total_served: int = 0
    total_tokens: int = 0
    _cp_ports: Dict[str, int] = field(default_factory=dict)

    # ------------------------------------------------------------ setup
    @property
    def pods(self) -> Dict[str, Pod]:
        """Live bound pods of the ersap Deployment (status view)."""
        if self.cluster is None:
            return {}
        return {r.name: r.pod
                for r in self.cluster.pods_of(DEPLOYMENT) if r.bound}

    def _ensure_plane(self, now: float):
        if self.cluster is None:
            self.cluster = Cluster()
        for n in self.nodes:
            if n.name not in self.cluster.nodes:
                self.cluster.register_node(n, now)
        if self.plane is None:
            self.plane = ControlPlane(self.cluster)

    def _replica_state(self, name: str) -> Optional[dict]:
        st = self.stats.get(name)
        if st is None:
            return None
        return {"served": st.served, "tokens": st.tokens}

    def deploy(self, now: float = 0.0):
        """Declare (or re-declare) the serving Deployment at the current
        replica count and reconcile until pods and monitoring converge."""
        self._ensure_plane(now)
        if DEPLOYMENT not in self.cluster.deployments:
            self.cluster.apply_deployment(Deployment(
                DEPLOYMENT, self.serving.replicas,
                template=PodTemplate(
                    labels={"app": "ersap"},
                    tolerations=[{"key": "virtual-kubelet.io/provider",
                                  "value": "mock"}],
                    request_chips=self.serving.tp,
                    checkpoint_state=self._replica_state)), now)
        else:
            self.cluster.scale(DEPLOYMENT, self.serving.replicas, now,
                               source="engine")
        self.reconcile(now)

    def reconcile(self, now: float):
        """One control-plane step + engine-side sync (registries, stats,
        Service endpoints follow the pod set — nothing leaks on retire)."""
        self._ensure_plane(now)
        self.plane.step(now)
        self._sync(now)

    def _sync(self, now: float):
        live = {r.name: r for r in self.cluster.pods_of(DEPLOYMENT)
                if r.bound}
        for name in list(self.registries):
            if name not in live:
                self.registries.pop(name, None)
                self.stats.pop(name, None)
        for name, rec in sorted(live.items()):
            if name in self.registries:
                continue
            self.registries[name] = Registry(port=2221)
            st = ReplicaStats()
            if rec.restored_state:
                st.served = int(rec.restored_state.get("served", 0))
                st.tokens = int(rec.restored_state.get("tokens", 0))
            self.stats[name] = st
        # Service endpoints rebuilt from live pods only (§4.6.3 port remap
        # stays unique per pod even though all VK pods share one pod IP)
        svc = Service("ersap-metrics", selector={"app": "ersap"},
                      labels={"monitored": "true"})
        for name, rec in sorted(live.items()):
            node = self.cluster.nodes.get(rec.pod.node)
            if node is None:
                continue
            if name not in self._cp_ports:
                self._cp_ports[name] = 20000 + len(self._cp_ports)
            svc.add_endpoint(Endpoint(
                pod=name, pod_ip=node.pod_ip, port=2221,
                cp_port=self._cp_ports[name], registry=self.registries[name]))
        self.prom.services = [svc]
        if not self.prom.monitors:
            self.prom.monitors = [ServiceMonitor(
                "ersap-mon", service_selector={"monitored": "true"})]

    # ------------------------------------------------------------- tick
    def tick(self, now: float, dt: float, lam: float):
        """One engine step of simulated time dt with arrival rate lam.
        Capacity follows the *actual* replica set in the cluster store."""
        self.queue.extend(self.source.arrivals(now, dt, lam))
        # per-replica service capacity this tick (mu * dt, M/M/1 analog —
        # doubling replicas doubles capacity, the paper's 16->32 threads)
        budget = int(self.service_rate * dt)
        for name in sorted(self.registries):
            reg = self.registries[name]
            n_take = min(len(self.queue), budget)
            took, self.queue = self.queue[:n_take], self.queue[n_take:]
            for j in range(0, len(took), self.max_batch):
                chunk = took[j:j + self.max_batch]
                self._process(chunk, name, now)
            reg.gauge("ersap_queue_len").set(len(self.queue))
            reg.counter("ersap_served_total")
        self.prom.scrape(now)
        self.history.append((now, len(self.queue), self.serving.replicas,
                             self.control))
        return len(self.queue)

    def _process(self, requests: List[Request], replica: str, now: float):
        """Actually run the model: batched prefill + greedy decode."""
        if not requests:
            return
        B = len(requests)
        plen = requests[0].prompt_len
        rng = np.random.default_rng(int(now * 1000) % (2**31))
        toks = rng.integers(0, self.cfg.vocab, (B, plen)).astype(np.int32)
        logits, cache = self.serving.prefill_fn(self.serving.params, toks)
        cache = MA.grow_cache(self.cfg, cache,
                              plen + (self.cfg.n_meta_tokens or 0)
                              + max(r.max_new for r in requests) + 1)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        n_new = max(r.max_new for r in requests)
        for _ in range(n_new):
            logits, cache = self.serving.decode_fn(self.serving.params, tok,
                                                   cache)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        reg = self.registries[replica]
        st = self.stats[replica]
        st.served += B
        st.tokens += B * n_new
        self.total_served += B
        self.total_tokens += B * n_new
        reg.counter("ersap_served_total").inc(B)
        reg.counter("ersap_tokens_total").inc(B * n_new)
        for r in requests:
            reg.histogram("ersap_latency_s").observe(max(now - r.arrival, 0.0))
            self.completed.append((r.rid, now))

    # ---------------------------------------------------------- control
    def control_step(self, now: float):
        """Assimilate queue depth into the twin; both the twin policy and
        the reactive HPA are desired-replica *writers* on the Deployment —
        the controllers/scheduler converge the pod set."""
        qlen = max(len(self.queue), 1e-3)
        self.twin.assimilate(qlen, self.control)
        if self.use_twin:
            self.control = self.policy.recommend(self.twin, self.control, now)
            desired = replicas_for_control(self.control, self.base_replicas)
            source = "digital-twin"
        else:
            pods = self.pods
            samples = {name: MetricSample(qlen / max(len(pods), 1), now)
                       for name in pods}
            desired = self.hpa.evaluate(list(pods.values()), samples, now)
            source = "hpa"
        desired = max(1, min(desired, self.serving.max_replicas()))
        if desired != self.serving.replicas:
            self.serving.scale_to(desired, now)
        if self.cluster is not None and DEPLOYMENT in self.cluster.deployments:
            self.cluster.scale(DEPLOYMENT, desired, now, source=source)
            self.reconcile(now)
        return desired
