"""Pallas TPU selective-SSM (mamba-style) chunked scan kernel.

Grid: (B, d_inner blocks, n_chunks) — chunk dim innermost; the hidden state
h (bdi, N) persists in VMEM scratch across chunks. Within a chunk the
recurrence is evaluated with an associative scan over the chunk axis
(log-depth VPU work), so the sequential grid only pays n_chunks latency.
Channel blocking (bdi) keeps the (c, bdi, N) working set inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, A_ref, b_ref, c_ref, d_ref,
            y_ref, hfin_ref, h_ref, *, c, n_chunks):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    u = u_ref[0].astype(jnp.float32)        # (c, bdi)
    dt = dt_ref[0].astype(jnp.float32)      # (c, bdi)
    A = A_ref[...].astype(jnp.float32)      # (bdi, N)
    Bsel = b_ref[0].astype(jnp.float32)     # (c, N)
    Csel = c_ref[0].astype(jnp.float32)     # (c, N)
    D = d_ref[...].astype(jnp.float32)      # (1, bdi)

    Ad = jnp.exp(dt[:, :, None] * A[None])                    # (c, bdi, N)
    Bx = (dt * u)[:, :, None] * Bsel[:, None, :]              # (c, bdi, N)
    Bx = Bx.at[0].add(Ad[0] * h_ref[...])                     # fold carry in

    a, b = jax.lax.associative_scan(
        lambda l, r: (r[0] * l[0], r[0] * l[1] + r[1]), (Ad, Bx), axis=0)
    y = jnp.einsum("cdn,cn->cd", b, Csel) + D * u
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[...] = b[-1]

    @pl.when(j == n_chunks - 1)
    def _done():
        hfin_ref[0] = h_ref[...]


def ssm_scan_kernel(u, dt, A, Bsel, Csel, Dskip, *, chunk=64,
                    block_di=256, interpret=False):
    """u, dt: (B,S,di); A: (di,N); Bsel,Csel: (B,S,N); Dskip: (di,).
    Returns (y (B,S,di), h_last (B,di,N))."""
    B, S, di = u.shape
    N = A.shape[1]
    c = min(chunk, S)
    assert S % c == 0
    NC = S // c
    bdi = min(block_di, di)
    assert di % bdi == 0
    ND = di // bdi

    kernel = functools.partial(_kernel, c=c, n_chunks=NC)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(B, ND, NC),
        in_specs=[
            pl.BlockSpec((1, c, bdi), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, c, bdi), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((bdi, N), lambda b, d, j: (d, 0)),
            pl.BlockSpec((1, c, N), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((1, c, N), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((1, bdi), lambda b, d, j: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, bdi), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, bdi, N), lambda b, d, j: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), u.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bdi, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bsel, Csel, Dskip.reshape(1, di))
    return y, hfin
