"""Pallas TPU flash attention (blockwise, causal/windowed/chunked, GQA).

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost so the fp32
running-softmax accumulators live in VMEM scratch across kv steps. Block
shapes are MXU-aligned (q/kv blocks multiples of 128 when the sequence
allows, head_dim padded to 128 by the wrapper in ops.py if needed).

TPU adaptation notes (vs. the CUDA flash-attention formulation): the kernel
is expressed as a grid-sequential reduction with VMEM carries rather than a
warp-synchronous tiling; MXU does the (bq, dh)x(dh, bk) and (bq, bk)x(bk, dh)
contractions, VPU the renormalization.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, chunk, kv_len, bq, bk, n_kv_blocks,
            softcap):
    j = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    if chunk is not None:
        ok &= (qpos // chunk) == (kpos // chunk)
    if kv_len is not None:
        ok &= kpos < kv_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, chunk=None,
                    kv_len=None, softcap=0.0, block_q=128, block_k=128,
                    interpret=False):
    """q: (B, Hq, Sq, dh); k, v: (B, Hkv, Sk, dh) -> (B, Hq, Sq, dh).

    GQA: kv head index = q head index // (Hq // Hkv) via the BlockSpec
    index maps — no KV replication in memory.
    """
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_kv = Sk // bk
    scale = dh ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, chunk=chunk,
        kv_len=kv_len, bq=bq, bk=bk, n_kv_blocks=n_kv, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
