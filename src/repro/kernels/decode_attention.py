"""Pallas TPU single-token decode attention over a KV cache.

Grid: (B, Hq, kv_blocks) — streaming LSE reduction over cache blocks in VMEM
scratch. Per-sequence valid length arrives as a (B, 1) i32 tensor; masking
(causal-by-length, sliding window, chunked) happens against absolute cache
positions, matching repro.models.attention.decode_attention semantics for
non-ring caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, window, chunk, bk, n_kv):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale                # (1, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    length = len_ref[0, 0]
    qpos = length - 1

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    ok = kpos < length
    if window is not None:
        ok &= (qpos - kpos) < window
    if chunk is not None:
        ok &= (qpos // chunk) == (kpos // chunk)
    s = jnp.where(ok, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, lengths, *, window=None,
                            chunk=None, block_k=512, interpret=False):
    """q: (B,Hq,dh); caches: (B,Smax,Hkv,dh); lengths: (B,) -> (B,Hq,dh)."""
    B, Hq, dh = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    bk = min(block_k, Smax)
    assert Smax % bk == 0
    n_kv = Smax // bk
    kernel = functools.partial(_kernel, scale=dh ** -0.5, window=window,
                               chunk=chunk, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, h, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda b, h, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths.reshape(B, 1).astype(jnp.int32))
