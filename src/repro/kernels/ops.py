"""Jit'd dispatch wrappers over the Pallas kernels.

On TPU the kernels run natively; on CPU (this container) they run in
interpret mode when requested, otherwise the jnp fallbacks from
repro.models are used (that is also what the dry-run lowers). The model
layer toggles with ``use_kernels`` / KERNEL_MODE.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_chunkwise_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk",
                                             "softcap", "block_q", "block_k"))
def attention(q, k, v, *, causal=True, window=None, chunk=None, softcap=0.0,
              block_q=128, block_k=128):
    """q: (B,S,Hq,dh) model layout -> flash kernel layout and back."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          chunk=chunk, softcap=softcap, block_q=block_q,
                          block_k=block_k, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("window", "chunk", "block_k"))
def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     chunk=None, block_k=512):
    """q: (B,1,Hq,dh) -> (B,1,Hq,dh)."""
    out = decode_attention_kernel(q[:, 0], k_cache, v_cache, lengths,
                                  window=window, chunk=chunk,
                                  block_k=block_k, interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm(q, k, v, li, lf, *, chunk=64):
    return mlstm_chunkwise_kernel(q, k, v, li, lf, chunk=chunk,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "block_di"))
def ssm(u, dt, A, Bsel, Csel, Dskip, *, chunk=64, block_di=256):
    return ssm_scan_kernel(u, dt, A, Bsel, Csel, Dskip, chunk=chunk,
                           block_di=block_di, interpret=_interpret())
