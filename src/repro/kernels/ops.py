"""Jit'd dispatch wrappers over the Pallas kernels + the kernel-mode toggle.

On TPU the kernels run natively; on CPU (this container) they run in
interpret mode when requested, otherwise the jnp fallbacks from
repro.models are used (that is also what the dry-run lowers). The model
layer routes its decode hot path through ``decode_attention_model`` /
``decode_attention_paged`` below, which honor the mode toggle:

  KERNEL_MODE=auto    pick per backend: Pallas on TPU, jnp elsewhere
  KERNEL_MODE=pallas  force the Pallas kernels (interpret mode off-TPU —
                      slow on CPU, meant for parity testing)
  KERNEL_MODE=jnp     force the jnp paths (block-skip streaming decode)

Set via the ``KERNEL_MODE`` env var or ``set_kernel_mode()`` (the serve
driver's ``--kernel-mode`` flag). The mode is read at *trace* time, so
flip it before building jitted closures (RuntimeKernels / ElasticServing
cache compiled artifacts keyed by shape, not by mode).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_chunkwise_kernel
from repro.kernels.paged_decode_attention import paged_decode_attention_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel

KERNEL_MODES = ("auto", "pallas", "jnp")
_kernel_mode = None                     # None -> read KERNEL_MODE env var


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def set_kernel_mode(mode: str | None) -> None:
    """Override the kernel dispatch mode (None -> back to the env var)."""
    global _kernel_mode
    if mode is not None and mode not in KERNEL_MODES:
        raise ValueError(f"kernel mode {mode!r} not in {KERNEL_MODES}")
    _kernel_mode = mode


def kernel_mode() -> str:
    """The configured mode (may be "auto")."""
    if _kernel_mode is not None:
        return _kernel_mode
    env = os.environ.get("KERNEL_MODE", "auto")
    return env if env in KERNEL_MODES else "auto"


def resolved_mode() -> str:
    """The effective implementation choice: "pallas" or "jnp"."""
    mode = kernel_mode()
    if mode == "auto":
        return "pallas" if on_tpu() else "jnp"
    return mode


def use_kernels() -> bool:
    """True when the model layer should route through the Pallas kernels."""
    return resolved_mode() == "pallas"


# ------------------------------------------------------- model-layer dispatch

def decode_attention_model(q, k_cache, v_cache, *, pos, window=None,
                           chunk=None, kv_positions=None, softcap=0.0,
                           block_skip=None):
    """Decode attention for the dense (slab / grow_cache) layout.

    q: (B,1,Hq,dh); caches: (B,Smax,Hkv,dh); pos scalar or (B,). The ring
    layouts (``kv_positions`` carrying absolute positions) have no Pallas
    kernel, so this always lowers the jnp path. ``block_skip`` (opt-in;
    the serving runtime engages it per dispatch) streams KV in blocks and
    skips blocks beyond the deepest live row at runtime — the default
    stays the single fused attention, which wins on a well-utilized
    cache. Called inside jitted model code: choices bake at trace time.
    """
    from repro.models.attention import decode_attention
    return decode_attention(q, k_cache, v_cache, pos=pos, window=window,
                            chunk=chunk, kv_positions=kv_positions,
                            softcap=softcap, block_skip=block_skip)


def decode_attention_paged(q, k_pool, v_pool, pages, lengths, *, kv_bucket,
                           page_size, window=None, chunk=None, softcap=0.0):
    """Decode attention for the paged layout.

    q: (B,1,Hq,dh); pools: (n_pages, page_size, Hkv, dh); pages: (B,P)
    physical-page table; lengths: (B,) live entries per row. ``kv_bucket``
    (static, a multiple of page_size) bounds how many *logical* entries the
    jnp path materializes — the host picks the smallest bucket covering the
    deepest live row, so gather cost tracks live tokens, not capacity.

    pallas mode: the paged kernel reads pages straight from the pool via
    scalar-prefetch indexing (no gather) and early-exits each row's page
    grid. jnp mode: gather the first kv_bucket//page_size pages per row and
    run the block-skip streaming decode over them.
    """
    if resolved_mode() == "pallas" and not softcap:
        out = paged_decode_attention_kernel(
            q[:, 0], k_pool, v_pool, pages, lengths,
            window=window, chunk=chunk, interpret=_interpret())
        return out[:, None]
    from repro.models.attention import decode_attention
    B = q.shape[0]
    npg = kv_bucket // page_size
    pid = pages[:, :npg]                                   # (B, npg)
    kb = k_pool[pid].reshape(B, kv_bucket, *k_pool.shape[2:])
    vb = v_pool[pid].reshape(B, kv_bucket, *v_pool.shape[2:])
    # the gathered width is already bucketed to the deepest live row, so
    # intra-bucket skipping only pays once the bucket spans several pages
    skip = page_size if npg >= 4 else None
    return decode_attention(q, kb, vb, pos=lengths - 1, window=window,
                            chunk=chunk, softcap=softcap, block_skip=skip)


def window_attention_paged(q, k_pool, v_pool, pages, pos, *, kv_bucket,
                           page_size, window=None, chunk=None, softcap=0.0):
    """W-token decode-window attention for the paged layout.

    q: (B,W,Hq,dh) — W consecutive new positions per row, whose KV the
    caller already scattered into the pool at positions pos..pos+W-1;
    pos: (B,) each row's first new position. Serves the prefix-cache tail
    prefill and the speculative-decode verify dispatch.

    pallas mode: W calls of the untouched 1-token paged kernel, one per
    window offset (offset w attends through pos+w) — the kernel's
    page-table indirection already covers the freshly written entries.
    jnp mode: one page gather + blockwise attention with per-offset
    causal masking over the kv_bucket.
    """
    if resolved_mode() == "pallas" and not softcap:
        W = q.shape[1]
        outs = [paged_decode_attention_kernel(
                    q[:, w], k_pool, v_pool, pages, pos + w + 1,
                    window=window, chunk=chunk, interpret=_interpret())
                for w in range(W)]
        return jnp.stack(outs, axis=1)
    from repro.models.attention import blockwise_attention
    B, W = q.shape[:2]
    npg = kv_bucket // page_size
    pid = pages[:, :npg]                                   # (B, npg)
    kb = k_pool[pid].reshape(B, kv_bucket, *k_pool.shape[2:])
    vb = v_pool[pid].reshape(B, kv_bucket, *v_pool.shape[2:])
    q_pos = pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    kv_pos = jnp.broadcast_to(
        jnp.arange(kv_bucket, dtype=jnp.int32)[None, :], (B, kv_bucket))
    return blockwise_attention(q, kb, vb, causal=True, window=window,
                               chunk=chunk, q_positions=q_pos,
                               kv_positions=kv_pos, softcap=softcap)


# --------------------------------------------------------- jit'd kernel entry

@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk",
                                             "softcap", "block_q", "block_k"))
def attention(q, k, v, *, causal=True, window=None, chunk=None, softcap=0.0,
              block_q=128, block_k=128):
    """q: (B,S,Hq,dh) model layout -> flash kernel layout and back."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          chunk=chunk, softcap=softcap, block_q=block_q,
                          block_k=block_k, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("window", "chunk", "block_k"))
def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     chunk=None, block_k=512):
    """q: (B,1,Hq,dh) -> (B,1,Hq,dh)."""
    out = decode_attention_kernel(q[:, 0], k_cache, v_cache, lengths,
                                  window=window, chunk=chunk,
                                  block_k=block_k, interpret=_interpret())
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm(q, k, v, li, lf, *, chunk=64):
    return mlstm_chunkwise_kernel(q, k, v, li, lf, chunk=chunk,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "block_di"))
def ssm(u, dt, A, Bsel, Csel, Dskip, *, chunk=64, block_di=256):
    return ssm_scan_kernel(u, dt, A, Bsel, Csel, Dskip, chunk=chunk,
                           block_di=block_di, interpret=_interpret())
