"""Pallas TPU chunkwise mLSTM kernel.

Grid: (B, H, n_chunks) — chunk dim innermost, so the matrix memory
(C: dh x dh), normalizer (n) and stabilizer (m) persist in VMEM scratch
across chunks. Intra-chunk work is two MXU contractions ((c,dh)x(dh,c) and
(c,c)x(c,dh)) plus VPU gating math; inter-chunk state update is one more
MXU contraction. This is the TPU-native adaptation of chunkwise linear-
attention kernels (no warp shuffles — grid-sequential VMEM carries).

Final (C, n, m) state is emitted at the last chunk (prefill -> decode
handoff).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
            h_ref, cfin_ref, nfin_ref, mfin_ref,
            C_ref, n_ref, m_ref, *, c, dh, n_chunks):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0, 0, 0].astype(jnp.float32)                 # (c, dh)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    li = li_ref[0, 0, 0].astype(jnp.float32)               # (c,)
    lf = jax.nn.log_sigmoid(lf_ref[0, 0, 0].astype(jnp.float32))

    D = jnp.cumsum(lf)                                     # (c,)
    G = D[-1]
    dec = li[None, :] + D[:, None] - D[None, :]            # (c, c)
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dec = jnp.where(tri, dec, NEG)
    a = li + G - D                                         # (c,)

    m_prev = m_ref[0, 0]
    C_prev = C_ref[...]
    n_prev = n_ref[...]                                    # (1, dh)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m_intra = jnp.max(dec, axis=1)                         # (c,)
    m_t = jnp.maximum(m_prev + D, m_intra)
    inter_w = jnp.exp(m_prev + D - m_t)                    # (c,)
    inter = inter_w[:, None] * jax.lax.dot_general(
        q, C_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (c, dh)
    den_inter = inter_w * jnp.sum(q * n_prev, axis=1)      # (c,)
    pw = jnp.exp(dec - m_t[:, None]) * scores
    intra = jax.lax.dot_general(pw, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    den = jnp.maximum(jnp.abs(den_inter + jnp.sum(pw, axis=1)),
                      jnp.exp(-m_t))
    h_ref[0, 0, 0] = ((inter + intra) / den[:, None]).astype(h_ref.dtype)

    m_a = jnp.max(a)
    m_next = jnp.maximum(m_prev + G, m_a)
    w_prev = jnp.exp(m_prev + G - m_next)
    w_s = jnp.exp(a - m_next)                              # (c,)
    kw = w_s[:, None] * k
    C_ref[...] = w_prev * C_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = w_prev * n_prev + jnp.sum(kw, axis=0, keepdims=True)
    m_ref[0, 0] = m_next

    @pl.when(j == n_chunks - 1)
    def _done():
        cfin_ref[0, 0] = C_ref[...]
        nfin_ref[0, 0] = n_ref[...]
        mfin_ref[0, 0] = m_ref[...]


def mlstm_chunkwise_kernel(q, k, v, li, lf, *, chunk=64, interpret=False):
    """q,k,v: (B,S,H,dh) (k pre-scaled by dh**-0.5); li,lf: (B,S,H) raw gates.
    Returns (h (B,S,H,dh), (C (B,H,dh,dh), n (B,H,dh), m (B,H)))."""
    B, S, H, dh = q.shape
    c = min(chunk, S)
    assert S % c == 0
    NC = S // c

    def cshape(x):        # (B,S,H,*) -> (B,H,NC,c,*)
        return x.reshape(B, NC, c, H, -1).transpose(0, 3, 1, 2, 4)

    qc, kc, vc = (cshape(x) for x in (q, k, v))
    lic = li.reshape(B, NC, c, H).transpose(0, 3, 1, 2)
    lfc = lf.reshape(B, NC, c, H).transpose(0, 3, 1, 2)

    kernel = functools.partial(_kernel, c=c, dh=dh, n_chunks=NC)
    h, Cf, nf, mf = pl.pallas_call(
        kernel,
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, c, dh), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, c, dh), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, c, dh), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, c, dh), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, NC, c, dh), q.dtype),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qc, kc, vc, lic, lfc)
    h = h.transpose(0, 2, 3, 1, 4).reshape(B, S, H, dh)
    return h, (Cf, nf[:, :, 0], mf[:, :, 0, 0])
