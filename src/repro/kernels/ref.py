"""Pure-jnp oracles for every Pallas kernel (naive, O(S^2)/sequential)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, chunk=None,
                  kv_len=None, softcap=0.0):
    """q: (B,Hq,Sq,dh); k,v: (B,Hkv,Sk,dh). Naive materialized softmax."""
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kg = jnp.repeat(k, G, axis=1)
    vg = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) * (dh ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    if chunk is not None:
        ok &= (qpos // chunk) == (kpos // chunk)
    if kv_len is not None:
        ok &= kpos < kv_len
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vg.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, *, lengths, window=None, chunk=None):
    """q: (B,Hq,dh); k,v: (B,Skmax,Hkv,dh); lengths: (B,) valid cache length.
    Query position = lengths - 1."""
    B, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kg = jnp.repeat(k, G, axis=2)
    vg = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) * (dh ** -0.5)
    qpos = (lengths - 1)[:, None, None]
    kpos = jnp.arange(Sk)[None, None, :]
    ok = kpos < lengths[:, None, None]
    if window is not None:
        ok &= (qpos - kpos) < window
    if chunk is not None:
        ok &= (qpos // chunk) == (kpos // chunk)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vg.astype(jnp.float32)).astype(q.dtype)


def mlstm_ref(q, k, v, li, lf, state=None):
    """Sequential stabilized mLSTM recurrence. q,k,v: (B,S,H,dh) (k pre-scaled);
    li, lf: (B,S,H) raw gates. Returns (h, (C, n, m))."""
    B, S, H, dh = q.shape
    f32 = jnp.float32
    if state is None:
        C = jnp.zeros((B, H, dh, dh), f32)
        n = jnp.zeros((B, H, dh), f32)
        m = jnp.full((B, H), NEG_INF, f32)
    else:
        C, n, m = (s.astype(f32) for s in state)
    lf = jax.nn.log_sigmoid(lf.astype(f32))
    li = li.astype(f32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        m_new = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m_new)
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        h = jnp.einsum("bhd,bhde->bhe", qt, C) / den[..., None]
        return (C, n, m_new), h

    xs = (q.astype(f32).swapaxes(0, 1), k.astype(f32).swapaxes(0, 1),
          v.astype(f32).swapaxes(0, 1), li.swapaxes(0, 1), lf.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return hs.swapaxes(0, 1).astype(q.dtype), (C, n, m)


def ssm_ref(u, dt, A, Bsel, Csel, Dskip, h0=None):
    """Sequential selective-SSM recurrence. u, dt: (B,S,di); A: (di,N);
    Bsel, Csel: (B,S,N). Returns (y (B,S,di), h_last (B,di,N))."""
    B, S, di = u.shape
    N = A.shape[1]
    h = jnp.zeros((B, di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        ut, dtt, Bt, Ct = xs
        Ad = jnp.exp(dtt[..., None] * A)
        h = Ad * h + (dtt * ut)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Ct) + Dskip * ut
        return h, y

    xs = (u.astype(jnp.float32).swapaxes(0, 1), dt.astype(jnp.float32).swapaxes(0, 1),
          Bsel.astype(jnp.float32).swapaxes(0, 1), Csel.astype(jnp.float32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.swapaxes(0, 1).astype(u.dtype), h
