"""Pallas TPU paged single-token decode attention (vLLM-style PagedAttention).

The KV cache is a shared physical pool of fixed-size pages
(``n_pages x page_size`` entries per layer); each batch row owns a small
page table mapping its logical KV blocks to physical pages. The grid is
(B, Hq, logical_pages): the page table rides in as a scalar-prefetch
operand so the BlockSpec index map can fetch each row's *physical* page,
and rows exit the page grid early — ``pl.when(j * page_size < length)``
skips every block fully beyond the row's live length, so decode FLOPs are
proportional to the tokens a request actually holds, not to the pool (or
slab) capacity. Streaming LSE reduction over the visited pages matches
``repro.kernels.decode_attention`` / the jnp path bit-for-bit in masking
semantics (causal-by-length, sliding window, chunked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(pages_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, window, chunk, ps, n_pg):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    qpos = length - 1

    # per-row early exit over the page grid: blocks fully beyond this
    # row's live length contribute nothing and are skipped outright
    @pl.when(j * ps < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (1, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)              # (ps, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1, ps)
        kpos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        ok = kpos < length
        if window is not None:
            ok &= (qpos - kpos) < window
        if chunk is not None:
            ok &= (qpos // chunk) == (kpos // chunk)
        s = jnp.where(ok, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pg - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, pages, lengths, *,
                                  window=None, chunk=None, interpret=False):
    """q: (B,Hq,dh); pools: (n_pages, page_size, Hkv, dh); pages: (B,P) i32
    physical-page table (entry 0 = the null page, only reachable past each
    row's length); lengths: (B,) live entries per row -> (B,Hq,dh)."""
    B, Hq, dh = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    P = pages.shape[1]
    G = Hq // Hkv
    kernel = functools.partial(_kernel, scale=dh ** -0.5, window=window,
                               chunk=chunk, ps=ps, n_pg=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, P),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, j, pt, lt: (b, h, 0)),
            # the page table is consulted *in the index map*: block j of
            # row b is whatever physical page the table names
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, h, j, pt, lt: (pt[b, j], 0, h // G, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, h, j, pt, lt: (pt[b, j], 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda b, h, j, pt, lt: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, dh), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)
