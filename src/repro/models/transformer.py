"""Decoder-only transformer substrate (dense / MoE / VLM-stub families).

Layers are stacked and scanned (small HLO, fast multi-pod compiles). All
functions are pure; sharding enters only through ``ShardCtx`` constraints so
the same code paths run on 1 CPU device (smoke tests) and on the 512-chip
dry-run mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.kernels import ops as OPS
from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import blockwise_attention

LOSS_CHUNK = 1024


# ------------------------------------------------------------------ params

def init(key, cfg: ArchConfig):
    n_moe = 0
    n_dense = cfg.n_layers
    if cfg.moe is not None:
        n_moe = cfg.n_layers - cfg.moe.first_k_dense
        n_dense = cfg.moe.first_k_dense
    keys = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.jdtype
    params = {
        "embed": L.ninit(keys[0], (cfg.vocab, d), dt, scale=1.0),
        "final_norm": L.oinit((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.ninit(keys[1], (d, cfg.vocab), dt)

    def attn_block(key, n):
        ks = jax.random.split(key, 8)
        blk = {
            "ln1": L.oinit((n, d), dt),
            "wq": L.ninit(ks[0], (n, d, cfg.q_dim), dt),
            "wk": L.ninit(ks[1], (n, d, cfg.kv_dim), dt),
            "wv": L.ninit(ks[2], (n, d, cfg.kv_dim), dt),
            "wo": L.ninit(ks[3], (n, cfg.q_dim, d), dt),
            "ln2": L.oinit((n, d), dt),
        }
        if cfg.qkv_bias:
            blk["bq"] = L.zinit((n, cfg.q_dim), dt)
            blk["bk"] = L.zinit((n, cfg.kv_dim), dt)
            blk["bv"] = L.zinit((n, cfg.kv_dim), dt)
        return blk, ks[4]

    if n_dense:
        blk, k = attn_block(keys[2], n_dense)
        ff = cfg.d_ff
        if cfg.moe is not None:  # deepseek-style first-dense layer width
            ff = cfg.moe.d_ff_expert * (cfg.moe.top_k + cfg.moe.n_shared)
        blk.update(L.init_mlp(k, d, ff, cfg.mlp, dt, stacked=(n_dense,)))
        params["dense_layers"] = blk
    if n_moe:
        blk, k = attn_block(keys[3], n_moe)
        blk["moe"] = M.init_moe(k, cfg, stacked=(n_moe,))
        params["moe_layers"] = blk
    return params


def param_axes(cfg: ArchConfig):
    ax = {
        "embed": P("vocab", None),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = P(None, "vocab")

    def attn_axes():
        blk = {
            "ln1": P(None, None),
            "wq": P(None, None, "qdim"),
            "wk": P(None, None, "kvdim"),
            "wv": P(None, None, "kvdim"),
            "wo": P(None, "qdim", None),
            "ln2": P(None, None),
        }
        if cfg.qkv_bias:
            blk["bq"] = P(None, "qdim")
            blk["bk"] = P(None, "kvdim")
            blk["bv"] = P(None, "kvdim")
        return blk

    if cfg.moe is None or cfg.moe.first_k_dense:
        blk = attn_axes()
        blk.update(L.mlp_axes(stacked=True))
        ax["dense_layers"] = blk
    if cfg.moe is not None:
        blk = attn_axes()
        blk["moe"] = M.moe_axes(cfg, stacked=True)
        ax["moe_layers"] = blk
    return ax


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(init, cfg=cfg), jax.random.PRNGKey(0))


# ----------------------------------------------------------------- helpers

def _constrain_qkv(ctx, cfg, q, k, v):
    if ctx is None:
        return q, k, v
    tp = ctx.axis_size("model")
    if cfg.n_heads % tp == 0:  # scheme A: Megatron head sharding
        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)
    else:                       # scheme B: sequence-sharded attention core
        q = ctx.constrain(q, "batch", "seq_tp", None, None)
        k = ctx.constrain(k, "batch", None, None, None)
        v = ctx.constrain(v, "batch", None, None, None)
    return q, k, v


def _attend_train(x, blk, cfg: ArchConfig, ctx, positions):
    """Self-attention sub-block (train/prefill path). x: (B, S, d)."""
    B, S, d = x.shape
    h = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dq->bsq", h, blk["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, blk["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + blk["bq"].astype(h.dtype)
        k = k + blk["bk"].astype(h.dtype)
        v = v + blk["bv"].astype(h.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q, k, v = _constrain_qkv(ctx, cfg, q, k, v)
    out = blockwise_attention(
        q, k, v, causal=True, window=cfg.sliding_window, chunk=cfg.attn_chunk,
        q_positions=positions, kv_positions=positions,
        softcap=cfg.logit_softcap)
    out = out.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))


def _block_train(x, blk, cfg: ArchConfig, ctx, positions, use_moe: bool):
    aux = jnp.zeros((), jnp.float32)
    x = x + _attend_train(x, blk, cfg, ctx, positions)
    h = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
    if use_moe:
        ff, aux = M.moe_ffn(h, blk["moe"], cfg, ctx)
    else:
        ff = L.mlp_apply(h, blk["w_up"], blk["w_down"], cfg.mlp)
    x = x + ff
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    return x, aux


def _scan_blocks(x, stacked, cfg, ctx, positions, use_moe, remat: bool):
    body = functools.partial(_block_train, cfg=cfg, ctx=ctx,
                             positions=positions, use_moe=use_moe)
    if remat:
        body = jax.checkpoint(body)

    def step(carry, blk):
        x, aux = carry
        x, a = body(x, blk)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def chunked_xent(hidden, lm_head, labels, mask, ctx=None, chunk=LOSS_CHUNK):
    """Cross entropy streamed over sequence chunks; never materializes the
    full (B, S, V) logits. Returns (sum_nll, sum_mask)."""
    B, S, d = hidden.shape
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nb = (S + pad) // chunk
    hs = hidden.reshape(B, nb, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, nb, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, nb, chunk).swapaxes(0, 1)

    def step(carry, xs):
        h, lab, mk = xs
        logits = jnp.einsum("bsd,dv->bsv", h, lm_head.astype(h.dtype))
        if ctx is not None:
            logits = ctx.constrain(logits, "batch", None, "vocab")
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold + 1e-4 * jnp.square(lse)) * mk.astype(jnp.float32)
        s_nll, s_mask = carry
        return (s_nll + jnp.sum(nll), s_mask + jnp.sum(mk)), None

    (s_nll, s_mask), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms))
    return s_nll, s_mask


# ------------------------------------------------------------------- train

def train_loss(params, batch, cfg: ArchConfig, ctx=None, remat=True):
    """batch: tokens (B,S), labels (B,S), mask (B,S) [, frontend (B,F,d)]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    labels, mask = batch["labels"], batch["mask"]
    if cfg.frontend is not None:
        pre = batch["frontend"].astype(cfg.jdtype)      # (B, F, d) stub embeds
        x = jnp.concatenate([pre, x], axis=1)
        labels = jnp.pad(labels, ((0, 0), (pre.shape[1], 0)))
        mask = jnp.pad(mask, ((0, 0), (pre.shape[1], 0)))
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        x, aux = _scan_blocks(x, params["dense_layers"], cfg, ctx, positions,
                              use_moe=False, remat=remat)
        aux_total += aux
    if "moe_layers" in params:
        x, aux = _scan_blocks(x, params["moe_layers"], cfg, ctx, positions,
                              use_moe=True, remat=remat)
        aux_total += aux

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    s_nll, s_mask = chunked_xent(x, lm_head, labels, mask, ctx)
    loss = s_nll / jnp.maximum(s_mask, 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux_total / cfg.n_layers
    return loss


# ------------------------------------------------------------ prefill/decode

def _kv_proj(h, blk, cfg, positions):
    B, S = h.shape[:2]
    k = jnp.einsum("bsd,dq->bsq", h, blk["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, blk["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        k = k + blk["bk"].astype(h.dtype)
        v = v + blk["bv"].astype(h.dtype)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def prefill(params, tokens, cfg: ArchConfig, ctx=None, frontend=None):
    """Full-sequence prefill. Returns (last_logits (B,V), cache dict)."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    if cfg.frontend is not None and frontend is not None:
        x = jnp.concatenate([frontend.astype(cfg.jdtype), x], axis=1)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]

    caches = {}

    def run(stacked, use_moe, name):
        nonlocal x

        def step(carry, blk):
            xx = carry
            h = L.rms_norm(xx, blk["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
            if cfg.qkv_bias:
                q = q + blk["bq"].astype(h.dtype)
            q = q.reshape(B, St, cfg.n_heads, cfg.head_dim)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k, v = _kv_proj(h, blk, cfg, positions)
            q, k, v = _constrain_qkv(ctx, cfg, q, k, v)
            out = blockwise_attention(
                q, k, v, causal=True, window=cfg.sliding_window,
                chunk=cfg.attn_chunk, q_positions=positions,
                kv_positions=positions, softcap=cfg.logit_softcap)
            out = out.reshape(B, St, cfg.q_dim)
            xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
            h2 = L.rms_norm(xx, blk["ln2"], cfg.norm_eps)
            if use_moe:
                ff, _ = M.moe_ffn(h2, blk["moe"], cfg, ctx)
            else:
                ff = L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
            xx = xx + ff
            if ctx is not None:
                xx = ctx.constrain(xx, "batch", "seq_tp", None)
            return xx, (k, v)

        x, (ks, vs) = jax.lax.scan(step, x, stacked)
        caches[name] = {"k": ks, "v": vs}   # (L, B, St, kv, dh)

    if "dense_layers" in params:
        run(params["dense_layers"], False, "dense")
    if "moe_layers" in params:
        run(params["moe_layers"], True, "moe")

    xl = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", xl, lm_head.astype(xl.dtype))[:, 0]
    if ctx is not None:
        logits = ctx.constrain(logits, "batch", "vocab")
    caches["pos"] = jnp.full((), St, jnp.int32)
    return logits, caches


def init_cache(cfg: ArchConfig, batch: int, max_len: int, ring: bool = False):
    """Zeroed decode cache. ``ring=True`` bounds the buffer for sub-quadratic
    archs (chunked attention -> attn_chunk slots; SWA -> window slots)."""
    slots = max_len
    if ring:
        if cfg.attn_chunk:
            slots = min(max_len, cfg.attn_chunk)
        elif cfg.sliding_window:
            slots = min(max_len, cfg.sliding_window)
    n_moe = 0 if cfg.moe is None else cfg.n_layers - cfg.moe.first_k_dense
    n_dense = cfg.n_layers - n_moe
    shape = lambda n: (n, batch, slots, cfg.n_kv_heads, cfg.head_dim)
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if n_dense:
        cache["dense"] = {"k": jnp.zeros(shape(n_dense), cfg.jdtype),
                          "v": jnp.zeros(shape(n_dense), cfg.jdtype)}
    if n_moe:
        cache["moe"] = {"k": jnp.zeros(shape(n_moe), cfg.jdtype),
                        "v": jnp.zeros(shape(n_moe), cfg.jdtype)}
    return cache


def decode_step(params, token, cache, cfg: ArchConfig, ctx=None,
                unroll: bool = False, pages=None, kv_bucket=None,
                block_skip: int = 0):
    """One decode step. token: (B, 1) int32. Returns (logits (B,V), cache).

    ``cache["pos"]`` may be a scalar (whole batch in lockstep — the classic
    path) or a (B,) vector of per-row positions (the serving runtime's slot
    slab, where every row is an independent request at its own depth). All
    position arithmetic below broadcasts over the batch dim so both layouts
    share one trace.

    Paged layout: when ``pages`` ((B, P) int32 physical-page table) is
    given, per-layer caches are shared pools of fixed-size KV pages
    ((n_pages, page_size, kvh, dh)) instead of per-row slabs. The new
    token's KV scatters into the row's current page, and attention reads
    only the first ``kv_bucket`` logical entries (static, host-picked to
    cover the deepest live row) through ``kernels.ops`` — so decode cost
    tracks live tokens, not slab capacity. Physical page 0 is the null
    page: pad/retired rows point there, writes to it are never read.

    ``block_skip`` (dense layout only, opt-in — the serving runtime
    engages it per dispatch when live depth <= capacity/2): stream KV in
    blocks of that size, skipping blocks beyond every row's position at
    runtime. 0 = the single fused attention (default; fastest on a
    well-utilized cache, and what the legacy chunked path always uses).

    ``unroll=True`` replaces the layer scan with a static python loop:
    per-layer caches become independent aliased buffers (no stacked xs/ys
    round-trip through the while carry) — a serving-oriented layout that
    removes the full-cache read/convert/write per step (see EXPERIMENTS.md
    §Perf, yi-34b decode hillclimb)."""
    B = token.shape[0]
    pos = cache["pos"]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))   # (B,)
    x = L.embed_lookup(params["embed"], token[:, 0])[:, None, :].astype(cfg.jdtype)
    positions = pos_b[:, None]                                    # (B, 1)
    paged = pages is not None
    if paged:
        pages = jnp.asarray(pages, jnp.int32)

    new_cache = {"pos": pos + 1}

    def run(stacked, kc, vc, use_moe):
        nonlocal x
        if paged:
            page_size = kc.shape[2]
            lp = pos_b // page_size                      # logical page
            off = pos_b % page_size                      # offset within it
            phys = jnp.take_along_axis(pages, lp[:, None], axis=1)[:, 0]
        else:
            slots = kc.shape[2]
            slot = pos_b % slots           # (B,) ring write for bounded caches

        def step(carry, xs):
            xx = carry
            blk, k_l, v_l = xs
            h = L.rms_norm(xx, blk["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
            if cfg.qkv_bias:
                q = q + blk["bq"].astype(h.dtype)
            q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k, v = _kv_proj(h, blk, cfg, positions)
            if paged:
                # scatter the new KV into each row's current physical page
                k_l = k_l.at[phys, off].set(k[:, 0].astype(k_l.dtype))
                v_l = v_l.at[phys, off].set(v[:, 0].astype(v_l.dtype))
                out = OPS.decode_attention_paged(
                    q, k_l, v_l, pages, pos_b + 1, kv_bucket=kv_bucket,
                    page_size=page_size, window=cfg.sliding_window,
                    chunk=cfg.attn_chunk, softcap=cfg.logit_softcap)
            else:
                # explicit masked write instead of dynamic_update_slice: on
                # a slot-sharded cache GSPMD lowers DUS to a masked select
                # anyway, but routes it through f32; the where() stays in
                # cache dtype and fully local (EXPERIMENTS.md §Perf).
                wmask = (jnp.arange(slots, dtype=jnp.int32)[None, :]
                         == slot[:, None])[:, :, None, None]
                k_l = jnp.where(wmask, k.astype(k_l.dtype), k_l)
                v_l = jnp.where(wmask, v.astype(v_l.dtype), v_l)
                # absolute positions of cache slots (ring-aware); unwritten
                # slots get INT32_MAX so the kv_len mask rejects them.
                slot_ids = jnp.arange(slots, dtype=jnp.int32)[None, :]
                wraps = ((pos_b // slots) * slots)[:, None]
                abs_pos = jnp.where(slot_ids <= slot[:, None],
                                    wraps + slot_ids,
                                    wraps - slots + slot_ids)
                kv_pos = jnp.where(abs_pos >= 0, abs_pos,
                                   jnp.iinfo(jnp.int32).max)
                out = OPS.decode_attention_model(
                    q, k_l, v_l, pos=pos, window=cfg.sliding_window,
                    chunk=cfg.attn_chunk, kv_positions=kv_pos,
                    softcap=cfg.logit_softcap,
                    block_skip=block_skip or None)
            out = out.reshape(B, 1, cfg.q_dim)
            xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
            h2 = L.rms_norm(xx, blk["ln2"], cfg.norm_eps)
            if use_moe:
                ff, _ = M.moe_ffn(h2, blk["moe"], cfg, ctx)
            else:
                ff = L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
            xx = xx + ff
            return xx, (k_l, v_l)

        if unroll:
            nl = kc.shape[0]
            ks_new, vs_new = kc, vc
            for l in range(nl):
                blk_l = jax.tree.map(lambda a: a[l], stacked)
                x, (k_l, v_l) = step(x, (blk_l, kc[l], vc[l]))
                ks_new = ks_new.at[l].set(k_l)
                vs_new = vs_new.at[l].set(v_l)
            return {"k": ks_new, "v": vs_new}
        x, (ks, vs) = jax.lax.scan(step, x, (stacked, kc, vc))
        return {"k": ks, "v": vs}

    if "dense_layers" in params:
        new_cache["dense"] = run(params["dense_layers"], cache["dense"]["k"],
                                 cache["dense"]["v"], False)
    if "moe_layers" in params:
        new_cache["moe"] = run(params["moe_layers"], cache["moe"]["k"],
                               cache["moe"]["v"], True)

    xl = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", xl, lm_head.astype(xl.dtype))[:, 0]
    if ctx is not None:
        logits = ctx.constrain(logits, "batch", "vocab")
    return logits, new_cache


def decode_window(params, tokens, cache, cfg: ArchConfig, ctx=None, *,
                  pages, pos, kv_bucket):
    """W-token decode window over the paged cache. tokens: (B, W) int32;
    pos: (B,) each row's first new position. Writes KV for all W tokens at
    positions pos..pos+W-1 and returns logits at every offset ((B, W, V))
    — the prefix-cache tail prefill reads only the last offset's argmax,
    the speculative verify step reads all of them to decide acceptance.
    ``cache["pos"]`` is deliberately NOT advanced: the caller owns
    position state (a verify dispatch may reject most of the window).
    Write targets must be CoW-private (the runtime copies shared pages
    first); pad rows point at null page 0, written but never read."""
    B, W = tokens.shape
    pages = jnp.asarray(pages, jnp.int32)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)

    new_cache = {"pos": cache["pos"]}

    def run(stacked, kc, vc, use_moe):
        nonlocal x
        page_size = kc.shape[2]
        lp = positions // page_size                     # (B, W) logical page
        off = positions % page_size
        phys = jnp.take_along_axis(pages, lp, axis=1)   # (B, W) physical

        def step(carry, xs):
            xx = carry
            blk, k_l, v_l = xs
            h = L.rms_norm(xx, blk["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
            if cfg.qkv_bias:
                q = q + blk["bq"].astype(h.dtype)
            q = q.reshape(B, W, cfg.n_heads, cfg.head_dim)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k, v = _kv_proj(h, blk, cfg, positions)
            k_l = k_l.at[phys, off].set(k.astype(k_l.dtype))
            v_l = v_l.at[phys, off].set(v.astype(v_l.dtype))
            out = OPS.window_attention_paged(
                q, k_l, v_l, pages, pos_b, kv_bucket=kv_bucket,
                page_size=page_size, window=cfg.sliding_window,
                chunk=cfg.attn_chunk, softcap=cfg.logit_softcap)
            out = out.reshape(B, W, cfg.q_dim)
            xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
            h2 = L.rms_norm(xx, blk["ln2"], cfg.norm_eps)
            if use_moe:
                ff, _ = M.moe_ffn(h2, blk["moe"], cfg, ctx)
            else:
                ff = L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
            xx = xx + ff
            return xx, (k_l, v_l)

        x, (ks, vs) = jax.lax.scan(step, x, (stacked, kc, vc))
        return {"k": ks, "v": vs}

    if "dense_layers" in params:
        new_cache["dense"] = run(params["dense_layers"], cache["dense"]["k"],
                                 cache["dense"]["v"], False)
    if "moe_layers" in params:
        new_cache["moe"] = run(params["moe_layers"], cache["moe"]["k"],
                               cache["moe"]["v"], True)

    xl = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", xl, lm_head.astype(xl.dtype))
    if ctx is not None:
        logits = ctx.constrain(logits, "batch", None, "vocab")
    return logits, new_cache
