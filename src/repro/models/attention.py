"""GQA attention: blockwise (flash-style, O(S) memory) jnp implementation for
train/prefill and a single-query decode path with KV caches.

This is the implementation that LOWERS for the dry-run (the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU hot-path, validated against this
in interpret mode). Blockwise streaming keeps the compiled memory roofline
honest: no (S, S) score tensor is ever materialized.

Mask model (all paths share it):
  allowed(qpos, kpos) = [kpos <= qpos if causal]
                      & [qpos - kpos < window if window]
                      & [qpos // chunk == kpos // chunk if chunk]
                      & [kpos < kv_len]
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, *, causal, window, chunk, kv_len):
    # qpos: (..., Sq, 1), kpos: (..., 1, Sk) int32
    ok = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:   # window may be a traced per-layer scalar
        ok &= (qpos - kpos) < window
    if chunk is not None:
        ok &= (qpos // chunk) == (kpos // chunk)
    if kv_len is not None:
        ok &= kpos < kv_len
    return ok


def blockwise_attention(q, k, v, *, causal=True, window=None, chunk=None,
                        q_positions=None, kv_positions=None, kv_len=None,
                        block_kv=1024, softcap=0.0):
    """q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh). Returns (B, Sq, Hq, dh).

    Streams KV in blocks with a running (max, denom, acc) softmax — the
    flash-attention recurrence in pure jnp (lax.scan over KV blocks).
    """
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = dh ** -0.5

    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)[None, :]        # (1, Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk, dtype=jnp.int32)[None, :]       # (1, Sk)

    bk = min(block_kv, Sk)
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max)
    nb = (Sk + pad) // bk

    qg = (q * scale).reshape(B, Sq, Hkv, G, dh)
    kb = k.reshape(B, nb, bk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, bk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    pb = jnp.broadcast_to(kv_positions, (B, nb * bk)).reshape(B, nb, bk)
    pb = pb.transpose(1, 0, 2)

    eff_len = jnp.asarray(Sk if kv_len is None else kv_len, jnp.int32)

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, posj = blk
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                       kj.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qp = q_positions[:, :, None, None, None]
        kp = posj[:, None, None, None, :]
        ok = _mask(qp, kp, causal=causal, window=window, chunk=chunk,
                   kv_len=eff_len)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, pos, window=None, chunk=None,
                     kv_positions=None, softcap=0.0, block_skip=None):
    """Single-token decode. q: (B, 1, Hq, dh); caches: (B, Smax, Hkv, dh);
    pos: scalar or (B,) current absolute position (cache holds pos valid
    entries, the new token's KV already written at its slot).

    ``kv_positions`` (B, Smax) gives absolute positions per cache slot for
    ring-buffer (sliding-window) caches; defaults to slot index.

    ``block_skip`` (int) selects the block-sparse path: KV streams in
    blocks of that size through the flash recurrence, and any block lying
    fully beyond every row's position is skipped *at runtime* (lax.cond
    inside the block scan) — decode compute tracks the deepest live row,
    not Smax. Exact w.r.t. the dense path: skipped blocks hold only
    masked entries, whose contribution is exactly zero.
    """
    B, _, Hq, dh = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = dh ** -0.5
    pos = jnp.asarray(pos, jnp.int32)
    qpos = jnp.broadcast_to(pos, (B,))[:, None]                     # (B, 1)
    if kv_positions is None:
        kv_positions = jnp.arange(Smax, dtype=jnp.int32)[None, :]    # (1, Smax)
    kv_positions = jnp.broadcast_to(kv_positions, (B, Smax))

    qg = (q * scale).reshape(B, Hkv, G, dh)
    if block_skip is not None and Smax > block_skip:
        return _decode_block_skip(qg, k_cache, v_cache, qpos, kv_positions,
                                  window=window, chunk=chunk, softcap=softcap,
                                  bs=block_skip).astype(q.dtype)
    # keep the cache in its storage dtype (bf16) and accumulate in f32 on
    # the MXU — upcasting the cache makes XLA hoist a full f32 copy of the
    # stacked cache out of the layer loop (EXPERIMENTS.md §Perf).
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    ok = _mask(qpos[:, :, None], kv_positions[:, None, :],
               causal=True, window=window, chunk=chunk,
               kv_len=(qpos + 1)[:, :, None])                 # (B, 1, Smax)
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)              # (B,Hkv,G,Smax)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, dh).astype(q.dtype)


def _decode_block_skip(qg, k_cache, v_cache, qpos, kv_positions, *,
                       window, chunk, softcap, bs):
    """Block-streamed decode (flash recurrence over KV blocks) with runtime
    skipping: a block whose entries lie beyond max(pos) holds, for *every*
    row, only future/sentinel positions — the ``kv_len`` mask rejects all
    of them, so the whole block is a no-op and lax.cond skips it. Ring
    caches stay correct automatically: once any row wraps, max(pos)+1
    exceeds Smax and every block is visited."""
    B, Hkv, G, dh = qg.shape
    Smax = k_cache.shape[1]
    pad = (-Smax) % bs
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max)
    nb = (Smax + pad) // bs
    eff = jnp.max(qpos) + 1                    # deepest live row, this step
    kv_len = (qpos + 1)[:, :, None]            # (B, 1, 1)

    def blk(carry, start):
        def compute(c):
            m, l, acc = c
            kj = jax.lax.dynamic_slice_in_dim(k_cache, start, bs, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v_cache, start, bs, axis=1)
            pj = jax.lax.dynamic_slice_in_dim(kv_positions, start, bs, axis=1)
            s = jnp.einsum("bhgd,bkhd->bhgk", qg, kj,
                           preferred_element_type=jnp.float32)
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            ok = _mask(qpos[:, :, None], pj[:, None, :], causal=True,
                       window=window, chunk=chunk, kv_len=kv_len)  # (B,1,bs)
            s = jnp.where(ok[:, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgk,bkhd->bhgd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        return jax.lax.cond(start < eff, compute, lambda c: c, carry), None

    carry0 = (jnp.full((B, Hkv, G), NEG_INF, jnp.float32),
              jnp.zeros((B, Hkv, G), jnp.float32),
              jnp.zeros((B, Hkv, G, dh), jnp.float32))
    starts = jnp.arange(nb, dtype=jnp.int32) * bs
    (m, l, acc), _ = jax.lax.scan(blk, carry0, starts)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, Hkv * G, dh)
