"""xLSTM block stack: super-blocks of (group_size-1) mLSTM + 1 sLSTM layers.

mLSTM uses the CHUNKWISE-PARALLEL form (stabilized exponential gating, matrix
memory): intra-chunk attention-like einsums + inter-chunk (C, n, m) scan.
This is both the lowering path (O(S·c) memory, MXU-friendly) and the oracle
for the ``repro.kernels.mlstm_scan`` Pallas kernel. sLSTM is inherently
sequential (scalar memory + recurrent gate weights) and runs as a two-level
scan (chunked remat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L

CHUNK = 64
NEG = -1e30


# ----------------------------------------------------------------- mLSTM

def mlstm_chunkwise(q, k, v, li, lf, state=None, chunk=CHUNK):
    """q,k,v: (B,S,H,dh); li,lf: (B,S,H) raw gate pre-activations.
    Returns (h (B,S,H,dh), (C,n,m) final state). k is pre-scaled by caller.
    """
    B, S, H, dh = q.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    NC = S // c
    f32 = jnp.float32

    qc = q.astype(f32).reshape(B, NC, c, H, dh).transpose(1, 0, 3, 2, 4)  # (NC,B,H,c,dh)
    kc = k.astype(f32).reshape(B, NC, c, H, dh).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(B, NC, c, H, dh).transpose(1, 0, 3, 2, 4)
    lic = li.astype(f32).reshape(B, NC, c, H).transpose(1, 0, 3, 2)       # (NC,B,H,c)
    lfc = jax.nn.log_sigmoid(lf.astype(f32)).reshape(B, NC, c, H).transpose(1, 0, 3, 2)

    D = jnp.cumsum(lfc, axis=-1)                    # (NC,B,H,c) inclusive
    G = D[..., -1:]                                 # (NC,B,H,1)
    # decay matrix: decay[t,s] = li_s + D_t - D_s for s<=t
    dec = lic[..., None, :] + D[..., :, None] - D[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    dec = jnp.where(tri, dec, NEG)                  # (NC,B,H,c,c)
    a = lic + G - D                                 # (NC,B,H,c) to-chunk-end

    scores = jnp.einsum("nbhtd,nbhsd->nbhts", qc, kc)   # (NC,B,H,c,c)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), f32)
        n0 = jnp.zeros((B, H, dh), f32)
        m0 = jnp.full((B, H), NEG, f32)
    else:
        C0, n0, m0 = (s.astype(f32) for s in state)

    def step(carry, xs):
        C, n, m = carry
        qj, kj, vj, Dj, Gj, decj, aj, sj = xs
        m_intra = jnp.max(decj, axis=-1)                         # (B,H,c)
        m_t = jnp.maximum(m[..., None] + Dj, m_intra)            # (B,H,c)
        inter_w = jnp.exp(m[..., None] + Dj - m_t)               # (B,H,c)
        inter = inter_w[..., None] * jnp.einsum("bhtd,bhde->bhte", qj, C)
        den_inter = inter_w * jnp.einsum("bhtd,bhd->bht", qj, n)
        pw = jnp.exp(decj - m_t[..., None])                      # (B,H,c,c)
        intra = jnp.einsum("bhts,bhsd->bhtd", pw * sj, vj)
        den_intra = jnp.einsum("bhts->bht", pw * sj)
        den = jnp.maximum(jnp.abs(den_inter + den_intra), jnp.exp(-m_t))
        h = (inter + intra) / den[..., None]                     # (B,H,c,dh)
        # state update
        m_a = jnp.max(aj, axis=-1)                               # (B,H)
        m_next = jnp.maximum(m + Gj[..., 0], m_a)
        w_prev = jnp.exp(m + Gj[..., 0] - m_next)
        w_s = jnp.exp(aj - m_next[..., None])                    # (B,H,c)
        C_next = w_prev[..., None, None] * C + jnp.einsum(
            "bhsd,bhse->bhde", w_s[..., None] * kj, vj)
        n_next = w_prev[..., None] * n + jnp.einsum("bhsd->bhd", w_s[..., None] * kj)
        return (C_next, n_next, m_next), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0),
                                 (qc, kc, vc, D, G, dec, a, scores))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)         # back to (B,S,H,dh)
    return h.astype(q.dtype), (C, n, m)


def mlstm_decode(q, k, v, li, lf, state):
    """Single-step recurrence. q,k,v: (B,H,dh); li,lf: (B,H)."""
    C, n, m = state
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    lf = jax.nn.log_sigmoid(lf.astype(f32))
    li = li.astype(f32)
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = jnp.einsum("bhd,bhde->bhe", q, C) / den[..., None]
    return h, (C, n, m_new)


def _mlstm_dims(cfg: ArchConfig):
    di = int(cfg.xlstm.proj_factor_m * cfg.d_model)
    return di, di // cfg.n_heads


def init_mlstm_layer(key, cfg: ArchConfig, stacked):
    d, dt = cfg.d_model, cfg.jdtype
    di, dh = _mlstm_dims(cfg)
    H, K = cfg.n_heads, cfg.xlstm.conv_width
    ks = jax.random.split(key, 8)
    return {
        "ln": L.oinit(stacked + (d,), dt),
        "w_up": L.ninit(ks[0], stacked + (d, 2 * di), dt),
        "conv_w": L.ninit(ks[1], stacked + (K, di), dt, scale=K ** -0.5),
        "wq": L.ninit(ks[2], stacked + (di, di), dt),
        "wk": L.ninit(ks[3], stacked + (di, di), dt),
        "wv": L.ninit(ks[4], stacked + (di, di), dt),
        "w_if": L.ninit(ks[5], stacked + (di, 2 * H), jnp.float32),
        "b_if": jnp.tile(jnp.array([0.0, 3.0], jnp.float32), (H,)).reshape(
            (1,) * len(stacked) + (2 * H,)) * jnp.ones(stacked + (2 * H,), jnp.float32),
        "mh_norm": L.oinit(stacked + (di,), dt),
        "w_down": L.ninit(ks[6], stacked + (di, d), dt),
    }


def mlstm_layer_axes(stacked_rank: int):
    lead = (None,) * stacked_rank
    return {
        "ln": P(*lead, None),
        "w_up": P(*lead, None, "inner"),
        "conv_w": P(*lead, None, "inner"),
        "wq": P(*lead, None, "inner"),
        "wk": P(*lead, None, "inner"),
        "wv": P(*lead, None, "inner"),
        "w_if": P(*lead, None, None),
        "b_if": P(*lead, None),
        "mh_norm": P(*lead, "inner"),
        "w_down": P(*lead, "inner", None),
    }


def mlstm_layer_apply(x, p, cfg: ArchConfig, ctx=None, state=None):
    """x: (B,S,d). state None (train/prefill) or (C,n,m,conv) for decode.
    Returns (x_out, new_state or final chunk state)."""
    B, S, d = x.shape
    di, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", h, p["w_up"].astype(h.dtype))
    z, g = jnp.split(u, 2, axis=-1)
    conv_state = None if state is None else state[3]
    zc, new_conv = L.causal_conv1d(z, p["conv_w"], conv_state)
    zc = jax.nn.silu(zc.astype(jnp.float32)).astype(z.dtype)
    q = jnp.einsum("bse,ef->bsf", zc, p["wq"].astype(z.dtype))
    k = jnp.einsum("bse,ef->bsf", zc, p["wk"].astype(z.dtype)) * (dh ** -0.5)
    v = jnp.einsum("bse,ef->bsf", z, p["wv"].astype(z.dtype))
    gates = jnp.einsum("bse,eg->bsg", zc.astype(jnp.float32),
                       p["w_if"]) + p["b_if"]
    li, lf = gates[..., 0::2], gates[..., 1::2]                  # (B,S,H)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, H, dh)
    v = v.reshape(B, S, H, dh)
    if ctx is not None:
        q = ctx.constrain(q, "batch", None, None, "inner")
        k = ctx.constrain(k, "batch", None, None, "inner")
        v = ctx.constrain(v, "batch", None, None, "inner")
    if state is None:
        hout, (C, n, m) = mlstm_chunkwise(q, k, v, li, lf,
                                          chunk=min(CHUNK, S))
    else:
        hflat, (C, n, m) = mlstm_decode(q[:, 0], k[:, 0], v[:, 0],
                                        li[:, 0], lf[:, 0], state[:3])
        hout = hflat[:, None].astype(x.dtype)
    hout = hout.reshape(B, S, di)
    # per-head rms norm ("multi-head norm")
    hn = hout.reshape(B, S, H, dh)
    hn = hn / jnp.sqrt(jnp.mean(jnp.square(hn.astype(jnp.float32)), -1,
                                keepdims=True) + cfg.norm_eps).astype(hout.dtype)
    hout = hn.reshape(B, S, di) * p["mh_norm"].astype(hout.dtype)
    hout = hout * jax.nn.silu(g.astype(jnp.float32)).astype(hout.dtype)
    y = jnp.einsum("bse,ed->bsd", hout, p["w_down"].astype(hout.dtype))
    return x + y, (C, n, m, new_conv)


# ----------------------------------------------------------------- sLSTM

def _slstm_dims(cfg: ArchConfig):
    d = cfg.d_model
    fs = int(cfg.xlstm.proj_factor_s * d)
    fs = (fs + 63) // 64 * 64
    return d // cfg.n_heads, fs


def init_slstm_layer(key, cfg: ArchConfig, stacked):
    d, dt = cfg.d_model, cfg.jdtype
    H, K = cfg.n_heads, cfg.xlstm.conv_width
    dh, fs = _slstm_dims(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "ln": L.oinit(stacked + (d,), dt),
        "conv_w": L.ninit(ks[0], stacked + (K, d), dt, scale=K ** -0.5),
        "w_gates": L.ninit(ks[1], stacked + (d, 4 * d), jnp.float32),
        "r_gates": L.ninit(ks[2], stacked + (H, dh, 4 * dh), jnp.float32,
                           scale=dh ** -0.5),
        "b_gates": L.zinit(stacked + (4 * d,), jnp.float32),
        "gn": L.oinit(stacked + (d,), dt),
        "ln2": L.oinit(stacked + (d,), dt),
    }
    p.update(L.init_mlp(ks[3], d, fs, "swiglu", dt, stacked=stacked))
    return p


def slstm_layer_axes(stacked_rank: int):
    lead = (None,) * stacked_rank
    return {
        "ln": P(*lead, None),
        "conv_w": P(*lead, None, None),
        "w_gates": P(*lead, None, None),
        "r_gates": P(*lead, None, None, None),
        "b_gates": P(*lead, None),
        "gn": P(*lead, None),
        "ln2": P(*lead, None),
        "w_up": P(*lead, None, "ffn"),
        "w_down": P(*lead, "ffn", None),
    }


def _slstm_cell(carry, gates_t, r_gates, H, dh):
    """carry: (c,n,h,m) each (B,H,dh); gates_t: (B,4,H,dh) from W·x."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, r_gates).reshape(
        h.shape[0], H, 4, dh).transpose(0, 2, 1, 3)             # (B,4,H,dh)
    gi, gf, gz, go = [gates_t[:, j] + rec[:, j] for j in range(4)]
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    ip = jnp.exp(gi - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * jnp.tanh(gz)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_layer_apply(x, p, cfg: ArchConfig, ctx=None, state=None,
                      inner_chunk: int = 256):
    """x: (B,S,d). Two-level scan (chunked remat) over the scalar recurrence."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    h_in = L.rms_norm(x, p["ln"], cfg.norm_eps)
    conv_state = None if state is None else state[4]
    xc, new_conv = L.causal_conv1d(h_in, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32))
    xr = h_in.astype(jnp.float32)
    # W·x for all t: i,f from conv'd; z,o from raw
    wi, wf, wz, wo = jnp.split(p["w_gates"], 4, axis=-1)
    bi, bf, bz, bo = jnp.split(p["b_gates"], 4, axis=-1)
    gi = xc @ wi + bi
    gf = xc @ wf + bf
    gz = xr @ wz + bz
    go = xr @ wo + bo
    gates = jnp.stack([gi, gf, gz, go], 2).reshape(B, S, 4, H, dh)

    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        carry0 = (z, z, z, jnp.full((B, H, dh), NEG, jnp.float32))
    else:
        carry0 = tuple(s.astype(jnp.float32) for s in state[:4])

    cell = functools.partial(_slstm_cell, r_gates=p["r_gates"], H=H, dh=dh)

    if S == 1:
        carry = cell(carry0, gates[:, 0])
        hs = carry[2][:, None]
    else:
        c = min(inner_chunk, S)
        NC = S // c if S % c == 0 else 1
        c = S // NC
        gch = gates.reshape(B, NC, c, 4, H, dh).transpose(1, 2, 0, 3, 4, 5)

        @jax.checkpoint
        def outer(carry, gc):  # gc: (c,B,4,H,dh)
            def inner(cr, g_t):
                cr = cell(cr, g_t)
                return cr, cr[2]
            carry, hseq = jax.lax.scan(inner, carry, gc)
            return carry, hseq                                  # (c,B,H,dh)

        carry, hs = jax.lax.scan(outer, carry0, gch)
        hs = hs.reshape(NC * c, B, H, dh).transpose(1, 0, 2, 3)  # (B,S,H,dh)
    hs = hs.reshape(B, S, d)
    # group norm per head
    hn = hs.reshape(B, S, H, dh)
    hn = hn / jnp.sqrt(jnp.mean(jnp.square(hn), -1, keepdims=True) + cfg.norm_eps)
    y = (hn.reshape(B, S, d) * p["gn"].astype(jnp.float32)).astype(x.dtype)
    x = x + y
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(h2, p["w_up"], p["w_down"], "swiglu")
    new_state = carry + (new_conv,) if state is not None else carry + (new_conv,)
    return x, new_state


# ------------------------------------------------------------------ model

def _layout(cfg: ArchConfig):
    gs = cfg.xlstm.group_size
    assert cfg.n_layers % gs == 0
    return cfg.n_layers // gs, gs - 1   # (n_groups, mlstm_per_group)


def init(key, cfg: ArchConfig):
    G, M = _layout(cfg)
    ks = jax.random.split(key, 5)
    return {
        "embed": L.ninit(ks[0], (cfg.vocab, cfg.d_model), cfg.jdtype, scale=1.0),
        "mlstm": init_mlstm_layer(ks[1], cfg, (G, M)),
        "slstm": init_slstm_layer(ks[2], cfg, (G,)),
        "final_norm": L.oinit((cfg.d_model,), cfg.jdtype),
        "lm_head": L.ninit(ks[3], (cfg.d_model, cfg.vocab), cfg.jdtype),
    }


def param_axes(cfg: ArchConfig):
    return {
        "embed": P("vocab", None),
        "mlstm": mlstm_layer_axes(2),
        "slstm": slstm_layer_axes(1),
        "final_norm": P(None),
        "lm_head": P(None, "vocab"),
    }


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(init, cfg=cfg), jax.random.PRNGKey(0))


def _backbone(params, x, cfg: ArchConfig, ctx, remat):
    """x: (B,S,d) -> (B,S,d). Train/prefill path; returns final states too."""
    mbody = functools.partial(mlstm_layer_apply, cfg=cfg, ctx=ctx)
    if remat:
        mbody = jax.checkpoint(mbody)

    def group(x, xs):
        mparams, sparams = xs

        def mstep(xx, mp):
            xx, _ = mbody(xx, mp)
            return xx, None

        x, _ = jax.lax.scan(mstep, x, mparams)
        x, _ = slstm_layer_apply(x, sparams, cfg, ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "seq_tp", None)
        return x, None

    x, _ = jax.lax.scan(group, x, (params["mlstm"], params["slstm"]))
    return x


def train_loss(params, batch, cfg: ArchConfig, ctx=None, remat=True):
    from repro.models.transformer import chunked_xent
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    x = _backbone(params, x, cfg, ctx, remat)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    s_nll, s_mask = chunked_xent(x, params["lm_head"], batch["labels"],
                                 batch["mask"], ctx)
    return s_nll / jnp.maximum(s_mask, 1.0)


def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0, ring: bool = True):
    """Recurrent state: O(1) in sequence length (the xLSTM selling point)."""
    G, M = _layout(cfg)
    di, dh = _mlstm_dims(cfg)
    H, K = cfg.n_heads, cfg.xlstm.conv_width
    d = cfg.d_model
    dhs = d // H
    f32 = jnp.float32
    z = jnp.zeros
    return {
        "mlstm": (z((G, M, batch, H, dh, dh), f32), z((G, M, batch, H, dh), f32),
                  jnp.full((G, M, batch, H), NEG, f32),
                  z((G, M, batch, K - 1, di), cfg.jdtype)),
        "slstm": (z((G, batch, H, dhs), f32), z((G, batch, H, dhs), f32),
                  z((G, batch, H, dhs), f32),
                  jnp.full((G, batch, H, dhs), NEG, f32),
                  z((G, batch, K - 1, d), cfg.jdtype)),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ArchConfig, ctx=None, frontend=None):
    """Prefill via the chunkwise path, materializing final recurrent states."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)

    def group(x, xs):
        mparams, sparams = xs

        def mstep(xx, mp):
            # capture final chunk state by re-running state-returning apply
            xx, st = mlstm_layer_apply(xx, mp, cfg, ctx)
            return xx, st

        x, mstates = jax.lax.scan(mstep, x, mparams)
        x, sstate = slstm_layer_apply(x, sparams, cfg, ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "seq_tp", None)
        return x, (mstates, sstate)

    x, (mstates, sstates) = jax.lax.scan(group, x, (params["mlstm"], params["slstm"]))
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    # conv states: mlstm_layer_apply with state=None returns new_conv from
    # causal_conv1d trained path (last K-1 inputs)
    cache = {"mlstm": mstates, "slstm": sstates,
             "pos": jnp.full((), S, jnp.int32)}
    return logits, cache


def decode_step(params, token, cache, cfg: ArchConfig, ctx=None):
    B = token.shape[0]
    x = L.embed_lookup(params["embed"], token[:, 0])[:, None, :].astype(cfg.jdtype)

    def group(x, xs):
        mparams, mstate, sparams, sstate = xs

        def mstep(carry, xs2):
            xx = carry
            mp, st = xs2
            xx, new_st = mlstm_layer_apply(xx, mp, cfg, ctx, state=st)
            return xx, new_st

        x, new_mstates = jax.lax.scan(mstep, x, (mparams, mstate))
        x, new_sstate = slstm_layer_apply(x, sparams, cfg, ctx, state=sstate)
        return x, (new_mstates, new_sstate)

    x, (nm, ns) = jax.lax.scan(
        group, x, (params["mlstm"], cache["mlstm"], params["slstm"],
                   cache["slstm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    return logits, {"mlstm": nm, "slstm": ns, "pos": cache["pos"] + 1}
