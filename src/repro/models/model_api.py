"""Uniform model protocol over the four family implementations.

Every family exposes: init / abstract_params / param_axes / train_loss /
prefill / decode_step / init_cache. This module adds input/cache spec
builders (ShapeDtypeStruct stand-ins, no allocation) used by smoke tests,
the launcher, and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from types import ModuleType

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, get_config
from repro.models import encdec, hybrid, transformer, xlstm


def get_module(cfg: ArchConfig) -> ModuleType:
    if cfg.family == "encdec":
        return encdec
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xlstm
    return transformer          # dense / moe / vlm


# ------------------------------------------------------------- input specs

def batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Training-batch ShapeDtypeStructs + logical axes."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    axes = {
        "tokens": P("batch", None),
        "labels": P("batch", None),
        "mask": P("batch", None),
    }
    if cfg.frontend is not None:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
        axes["frontend"] = P("batch", None, None)
    return specs, axes


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    axes = {"tokens": P("batch", None)}
    if cfg.frontend is not None:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
        axes["frontend"] = P("batch", None, None)
    return specs, axes


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, cache_mode="slots"):
    """Abstract decode cache (ring-bounded for long_500k) + logical axes."""
    mod = get_module(cfg)
    ring = shape.name == "long_500k"
    abstract = jax.eval_shape(functools.partial(
        mod.init_cache, cfg, shape.global_batch, shape.seq_len, ring=ring))
    return abstract, cache_axes(cfg, cache_mode)


def cache_axes(cfg: ArchConfig, cache_mode: str = "slots"):
    """cache_mode: "slots" shards the KV slot dim (spreads memory; GSPMD
    lowers the per-token write to a masked local-slice rewrite); "dh" shards
    head_dim (local one-slot writes; reads psum score stats). See
    EXPERIMENTS.md §Perf (yi-34b decode)."""
    if cache_mode == "dh":
        kv = {"k": P(None, "batch", None, None, "inner"),
              "v": P(None, "batch", None, None, "inner")}
    else:
        kv = {"k": P(None, "batch", "cache_seq"),
              "v": P(None, "batch", "cache_seq")}
    if cfg.family == "encdec":
        return {"self": dict(kv), "cross": dict(kv), "pos": P()}
    if cfg.family == "hybrid":
        return {**kv, "ssm": P(None, "batch", "inner"),
                "conv": P(None, "batch", None, "inner"), "pos": P()}
    if cfg.xlstm is not None:
        return {
            "mlstm": (P(None, None, "batch", None, None, "inner"),
                      P(None, None, "batch", None, "inner"),
                      P(None, None, "batch"),
                      P(None, None, "batch", None, "inner")),
            "slstm": (P(None, "batch"), P(None, "batch"), P(None, "batch"),
                      P(None, "batch"), P(None, "batch", None, None)),
            "pos": P(),
        }
    out = {"pos": P()}
    if cfg.moe is None or cfg.moe.first_k_dense:
        out["dense"] = dict(kv)
    if cfg.moe is not None:
        out["moe"] = dict(kv)
    return out


def decode_token_specs(cfg: ArchConfig, shape: ShapeConfig):
    B = shape.global_batch
    return (jax.ShapeDtypeStruct((B, 1), jnp.int32), P("batch", None))


def grow_cache(cfg: ArchConfig, cache, max_len: int):
    """Pad prefill-produced KV caches (seq dim) to ``max_len`` slots so decode
    can continue past the prefill length. Recurrent states are size-invariant."""
    def pad_kv(d):
        out = {}
        for name in ("k", "v"):
            buf = d[name]
            slots = buf.shape[2]
            if slots < max_len:
                buf = jnp.pad(buf, ((0, 0), (0, 0), (0, max_len - slots),
                                    (0, 0), (0, 0)))
            out[name] = buf
        return out

    if cfg.family == "encdec":
        return {"self": pad_kv(cache["self"]), "cross": cache["cross"],
                "pos": cache["pos"]}
    if cfg.family == "hybrid":
        new = dict(cache)
        new.update(pad_kv(cache))
        return new
    if cfg.xlstm is not None:
        return cache
    new = dict(cache)
    for part in ("dense", "moe"):
        if part in cache:
            new[part] = pad_kv(cache[part])
    return new


# ------------------------------------------------- serving runtime helpers

def supports_slots(cfg: ArchConfig) -> bool:
    """True when the family's decode cache is a pure KV slab whose rows are
    independent requests (dense / moe / vlm -> transformer module). The
    recurrent families (hybrid, xlstm) and encdec carry scalar-position
    states the slot runtime cannot address per-row yet."""
    return get_module(cfg) is transformer


def pow2_bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power-of-two >= n, clamped to [lo, hi]. Padding shapes to
    these buckets bounds the number of distinct jit traces to O(log)."""
    b = lo
    while b < n and b < hi:
        b *= 2
    return min(max(b, lo), hi)


def bucket_ladder(lo: int, hi: int):
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return tuple(out)


def init_slab_cache(cfg: ArchConfig, slots: int, capacity: int):
    """Fixed-shape slot-slab decode cache: ``slots`` independent requests x
    ``capacity`` KV entries each, with a per-row position vector (the shape
    never changes across admissions, so decode compiles exactly once)."""
    cache = get_module(cfg).init_cache(cfg, slots, capacity)
    cache["pos"] = jnp.zeros((slots,), jnp.int32)
    return cache


def scatter_prefill(cfg: ArchConfig, slab, prefill_cache, slot_idx, seq_len):
    """Write a prefilled (B, seq_len) KV cache into slab rows ``slot_idx``
    ((B,) int32) and stamp their positions. Pure function of fixed shapes —
    jit it once per (batch-bucket, length-bucket)."""
    new = dict(slab)
    for part in ("dense", "moe"):
        if part not in prefill_cache or part not in slab:
            continue
        dst = dict(slab[part])
        for nm in ("k", "v"):
            src = prefill_cache[part][nm]          # (L, B, S, kvh, dh)
            dst[nm] = slab[part][nm].at[:, slot_idx, :src.shape[2]].set(
                src.astype(slab[part][nm].dtype))
        new[part] = dst
    new["pos"] = slab["pos"].at[slot_idx].set(jnp.int32(seq_len))
    return new


def init_paged_cache(cfg: ArchConfig, rows: int, n_pages: int,
                     page_size: int):
    """Paged decode cache: a shared physical pool of ``n_pages`` KV pages
    of ``page_size`` entries per layer ((L, n_pages, page_size, kvh, dh)),
    plus a per-row position vector for ``rows`` slots. Which pages a row
    owns lives host-side (the runtime's page table / allocator); physical
    page 0 is reserved as the null page. HBM scales with the pool, not
    with rows x capacity."""
    cache = get_module(cfg).init_cache(cfg, n_pages, page_size)
    cache["pos"] = jnp.zeros((rows,), jnp.int32)
    return cache


def scatter_prefill_paged(cfg: ArchConfig, slab, prefill_cache, slot_idx,
                          seq_len, page_rows, page_size: int):
    """Paged counterpart of ``scatter_prefill``: split a prefilled
    (B, seq_len) KV cache into page-size chunks and scatter them into the
    physical pool pages named by ``page_rows`` ((B, ceil(seq_len/page))
    int32), stamping positions for rows ``slot_idx``. Pad rows aim all
    their chunks at the null page (0) — colliding writes there are never
    read. Pure function of fixed shapes, jitted once per bucket."""
    new = dict(slab)
    npg = page_rows.shape[1]
    flat = page_rows.reshape(-1)
    for part in ("dense", "moe"):
        if part not in prefill_cache or part not in slab:
            continue
        dst = dict(slab[part])
        for nm in ("k", "v"):
            src = prefill_cache[part][nm]          # (L, B, S, kvh, dh)
            L, B, S = src.shape[:3]
            pad = npg * page_size - S
            if pad:
                src = jnp.pad(src, ((0, 0), (0, 0), (0, pad),
                                    (0, 0), (0, 0)))
            src = src.reshape(L, B * npg, page_size, *src.shape[3:])
            dst[nm] = slab[part][nm].at[:, flat].set(
                src.astype(slab[part][nm].dtype))
        new[part] = dst
    new["pos"] = slab["pos"].at[slot_idx].set(jnp.int32(seq_len))
    return new


def fused_decode(params, tok, cache, active, remaining, cfg: ArchConfig,
                 ctx=None, steps: int = 8, pages=None, kv_bucket=None,
                 block_skip=None):
    """``steps`` greedy decode steps fused into one ``lax.scan`` (one device
    dispatch per block instead of per token). Rows where ``active`` is False
    are frozen: their position does not advance and their token does not
    change, so finished requests stop paying for rides they do not take.

    tok: (S, 1) int32; active: (S,) bool; remaining: (S,) int32.
    ``pages``/``kv_bucket`` select the paged-cache layout (transformer
    only): the page table is constant across the fused block — the host
    pre-allocates pages covering every row's position through the final
    step — and ``kv_bucket`` must cover max(pos) + steps.
    Returns (tok, cache, active, remaining, tokens (steps, S))."""
    mod = get_module(cfg)
    kw = {} if pages is None else {"pages": pages, "kv_bucket": kv_bucket}
    if block_skip is not None:       # 0 = force the plain full-width path
        kw["block_skip"] = block_skip

    def step(carry, _):
        tok, cache, active, remaining = carry
        pos0 = cache["pos"]
        logits, cache = mod.decode_step(params, tok, cache, cfg, ctx, **kw)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        tok = jnp.where(active[:, None], nxt, tok)
        cache["pos"] = jnp.where(active, cache["pos"], pos0)
        remaining = remaining - active.astype(jnp.int32)
        active = active & (remaining > 0)
        return (tok, cache, active, remaining), nxt[:, 0]

    (tok, cache, active, remaining), toks = jax.lax.scan(
        step, (tok, cache, active, remaining), None, length=steps)
    return tok, cache, active, remaining, toks


def decode_window(params, tokens, cache, cfg: ArchConfig, ctx=None, *,
                  pages, pos, kv_bucket):
    """Multi-token decode window (paged transformer slab only): write KV
    for all W tokens at positions pos..pos+W-1 and return per-offset
    logits ((B, W, V)) without advancing cache positions. Two serving
    users share it: the prefix-cache tail prefill (argmax of the last
    offset seeds decode) and the speculative-decode verify dispatch (all
    offsets decide acceptance host-side). Requires ``supports_slots``;
    the trace key is (batch bucket, W, kv_bucket), so distinct window
    widths stay within the bucketed-compilation budget
    (``RuntimeKernels.max_traces``)."""
    mod = get_module(cfg)
    return mod.decode_window(params, tokens, cache, cfg, ctx, pages=pages,
                             pos=pos, kv_bucket=kv_bucket)


# --------------------------------------------------------------- metadata

def param_count(cfg: ArchConfig) -> int:
    import math
    tree = get_module(cfg).abstract_params(cfg)
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(tree))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: only top-k routed experts count)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    mo = cfg.moe
    n_moe_layers = cfg.n_layers - mo.first_k_dense
    from repro.models.layers import mlp_up_width
    per_expert = (cfg.d_model * mlp_up_width(mo.d_ff_expert, cfg.mlp)
                  + mo.d_ff_expert * cfg.d_model)
    inactive = n_moe_layers * (mo.n_routed - mo.top_k) * per_expert
    return total - inactive
