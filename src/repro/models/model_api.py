"""Uniform model protocol over the four family implementations.

Every family exposes: init / abstract_params / param_axes / train_loss /
prefill / decode_step / init_cache. This module adds input/cache spec
builders (ShapeDtypeStruct stand-ins, no allocation) used by smoke tests,
the launcher, and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from types import ModuleType

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, get_config
from repro.models import encdec, hybrid, transformer, xlstm


def get_module(cfg: ArchConfig) -> ModuleType:
    if cfg.family == "encdec":
        return encdec
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return xlstm
    return transformer          # dense / moe / vlm


# ------------------------------------------------------------- input specs

def batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Training-batch ShapeDtypeStructs + logical axes."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    axes = {
        "tokens": P("batch", None),
        "labels": P("batch", None),
        "mask": P("batch", None),
    }
    if cfg.frontend is not None:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
        axes["frontend"] = P("batch", None, None)
    return specs, axes


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    axes = {"tokens": P("batch", None)}
    if cfg.frontend is not None:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
        axes["frontend"] = P("batch", None, None)
    return specs, axes


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, cache_mode="slots"):
    """Abstract decode cache (ring-bounded for long_500k) + logical axes."""
    mod = get_module(cfg)
    ring = shape.name == "long_500k"
    abstract = jax.eval_shape(functools.partial(
        mod.init_cache, cfg, shape.global_batch, shape.seq_len, ring=ring))
    return abstract, cache_axes(cfg, cache_mode)


def cache_axes(cfg: ArchConfig, cache_mode: str = "slots"):
    """cache_mode: "slots" shards the KV slot dim (spreads memory; GSPMD
    lowers the per-token write to a masked local-slice rewrite); "dh" shards
    head_dim (local one-slot writes; reads psum score stats). See
    EXPERIMENTS.md §Perf (yi-34b decode)."""
    if cache_mode == "dh":
        kv = {"k": P(None, "batch", None, None, "inner"),
              "v": P(None, "batch", None, None, "inner")}
    else:
        kv = {"k": P(None, "batch", "cache_seq"),
              "v": P(None, "batch", "cache_seq")}
    if cfg.family == "encdec":
        return {"self": dict(kv), "cross": dict(kv), "pos": P()}
    if cfg.family == "hybrid":
        return {**kv, "ssm": P(None, "batch", "inner"),
                "conv": P(None, "batch", None, "inner"), "pos": P()}
    if cfg.xlstm is not None:
        return {
            "mlstm": (P(None, None, "batch", None, None, "inner"),
                      P(None, None, "batch", None, "inner"),
                      P(None, None, "batch"),
                      P(None, None, "batch", None, "inner")),
            "slstm": (P(None, "batch"), P(None, "batch"), P(None, "batch"),
                      P(None, "batch"), P(None, "batch", None, None)),
            "pos": P(),
        }
    out = {"pos": P()}
    if cfg.moe is None or cfg.moe.first_k_dense:
        out["dense"] = dict(kv)
    if cfg.moe is not None:
        out["moe"] = dict(kv)
    return out


def decode_token_specs(cfg: ArchConfig, shape: ShapeConfig):
    B = shape.global_batch
    return (jax.ShapeDtypeStruct((B, 1), jnp.int32), P("batch", None))


def grow_cache(cfg: ArchConfig, cache, max_len: int):
    """Pad prefill-produced KV caches (seq dim) to ``max_len`` slots so decode
    can continue past the prefill length. Recurrent states are size-invariant."""
    def pad_kv(d):
        out = {}
        for name in ("k", "v"):
            buf = d[name]
            slots = buf.shape[2]
            if slots < max_len:
                buf = jnp.pad(buf, ((0, 0), (0, 0), (0, max_len - slots),
                                    (0, 0), (0, 0)))
            out[name] = buf
        return out

    if cfg.family == "encdec":
        return {"self": pad_kv(cache["self"]), "cross": cache["cross"],
                "pos": cache["pos"]}
    if cfg.family == "hybrid":
        new = dict(cache)
        new.update(pad_kv(cache))
        return new
    if cfg.xlstm is not None:
        return cache
    new = dict(cache)
    for part in ("dense", "moe"):
        if part in cache:
            new[part] = pad_kv(cache[part])
    return new


# --------------------------------------------------------------- metadata

def param_count(cfg: ArchConfig) -> int:
    import math
    tree = get_module(cfg).abstract_params(cfg)
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(tree))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: only top-k routed experts count)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    mo = cfg.moe
    n_moe_layers = cfg.n_layers - mo.first_k_dense
    from repro.models.layers import mlp_up_width
    per_expert = (cfg.d_model * mlp_up_width(mo.d_ff_expert, cfg.mlp)
                  + mo.d_ff_expert * cfg.d_model)
    inactive = n_moe_layers * (mo.n_routed - mo.top_k) * per_expert
    return total - inactive
