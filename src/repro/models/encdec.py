"""Whisper-style encoder-decoder (audio frontend stubbed to frame embeddings).

Encoder: bidirectional pre-LN transformer over (B, enc_seq, d) frames with a
learnable position embedding. Decoder: causal self-attention (RoPE) +
cross-attention over encoder output. LayerNorm (w, b) matches whisper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import blockwise_attention, decode_attention


def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 16)
    d, dt = cfg.d_model, cfg.jdtype
    ne, nd = cfg.encdec.n_enc_layers, cfg.n_layers

    def attn(k, n, prefix=""):
        kk = jax.random.split(k, 4)
        return {
            prefix + "wq": L.ninit(kk[0], (n, d, cfg.q_dim), dt),
            prefix + "wk": L.ninit(kk[1], (n, d, cfg.kv_dim), dt),
            prefix + "wv": L.ninit(kk[2], (n, d, cfg.kv_dim), dt),
            prefix + "wo": L.ninit(kk[3], (n, cfg.q_dim, d), dt),
        }

    def ln(n, name):
        return {name + "_w": L.oinit((n, d), dt), name + "_b": L.zinit((n, d), dt)}

    enc = {}
    enc.update(ln(ne, "ln1"))
    enc.update(attn(ks[0], ne))
    enc.update(ln(ne, "ln2"))
    enc.update(L.init_mlp(ks[1], d, cfg.d_ff, cfg.mlp, dt, stacked=(ne,)))

    dec = {}
    dec.update(ln(nd, "ln1"))
    dec.update(attn(ks[2], nd))
    dec.update(ln(nd, "lnx"))
    dec.update(attn(ks[3], nd, prefix="x_"))
    dec.update(ln(nd, "ln2"))
    dec.update(L.init_mlp(ks[4], d, cfg.d_ff, cfg.mlp, dt, stacked=(nd,)))

    return {
        "embed": L.ninit(ks[5], (cfg.vocab, d), dt, scale=1.0),
        "enc_pos": L.ninit(ks[6], (cfg.encdec.enc_seq, d), dt, scale=0.02),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm_w": L.oinit((d,), dt), "enc_norm_b": L.zinit((d,), dt),
        "final_norm_w": L.oinit((d,), dt), "final_norm_b": L.zinit((d,), dt),
        "lm_head": L.ninit(ks[7], (d, cfg.vocab), dt),
    }


def param_axes(cfg: ArchConfig):
    def attn(prefix=""):
        return {
            prefix + "wq": P(None, None, "qdim"),
            prefix + "wk": P(None, None, "kvdim"),
            prefix + "wv": P(None, None, "kvdim"),
            prefix + "wo": P(None, "qdim", None),
        }

    def ln(name):
        return {name + "_w": P(None, None), name + "_b": P(None, None)}

    enc = {**ln("ln1"), **attn(), **ln("ln2"), **L.mlp_axes(stacked=True)}
    dec = {**ln("ln1"), **attn(), **ln("lnx"), **attn("x_"), **ln("ln2"),
           **L.mlp_axes(stacked=True)}
    return {
        "embed": P("vocab", None),
        "enc_pos": P(None, None),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm_w": P(None), "enc_norm_b": P(None),
        "final_norm_w": P(None), "final_norm_b": P(None),
        "lm_head": P(None, "vocab"),
    }


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(init, cfg=cfg), jax.random.PRNGKey(0))


def _proj_qkv(h, blk, cfg, prefix=""):
    B, S = h.shape[:2]
    q = jnp.einsum("bsd,dq->bsq", h, blk[prefix + "wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dq->bsq", h, blk[prefix + "wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, blk[prefix + "wv"].astype(h.dtype))
    return (q.reshape(B, S, cfg.n_heads, cfg.head_dim),
            k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))


def encode(params, frames, cfg: ArchConfig, ctx=None, remat=False):
    """frames: (B, enc_seq, d) stub embeddings -> encoder output (B, enc_seq, d)."""
    x = frames.astype(cfg.jdtype) + params["enc_pos"].astype(cfg.jdtype)[None]
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)

    def body(xx, blk):
        h = L.layer_norm(xx, blk["ln1_w"], blk["ln1_b"], cfg.norm_eps)
        q, k, v = _proj_qkv(h, blk, cfg)
        if ctx is not None:
            q, k, v = _cq(ctx, cfg, q, k, v)
        out = blockwise_attention(q, k, v, causal=False)
        out = out.reshape(xx.shape[0], xx.shape[1], cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
        h2 = L.layer_norm(xx, blk["ln2_w"], blk["ln2_b"], cfg.norm_eps)
        xx = xx + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
        if ctx is not None:
            xx = ctx.constrain(xx, "batch", "seq_tp", None)
        return xx, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_norm_w"], params["enc_norm_b"], cfg.norm_eps)


def _cq(ctx, cfg, q, k, v):
    tp = ctx.axis_size("model")
    if cfg.n_heads % tp == 0:
        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)
    else:
        q = ctx.constrain(q, "batch", "seq_tp", None, None)
    return q, k, v


def _decoder(params, tokens, enc_out, cfg, ctx, remat):
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(xx, blk):
        h = L.layer_norm(xx, blk["ln1_w"], blk["ln1_b"], cfg.norm_eps)
        q, k, v = _proj_qkv(h, blk, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if ctx is not None:
            q, k, v = _cq(ctx, cfg, q, k, v)
        out = blockwise_attention(q, k, v, causal=True,
                                  q_positions=positions, kv_positions=positions)
        out = out.reshape(B, S, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
        # cross attention
        hx = L.layer_norm(xx, blk["lnx_w"], blk["lnx_b"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dq->bsq", hx, blk["x_wq"].astype(hx.dtype))
        qx = qx.reshape(B, S, cfg.n_heads, cfg.head_dim)
        kx = jnp.einsum("bsd,dq->bsq", enc_out, blk["x_wk"].astype(hx.dtype))
        vx = jnp.einsum("bsd,dq->bsq", enc_out, blk["x_wv"].astype(hx.dtype))
        kx = kx.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
        vx = vx.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
        outx = blockwise_attention(qx, kx, vx, causal=False)
        outx = outx.reshape(B, S, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", outx, blk["x_wo"].astype(hx.dtype))
        h2 = L.layer_norm(xx, blk["ln2_w"], blk["ln2_b"], cfg.norm_eps)
        xx = xx + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
        if ctx is not None:
            xx = ctx.constrain(xx, "batch", "seq_tp", None)
        return xx, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.layer_norm(x, params["final_norm_w"], params["final_norm_b"],
                        cfg.norm_eps)


def train_loss(params, batch, cfg: ArchConfig, ctx=None, remat=True):
    from repro.models.transformer import chunked_xent
    enc_out = encode(params, batch["frontend"], cfg, ctx, remat=remat)
    x = _decoder(params, batch["tokens"], enc_out, cfg, ctx, remat)
    s_nll, s_mask = chunked_xent(x, params["lm_head"], batch["labels"],
                                 batch["mask"], ctx)
    return s_nll / jnp.maximum(s_mask, 1.0)


def prefill(params, tokens, cfg: ArchConfig, ctx=None, frontend=None):
    """Returns (last-token logits, cache with self KV + cross KV)."""
    B, S = tokens.shape
    enc_out = encode(params, frontend, cfg, ctx)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)

    def body(xx, blk):
        h = L.layer_norm(xx, blk["ln1_w"], blk["ln1_b"], cfg.norm_eps)
        q, k, v = _proj_qkv(h, blk, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if ctx is not None:
            q, k, v = _cq(ctx, cfg, q, k, v)
        out = blockwise_attention(q, k, v, causal=True,
                                  q_positions=positions, kv_positions=positions)
        out = out.reshape(B, S, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
        hx = L.layer_norm(xx, blk["lnx_w"], blk["lnx_b"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dq->bsq", hx, blk["x_wq"].astype(hx.dtype))
        qx = qx.reshape(B, S, cfg.n_heads, cfg.head_dim)
        kx = jnp.einsum("bsd,dq->bsq", enc_out, blk["x_wk"].astype(hx.dtype))
        vx = jnp.einsum("bsd,dq->bsq", enc_out, blk["x_wv"].astype(hx.dtype))
        kx = kx.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
        vx = vx.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim)
        outx = blockwise_attention(qx, kx, vx, causal=False)
        outx = outx.reshape(B, S, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", outx, blk["x_wo"].astype(hx.dtype))
        h2 = L.layer_norm(xx, blk["ln2_w"], blk["ln2_b"], cfg.norm_eps)
        xx = xx + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
        if ctx is not None:
            xx = ctx.constrain(xx, "batch", "seq_tp", None)
        return xx, (k, v, kx, vx)

    x, (ks, vs, kxs, vxs) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layer_norm(x, params["final_norm_w"], params["final_norm_b"],
                     cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["lm_head"].astype(x.dtype))
    cache = {"self": {"k": ks, "v": vs}, "cross": {"k": kxs, "v": vxs},
             "pos": jnp.full((), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, ring: bool = False):
    nd = cfg.n_layers
    kv = (nd, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xkv = (nd, batch, cfg.encdec.enc_seq, cfg.n_kv_heads, cfg.head_dim)
    z = lambda s: jnp.zeros(s, cfg.jdtype)
    return {"self": {"k": z(kv), "v": z(kv)},
            "cross": {"k": z(xkv), "v": z(xkv)},
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, token, cache, cfg: ArchConfig, ctx=None):
    B = token.shape[0]
    pos = cache["pos"]
    x = L.embed_lookup(params["embed"], token[:, 0])[:, None, :].astype(cfg.jdtype)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)

    def body(carry, xs):
        xx = carry
        blk, k_l, v_l, kx_l, vx_l = xs
        h = L.layer_norm(xx, blk["ln1_w"], blk["ln1_b"], cfg.norm_eps)
        q, k, v = _proj_qkv(h, blk, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        k_l = jax.lax.dynamic_update_slice(k_l, k, (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v, (0, pos, 0, 0))
        out = decode_attention(q, k_l, v_l, pos=pos)
        out = out.reshape(B, 1, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", out, blk["wo"].astype(h.dtype))
        hx = L.layer_norm(xx, blk["lnx_w"], blk["lnx_b"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dq->bsq", hx, blk["x_wq"].astype(hx.dtype))
        qx = qx.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        # cross attention over the full (static) encoder cache
        enc_len = kx_l.shape[1]
        outx = decode_attention(qx, kx_l, vx_l, pos=jnp.full((), enc_len - 1, jnp.int32))
        outx = outx.reshape(B, 1, cfg.q_dim)
        xx = xx + jnp.einsum("bsq,qd->bsd", outx, blk["x_wo"].astype(hx.dtype))
        h2 = L.layer_norm(xx, blk["ln2_w"], blk["ln2_b"], cfg.norm_eps)
        xx = xx + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
        return xx, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"]["k"], cache["self"]["v"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    x = L.layer_norm(x, params["final_norm_w"], params["final_norm_b"],
                     cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    new_cache = {"self": {"k": ks, "v": vs}, "cross": cache["cross"],
                 "pos": pos + 1}
    return logits, new_cache
