"""Mixture-of-Experts FFN with static-shape, sort-based token dispatch.

Routing is per batch row (keeps the scatter local to the ``data`` shard), with
per-row expert capacity ``C = ceil(S * top_k / E * capacity_factor)``. The
(B, E, C, d) dispatch buffer is sharded batch->data, expert->model, so the
expert einsum runs under expert parallelism and GSPMD inserts the all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import layers as L


def init_moe(key, cfg: ArchConfig, stacked):
    mo = cfg.moe
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, fe = cfg.d_model, mo.d_ff_expert
    up_w = L.mlp_up_width(fe, cfg.mlp)
    params = {
        "router": L.ninit(k1, stacked + (d, mo.n_routed), jnp.float32),
        "we_up": L.ninit(k2, stacked + (mo.n_routed, d, up_w), cfg.jdtype),
        "we_down": L.ninit(k3, stacked + (mo.n_routed, fe, d), cfg.jdtype),
    }
    if mo.n_shared:
        fs = mo.n_shared * fe
        params["ws_up"] = L.ninit(k4, stacked + (d, L.mlp_up_width(fs, cfg.mlp)), cfg.jdtype)
        params["ws_down"] = L.ninit(k5, stacked + (fs, d), cfg.jdtype)
    return params


def moe_axes(cfg: ArchConfig, stacked: bool):
    lead = (None,) if stacked else ()
    ax = {
        "router": P(*lead, None, "expert"),
        "we_up": P(*lead, "expert", None, "ffn"),
        "we_down": P(*lead, "expert", "ffn", None),
    }
    if cfg.moe.n_shared:
        ax["ws_up"] = P(*lead, None, "ffn")
        ax["ws_down"] = P(*lead, "ffn", None)
    return ax


def capacity(moe: MoEConfig, seq: int) -> int:
    return max(1, int(seq * moe.top_k / moe.n_routed * moe.capacity_factor))


def moe_ffn_shardmap(x, p, cfg: ArchConfig, ctx):
    """Explicit expert parallelism over the `model` axis via shard_map:
    dispatch is data-local, each model rank computes its E/tp experts, and
    the only collective is a psum of the (B, S, d) partial outputs —
    O(tokens·d) wire instead of O(buffer) (EXPERIMENTS.md §Perf iter 3)."""
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    mesh = ctx.mesh
    tp = ctx.axis_size("model")
    B, S, d = x.shape
    E, K = mo.n_routed, mo.top_k
    C = capacity(mo, S)
    assert E % tp == 0, (E, tp)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = P(batch_axes if len(batch_axes) > 1 else
              (batch_axes[0] if batch_axes else None))

    def local(xl, router, we_up, we_down):
        Bl = xl.shape[0]
        logits = jnp.einsum("bsd,de->bse", xl.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        vals, idx = jax.lax.top_k(probs, K)
        vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
        importance = jnp.mean(probs, axis=(0, 1))
        load = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
        aux = E * jnp.sum(importance * load)

        flat_e = idx.reshape(Bl, S * K)
        tok_of = jnp.broadcast_to(
            jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (Bl, S * K))
        order = jnp.argsort(flat_e, axis=-1)
        se = jnp.take_along_axis(flat_e, order, -1)
        st = jnp.take_along_axis(tok_of, order, -1)
        sw = jnp.take_along_axis(vals.reshape(Bl, S * K), order, -1)
        starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
        pos = jnp.arange(S * K, dtype=jnp.int32)[None] - \
            jnp.take_along_axis(starts, se, -1)
        keep = pos < C
        dest = jnp.where(keep, se * C + pos, E * C)
        brow = jnp.arange(Bl)[:, None]
        xs = jnp.take_along_axis(xl, st[..., None], axis=1)
        buf = jnp.zeros((Bl, E * C + 1, d), xl.dtype).at[brow, dest].set(xs)

        # this rank's expert block
        r = jax.lax.axis_index("model")
        epr = E // tp
        mine = jax.lax.dynamic_slice_in_dim(
            buf[:, :E * C].reshape(Bl, E, C, d), r * epr, epr, axis=1)
        h = jnp.einsum("becd,edf->becf", mine, we_up.astype(xl.dtype))
        if cfg.mlp in ("swiglu", "geglu"):
            g, u = jnp.split(h, 2, axis=-1)
            act = jax.nn.silu if cfg.mlp == "swiglu" else (
                lambda t: jax.nn.gelu(t, approximate=True))
            h = act(g.astype(jnp.float32)).astype(xl.dtype) * u
        elif cfg.mlp == "relu2":
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(xl.dtype)
        y_mine = jnp.einsum("becf,efd->becd", h, we_down.astype(xl.dtype))

        y_full = jnp.zeros((Bl, E * C + 1, d), xl.dtype)
        y_full = jax.lax.dynamic_update_slice(
            y_full, y_mine.reshape(Bl, epr * C, d), (0, r * epr * C, 0))
        gathered = jnp.take_along_axis(y_full, dest[..., None], axis=1)
        gathered = gathered * (sw * keep)[..., None].astype(xl.dtype)
        out = jnp.zeros((Bl, S, d), xl.dtype).at[brow, st].add(gathered)
        out = jax.lax.psum(out, "model")
        return out, aux[None]

    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(), P("model"), P("model")),
        out_specs=(bspec, bspec if batch_axes else P()),
        check_vma=False,
    )(x, p["router"].astype(jnp.float32), p["we_up"], p["we_down"])
    aux = jnp.mean(aux)
    if mo.n_shared:
        out = out + L.mlp_apply(x, p["ws_up"], p["ws_down"], cfg.mlp)
    return out, aux


def moe_ffn(x, p, cfg: ArchConfig, ctx=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if (ctx is not None and ctx.mesh is not None
            and cfg.moe.dispatch == "shard_map"
            and cfg.moe.n_routed % max(ctx.axis_size("model"), 1) == 0
            and x.shape[0] % (ctx.axis_size("pod") * ctx.axis_size("data")) == 0):
        return moe_ffn_shardmap(x, p, cfg, ctx)
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.n_routed, mo.top_k
    C = capacity(mo, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, K)                       # (B, S, K)
    vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)

    # load-balance aux (switch-style): E * sum_e importance_e * load_e
    importance = jnp.mean(probs, axis=(0, 1))                 # (E,)
    load = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(importance * load)

    # ---- sort-based dispatch (static shapes, per-row) ----
    flat_e = idx.reshape(B, S * K)
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (B, S * K))
    order = jnp.argsort(flat_e, axis=-1)
    se = jnp.take_along_axis(flat_e, order, -1)               # sorted experts
    st = jnp.take_along_axis(tok_of, order, -1)               # their tokens
    sw = jnp.take_along_axis(vals.reshape(B, S * K), order, -1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    pos = jnp.arange(S * K, dtype=jnp.int32)[None] - jnp.take_along_axis(starts, se, -1)
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)               # E*C = drop slot

    brow = jnp.arange(B)[:, None]
    xs = jnp.take_along_axis(x, st[..., None], axis=1)        # (B, S*K, d)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[brow, dest].set(xs)
    buf = buf[:, :E * C].reshape(B, E, C, d)
    if ctx is not None:
        if mo.dispatch == "local":
            # data-local scatter; model ranks slice their experts from the
            # replicated buffer inside the einsum (no dispatch collective)
            buf = ctx.constrain(buf, "batch", None, None, None)
        else:
            buf = ctx.constrain(buf, "batch", "expert", None, None)

    h = jnp.einsum("becd,edf->becf", buf, p["we_up"].astype(x.dtype))
    if cfg.mlp in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu if cfg.mlp == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    y = jnp.einsum("becf,efd->becd", h, p["we_down"].astype(x.dtype))
    if ctx is not None:
        y = ctx.constrain(y, "batch", "expert", None, None)

    y = jnp.concatenate(
        [y.reshape(B, E * C, d), jnp.zeros((B, 1, d), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(y, dest[..., None], axis=1)  # (B, S*K, d)
    gathered = gathered * (sw * keep)[..., None].astype(x.dtype)
    out = jnp.zeros((B, S, d), x.dtype).at[brow, st].add(gathered)
    if ctx is not None and mo.dispatch == "local":
        # combine stays in the expert-sharded domain; the psum lands on the
        # small (B, S, d) output, not the (B, E*C, d) buffer
        out = ctx.constrain(out, "batch", None, None)

    if mo.n_shared:
        out = out + L.mlp_apply(x, p["ws_up"], p["ws_down"], cfg.mlp)
    return out, aux
