"""Shared model building blocks (pure JAX, functional, scan-friendly)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------- init utils

def ninit(key, shape, dtype, scale=None):
    """Truncated-normal init with 1/sqrt(fan_in) default scale."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def zinit(shape, dtype):
    return jnp.zeros(shape, dtype)


def oinit(shape, dtype):
    return jnp.ones(shape, dtype)


# ------------------------------------------------------------------- norms

def rms_norm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- MLP

def mlp_apply(x, w_up, w_down, kind: str, b_up=None, b_down=None):
    """w_up: (d, 2f) for gated kinds, (d, f) otherwise. w_down: (f, d)."""
    h = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    if b_up is not None:
        h = h + b_up.astype(x.dtype)
    if kind == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif kind == "geglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype) * u
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise ValueError(kind)
    out = jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))
    if b_down is not None:
        out = out + b_down.astype(x.dtype)
    return out


def mlp_up_width(d_ff: int, kind: str) -> int:
    return 2 * d_ff if kind in ("swiglu", "geglu") else d_ff


def init_mlp(key, d_model, d_ff, kind, dtype, stacked=()):
    k1, k2 = jax.random.split(key)
    up = stacked + (d_model, mlp_up_width(d_ff, kind))
    down = stacked + (d_ff, d_model)
    return {"w_up": ninit(k1, up, dtype), "w_down": ninit(k2, down, dtype)}


def mlp_axes(stacked: bool):
    lead = (None,) if stacked else ()
    return {"w_up": P(*lead, None, "ffn"), "w_down": P(*lead, "ffn", None)}


# ---------------------------------------------------------------- embedding

def embed_lookup(embed, tokens):
    # one_hot-free gather; GSPMD partitions vocab-sharded gathers natively.
    return jnp.take(embed, tokens, axis=0)


# -------------------------------------------------------------------- loss

def softmax_xent(logits, labels, mask=None, z_loss: float = 1e-4):
    """logits (..., V) fp32-accumulated xent with optional z-loss and mask."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------------ misc helpers

def causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B, S, C), w: (K, C). state: (B, K-1, C) or None.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (K - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=-2)          # (B, S+K-1, C)
    # y[t] = sum_k w[k] * xp[t+k]
    segs = [xp[..., k:k + x.shape[-2], :] * w[k].astype(x.dtype) for k in range(K)]
    y = sum(segs)
    new_state = xp[..., -(K - 1):, :] if K > 1 else pad
    return y, new_state
