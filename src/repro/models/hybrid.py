"""Hymba-style hybrid blocks: attention heads and mamba-style selective-SSM
heads run in PARALLEL on the same input; outputs are per-branch normalized
and averaged. 128 learnable meta tokens are prepended; sliding-window
attention everywhere except global layers {0, mid, last}.

The selective scan uses ``jax.lax.associative_scan`` (the oracle for the
``repro.kernels.ssm_scan`` Pallas kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.transformer import _constrain_qkv, chunked_xent

BIG_WINDOW = 1 << 30


def layer_windows(cfg: ArchConfig):
    """Per-layer attention window: global (huge) for layers {0, every k-th,
    last}; cfg.sliding_window otherwise."""
    ws = []
    for l in range(cfg.n_layers):
        is_global = (l == 0 or l == cfg.n_layers - 1 or
                     (cfg.global_every and l % cfg.global_every == 0))
        ws.append(BIG_WINDOW if is_global else cfg.sliding_window)
    return jnp.asarray(ws, jnp.int32)


def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 16)
    d, dt = cfg.d_model, cfg.jdtype
    Lr, di, N, R = cfg.n_layers, cfg.ssm.d_inner, cfg.ssm.state_dim, cfg.ssm.dt_rank
    K = cfg.ssm.conv_width
    layers = {
        "ln1": L.oinit((Lr, d), dt),
        "wq": L.ninit(ks[0], (Lr, d, cfg.q_dim), dt),
        "wk": L.ninit(ks[1], (Lr, d, cfg.kv_dim), dt),
        "wv": L.ninit(ks[2], (Lr, d, cfg.kv_dim), dt),
        "wo_attn": L.ninit(ks[3], (Lr, cfg.q_dim, d), dt),
        "w_in": L.ninit(ks[4], (Lr, d, 2 * di), dt),
        "conv_w": L.ninit(ks[5], (Lr, K, di), dt, scale=K ** -0.5),
        "w_bc": L.ninit(ks[6], (Lr, di, 2 * N), dt),
        "w_dt1": L.ninit(ks[7], (Lr, di, R), dt),
        "w_dt2": L.ninit(ks[8], (Lr, R, di), jnp.float32),
        "b_dt": L.zinit((Lr, di), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (Lr, di, N))),
        "Dskip": L.oinit((Lr, di), jnp.float32),
        "wo_ssm": L.ninit(ks[9], (Lr, di, d), dt),
        "ng_attn": L.oinit((Lr, d), dt),
        "ng_ssm": L.oinit((Lr, d), dt),
        "ln2": L.oinit((Lr, d), dt),
    }
    layers.update(L.init_mlp(ks[10], d, cfg.d_ff, cfg.mlp, dt, stacked=(Lr,)))
    return {
        "embed": L.ninit(ks[11], (cfg.vocab, d), dt, scale=1.0),
        "meta": L.ninit(ks[12], (cfg.n_meta_tokens, d), dt, scale=0.02),
        "layers": layers,
        "final_norm": L.oinit((d,), dt),
        "lm_head": L.ninit(ks[13], (d, cfg.vocab), dt),
    }


def param_axes(cfg: ArchConfig):
    n = (None,)
    layers = {
        "ln1": P(None, None),
        "wq": P(None, None, "qdim"),
        "wk": P(None, None, "kvdim"),
        "wv": P(None, None, "kvdim"),
        "wo_attn": P(None, "qdim", None),
        "w_in": P(None, None, "inner"),
        "conv_w": P(None, None, "inner"),
        "w_bc": P(None, "inner", None),
        "w_dt1": P(None, "inner", None),
        "w_dt2": P(None, None, "inner"),
        "b_dt": P(None, "inner"),
        "A_log": P(None, "inner", None),
        "Dskip": P(None, "inner"),
        "wo_ssm": P(None, "inner", None),
        "ng_attn": P(None, None),
        "ng_ssm": P(None, None),
        "ln2": P(None, None),
        "w_up": P(None, None, "ffn"),
        "w_down": P(None, "ffn", None),
    }
    return {
        "embed": P("vocab", None),
        "meta": P(None, None),
        "layers": layers,
        "final_norm": P(None),
        "lm_head": P(None, "vocab"),
    }


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(init, cfg=cfg), jax.random.PRNGKey(0))


# ------------------------------------------------------------ selective SSM

def ssm_scan(u, dt, A, Bsel, Csel, Dskip, h0=None):
    """u, dt: (B,S,di); A: (di,N); Bsel,Csel: (B,S,N). Associative scan.
    Returns (y (B,S,di), h_last (B,di,N))."""
    Ad = jnp.exp(dt[..., None] * A)                          # (B,S,di,N)
    Bx = (dt * u)[..., None] * Bsel[:, :, None, :]           # (B,S,di,N)
    if h0 is not None:
        # fold initial state into step 0: h1 = Ad1*h0 + Bx1
        Bx = Bx.at[:, 0].add(Ad[:, 0] * h0)
    a, b = jax.lax.associative_scan(
        lambda l, r: (r[0] * l[0], r[0] * l[1] + r[1]), (Ad, Bx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", b, Csel) + Dskip * u
    return y, b[:, -1]


def ssm_step(u, dt, A, Bsel, Csel, Dskip, h):
    """Single decode step. u, dt: (B,di); Bsel,Csel: (B,N); h: (B,di,N)."""
    Ad = jnp.exp(dt[..., None] * A)
    h = Ad * h + (dt * u)[..., None] * Bsel[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Csel) + Dskip * u
    return y, h


def _ssm_branch(h, blk, cfg: ArchConfig, ctx, state=None):
    """h: (B,S,d) -> (out (B,S,d), (h_ssm, conv_state))."""
    B, S, _ = h.shape
    di, N = cfg.ssm.d_inner, cfg.ssm.state_dim
    u = jnp.einsum("bsd,de->bse", h, blk["w_in"].astype(h.dtype))
    xs, zg = jnp.split(u, 2, axis=-1)
    conv_state = None if state is None else state[1]
    xc, new_conv = L.causal_conv1d(xs, blk["conv_w"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32))
    if ctx is not None:
        xc = ctx.constrain(xc, "batch", None, "inner")
    dt = jax.nn.softplus(
        (xc @ blk["w_dt1"].astype(jnp.float32)) @ blk["w_dt2"] + blk["b_dt"])
    bc = xc @ blk["w_bc"].astype(jnp.float32)
    Bsel, Csel = jnp.split(bc, 2, axis=-1)
    A = -jnp.exp(blk["A_log"])
    h0 = None if state is None else state[0]
    if S == 1 and state is not None:
        y, h_new = ssm_step(xc[:, 0], dt[:, 0], A, Bsel[:, 0], Csel[:, 0],
                            blk["Dskip"], h0)
        y = y[:, None]
    else:
        y, h_new = ssm_scan(xc, dt, A, Bsel, Csel, blk["Dskip"], h0)
    y = y.astype(h.dtype) * jax.nn.silu(zg.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bse,ed->bsd", y, blk["wo_ssm"].astype(h.dtype))
    return out, (h_new, new_conv)


# ----------------------------------------------------------------- forward

def _block(x, blk, window, cfg: ArchConfig, ctx, positions):
    B, S, d = x.shape
    h = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
    # attention branch
    q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dq->bsq", h, blk["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, blk["wv"].astype(h.dtype))
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q, k, v = _constrain_qkv(ctx, cfg, q, k, v)
    ao = blockwise_attention(q, k, v, causal=True, window=window,
                             q_positions=positions, kv_positions=positions)
    ao = jnp.einsum("bsq,qd->bsd", ao.reshape(B, S, cfg.q_dim),
                    blk["wo_attn"].astype(h.dtype))
    # ssm branch (parallel, same input)
    so, ssm_state = _ssm_branch(h, blk, cfg, ctx)
    y = 0.5 * (L.rms_norm(ao, blk["ng_attn"], cfg.norm_eps) +
               L.rms_norm(so, blk["ng_ssm"], cfg.norm_eps))
    x = x + y
    h2 = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    return x, (k, v, ssm_state)


def _prepend_meta(params, x, ctx, cfg):
    B = x.shape[0]
    meta = jnp.broadcast_to(params["meta"].astype(x.dtype)[None],
                            (B,) + params["meta"].shape)
    x = jnp.concatenate([meta, x], axis=1)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq_tp", None)
    return x


def train_loss(params, batch, cfg: ArchConfig, ctx=None, remat=True):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    x = _prepend_meta(params, x, ctx, cfg)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]
    windows = layer_windows(cfg)

    body = functools.partial(_block, cfg=cfg, ctx=ctx, positions=positions)
    if remat:
        body = jax.checkpoint(body)

    def step(xx, xs):
        blk, w = xs
        xx, _ = body(xx, blk, w)
        return xx, None

    x, _ = jax.lax.scan(step, x, (params["layers"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    labels = jnp.pad(batch["labels"], ((0, 0), (cfg.n_meta_tokens, 0)))
    mask = jnp.pad(batch["mask"], ((0, 0), (cfg.n_meta_tokens, 0)))
    s_nll, s_mask = chunked_xent(x, params["lm_head"], labels, mask, ctx)
    return s_nll / jnp.maximum(s_mask, 1.0)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, ring: bool = False):
    """KV cache (ring-bounded for long contexts) + SSM/conv recurrent state."""
    slots = max_len + cfg.n_meta_tokens
    if ring and cfg.sliding_window:
        slots = min(slots, cfg.sliding_window)
    Lr, di, N, K = (cfg.n_layers, cfg.ssm.d_inner, cfg.ssm.state_dim,
                    cfg.ssm.conv_width)
    z = jnp.zeros
    return {
        "k": z((Lr, batch, slots, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        "v": z((Lr, batch, slots, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        "ssm": z((Lr, batch, di, N), jnp.float32),
        "conv": z((Lr, batch, K - 1, di), cfg.jdtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg: ArchConfig, ctx=None, frontend=None):
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(cfg.jdtype)
    x = _prepend_meta(params, x, ctx, cfg)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]
    windows = layer_windows(cfg)

    def step(xx, xs):
        blk, w = xs
        xx, (k, v, ssm_state) = _block(xx, blk, w, cfg, ctx, positions)
        return xx, (k, v, ssm_state)

    x, (ks, vs, sst) = jax.lax.scan(step, x, (params["layers"], windows))
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    cache = {"k": ks, "v": vs, "ssm": sst[0], "conv": sst[1],
             "pos": jnp.full((), St, jnp.int32)}
    return logits, cache


def decode_step(params, token, cache, cfg: ArchConfig, ctx=None):
    B = token.shape[0]
    pos = cache["pos"]          # absolute position incl. meta offset
    x = L.embed_lookup(params["embed"], token[:, 0])[:, None, :].astype(cfg.jdtype)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    windows = layer_windows(cfg)
    slots = cache["k"].shape[2]
    slot = pos % slots

    def step(carry, xs):
        xx = carry
        blk, w, k_l, v_l, ssm_l, conv_l = xs
        h = L.rms_norm(xx, blk["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", h, blk["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dq->bsq", h, blk["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dq->bsq", h, blk["wv"].astype(h.dtype))
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        k_l = jax.lax.dynamic_update_slice(k_l, k, (0, slot, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v, (0, slot, 0, 0))
        slot_ids = jnp.arange(slots, dtype=jnp.int32)[None, :]
        wraps = (pos // slots) * slots
        abs_pos = jnp.where(slot_ids <= slot, wraps + slot_ids,
                            wraps - slots + slot_ids)
        kv_pos = jnp.where(abs_pos >= 0, abs_pos, jnp.iinfo(jnp.int32).max)
        ao = decode_attention(q, k_l, v_l, pos=pos, window=w, kv_positions=kv_pos)
        ao = jnp.einsum("bsq,qd->bsd", ao.reshape(B, 1, cfg.q_dim),
                        blk["wo_attn"].astype(h.dtype))
        so, (ssm_new, conv_new) = _ssm_branch(h, blk, cfg, ctx,
                                              state=(ssm_l, conv_l))
        y = 0.5 * (L.rms_norm(ao, blk["ng_attn"], cfg.norm_eps) +
                   L.rms_norm(so, blk["ng_ssm"], cfg.norm_eps))
        xx = xx + y
        h2 = L.rms_norm(xx, blk["ln2"], cfg.norm_eps)
        xx = xx + L.mlp_apply(h2, blk["w_up"], blk["w_down"], cfg.mlp)
        return xx, (k_l, v_l, ssm_new, conv_new)

    x, (ks, vs, sst, cst) = jax.lax.scan(
        step, x, (params["layers"], windows, cache["k"], cache["v"],
                  cache["ssm"], cache["conv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    return logits, {"k": ks, "v": vs, "ssm": sst, "conv": cst, "pos": pos + 1}
