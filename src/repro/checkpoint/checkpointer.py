"""Checkpoint/restore for param + optimizer + data-iterator pytrees.

Fault-tolerance substrate: atomic *and durable* writes (tmp + fsync +
rename + directory fsync), per-leaf checksum manifests verified on
restore, fall-back to the last good generation when the newest one is
truncated or corrupted, bounded retry-with-backoff on transient I/O
errors, retention, restore onto a DIFFERENT mesh/sharding
(topology-change resharding via device_put with the new shardings —
elastic scaling and node-failure recovery both go through this path),
and async save (background thread over host copies) so the training
loop does not stall on I/O.

Durability contract (exercised by tests/test_chaos.py):
  - ``save`` fsyncs every file *and* the containing directories around
    the tmp -> final rename, so a host crash after ``save`` returns can
    not lose or tear the generation;
  - ``meta.json`` carries a crc32 per leaf; ``restore``/``load_tree``
    recompute and compare before handing data back;
  - a generation that fails verification (truncated npz, flipped bytes,
    missing/unparseable meta) raises ``CheckpointCorruptError`` when
    requested explicitly, and is *skipped* when the caller asked for
    "the latest good one" — recovery proceeds from the previous
    generation, mirroring what a restarted trainer must do.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
import zlib
from typing import Callable, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A specific checkpoint generation failed integrity verification."""


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_native(a: np.ndarray) -> np.ndarray:
    """bf16/fp8 etc. don't survive npz round-trips; store as uint views."""
    if a.dtype.kind in "fiub":
        return a
    return a.view(_UINT_FOR_SIZE[a.dtype.itemsize])


def _from_native(h: np.ndarray, target_dtype) -> np.ndarray:
    td = np.dtype(target_dtype)
    if h.dtype == td:
        return h
    if h.dtype.kind == "u" and h.dtype.itemsize == td.itemsize \
            and td.kind not in "fiub":
        return h.view(td)
    return h.astype(td)


def _leaf_crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF


def _fsync_path(path: pathlib.Path):
    """fsync a file's contents (or a directory's entry table)."""
    flags = os.O_RDONLY | (os.O_DIRECTORY if path.is_dir() else 0)
    fd = os.open(path, flags)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def with_retry(fn: Callable, *, retries: int = 0, backoff: float = 0.05,
               timeout: Optional[float] = None,
               sleep: Callable[[float], None] = time.sleep):
    """Run ``fn`` with bounded retry + exponential backoff + wall timeout.

    Used by save/restore callers on flaky filesystems (the JIRIAF
    steady state): ``retries`` extra attempts, delay doubling from
    ``backoff``, and a hard ``timeout`` on the whole loop so a wedged
    mount can't stall a drain past the node's walltime."""
    deadline = None if timeout is None else time.monotonic() + timeout
    attempt = 0
    while True:
        try:
            return fn()
        except (OSError, CheckpointCorruptError):
            attempt += 1
            if attempt > retries:
                raise
            if deadline is not None and time.monotonic() >= deadline:
                raise
            sleep(backoff * (2 ** (attempt - 1)))


def save(ckpt_dir, step: int, tree, *, meta: Optional[dict] = None,
         keep: int = 3, retries: int = 0, retry_backoff: float = 0.05,
         timeout: Optional[float] = None):
    """Synchronous atomic + durable checkpoint (see module docstring)."""
    return with_retry(
        lambda: _save_once(ckpt_dir, step, tree, meta=meta, keep=keep),
        retries=retries, backoff=retry_backoff, timeout=timeout)


def _save_once(ckpt_dir, step: int, tree, *, meta: Optional[dict] = None,
               keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp-{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    host = [_to_native(np.asarray(l)) for l in leaves]
    np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(host)})
    manifest = {
        "step": step, "n_leaves": len(host), "treedef": str(treedef),
        "time": time.time(),
        # per-leaf integrity manifest, recomputed + compared on restore
        "checksums": [[_leaf_crc(a), str(a.dtype), list(a.shape)]
                      for a in host],
        **(meta or {})}
    if isinstance(tree, dict) and all(
            not isinstance(v, dict) for v in tree.values()):
        # flat dict trees (the drain-loop pod snapshots) record their key
        # order so load_tree can rebuild them with no abstract tree in
        # hand — the crash path restores from disk alone
        manifest["tree_keys"] = sorted(tree.keys())
    (tmp / "meta.json").write_text(json.dumps(manifest))
    # durability: flush file contents, then the tmp dir entries, *then*
    # rename, then the parent so the new name itself is on disk
    _fsync_path(tmp / "leaves.npz")
    _fsync_path(tmp / "meta.json")
    _fsync_path(tmp)
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_path(ckpt_dir)
    _retain(ckpt_dir, keep)
    return final


def save_async(ckpt_dir, step, tree, *, meta=None, keep: int = 3):
    """Copy to host synchronously (cheap), write in a background thread."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(l) for l in leaves]   # device->host copy happens here
    rebuilt = jax.tree.unflatten(treedef, host)
    t = threading.Thread(target=save, args=(ckpt_dir, step, rebuilt),
                         kwargs={"meta": meta, "keep": keep}, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir, keep):
    steps = sorted(pathlib.Path(ckpt_dir).glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = sorted(pathlib.Path(ckpt_dir).glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def _load_verified(d: pathlib.Path):
    """Load one generation's leaves + meta, verifying the manifest.

    Raises CheckpointCorruptError on truncation, bit flips, or missing
    pieces. Generations written before the manifest existed (no
    ``checksums`` key) are accepted as-is."""
    try:
        meta = json.loads((d / "meta.json").read_text())
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(f"{d}: unreadable meta.json: {e}")
    try:
        with np.load(d / "leaves.npz") as data:
            host = [data[f"l{i}"] for i in range(int(meta["n_leaves"]))]
    except Exception as e:  # zipfile.BadZipFile, KeyError, OSError, ...
        raise CheckpointCorruptError(f"{d}: unreadable leaves.npz: {e}")
    sums = meta.get("checksums")
    if sums is not None:
        if len(sums) != len(host):
            raise CheckpointCorruptError(
                f"{d}: manifest lists {len(sums)} leaves, found {len(host)}")
        for i, (h, (crc, dt, shape)) in enumerate(zip(host, sums)):
            if list(h.shape) != list(shape) or str(h.dtype) != dt \
                    or _leaf_crc(h) != crc:
                raise CheckpointCorruptError(
                    f"{d}: leaf l{i} failed checksum/shape verification")
    return host, meta


def verify_step(ckpt_dir, step: int) -> bool:
    """True iff generation ``step`` exists and passes verification."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    if not d.exists():
        return False
    try:
        _load_verified(d)
        return True
    except CheckpointCorruptError:
        return False


def latest_good_step(ckpt_dir) -> Optional[int]:
    """Newest generation that passes integrity verification (or None)."""
    for d in sorted(pathlib.Path(ckpt_dir).glob("step_*"), reverse=True):
        step = int(d.name.split("_")[1])
        if verify_step(ckpt_dir, step):
            return step
    return None


def _pick_step(ckpt_dir: pathlib.Path, step: Optional[int], verify: bool):
    """Resolve which generation to read; with step=None and verify on,
    corrupt generations are skipped (fall back to the last good one)."""
    if step is not None:
        return step
    picked = latest_good_step(ckpt_dir) if verify else latest_step(ckpt_dir)
    if picked is None:
        raise FileNotFoundError(f"no usable checkpoints under {ckpt_dir}")
    return picked


def load_tree(ckpt_dir, *, step: Optional[int] = None, verify: bool = True):
    """Restore a flat-dict checkpoint with no abstract tree in hand.

    The crash-recovery path: a node died without a graceful drain, so
    nothing live can describe the tree — the manifest's ``tree_keys``
    rebuild it from disk alone. Returns ``(dict, meta)``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = _pick_step(ckpt_dir, step, verify)
    d = ckpt_dir / f"step_{step:08d}"
    host, meta = _load_verified(d)
    keys = meta.get("tree_keys")
    if keys is None or len(keys) != len(host):
        raise CheckpointCorruptError(
            f"{d}: no tree_keys manifest; need an abstract tree (restore())")
    return dict(zip(keys, host)), meta


def restore(ckpt_dir, abstract_tree, *, step: Optional[int] = None,
            shardings=None, verify: bool = True, retries: int = 0,
            retry_backoff: float = 0.05, timeout: Optional[float] = None):
    """Restore into the structure of ``abstract_tree``; if ``shardings`` is
    given the leaves are placed with those shardings (which may correspond
    to a completely different mesh than the one that saved — ZeRO/elastic
    reshard on restore). With ``verify`` (default) every leaf is checked
    against the saved manifest; when ``step`` is None a corrupt newest
    generation falls back to the last good one."""
    return with_retry(
        lambda: _restore_once(ckpt_dir, abstract_tree, step=step,
                              shardings=shardings, verify=verify),
        retries=retries, backoff=retry_backoff, timeout=timeout)


def _restore_once(ckpt_dir, abstract_tree, *, step=None, shardings=None,
                  verify=True):
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = _pick_step(ckpt_dir, step, verify)
    d = ckpt_dir / f"step_{step:08d}"
    if verify:
        host, meta = _load_verified(d)
    else:
        data = np.load(d / "leaves.npz")
        meta = json.loads((d / "meta.json").read_text())
        host = [data[f"l{i}"] for i in range(int(meta["n_leaves"]))]
    leaves, treedef = jax.tree.flatten(abstract_tree)
    if len(host) != len(leaves):
        raise ValueError(
            f"leaf count mismatch: {len(host)} saved vs {len(leaves)}")
    for h, a in zip(host, leaves):
        if tuple(h.shape) != tuple(a.shape):
            raise ValueError(f"shape mismatch {h.shape} vs {a.shape}")
    host = [_from_native(h, a.dtype) for h, a in zip(host, leaves)]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
        out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        out = [jax.numpy.asarray(h) for h in host]
    return jax.tree.unflatten(treedef, out), meta
