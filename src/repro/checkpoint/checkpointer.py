"""Checkpoint/restore for param + optimizer + data-iterator pytrees.

Fault-tolerance substrate: atomic writes (tmp + rename), retention, restore
onto a DIFFERENT mesh/sharding (topology-change resharding via device_put
with the new shardings — elastic scaling and node-failure recovery both go
through this path), and async save (background thread over host copies) so
the training loop does not stall on I/O."""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_native(a: np.ndarray) -> np.ndarray:
    """bf16/fp8 etc. don't survive npz round-trips; store as uint views."""
    if a.dtype.kind in "fiub":
        return a
    return a.view(_UINT_FOR_SIZE[a.dtype.itemsize])


def _from_native(h: np.ndarray, target_dtype) -> np.ndarray:
    td = np.dtype(target_dtype)
    if h.dtype == td:
        return h
    if h.dtype.kind == "u" and h.dtype.itemsize == td.itemsize \
            and td.kind not in "fiub":
        return h.view(td)
    return h.astype(td)


def save(ckpt_dir, step: int, tree, *, meta: Optional[dict] = None,
         keep: int = 3):
    """Synchronous atomic checkpoint."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp-{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    host = [_to_native(np.asarray(l)) for l in leaves]
    np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(host)})
    (tmp / "meta.json").write_text(json.dumps({
        "step": step, "n_leaves": len(host), "treedef": str(treedef),
        "time": time.time(), **(meta or {})}))
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def save_async(ckpt_dir, step, tree, *, meta=None, keep: int = 3):
    """Copy to host synchronously (cheap), write in a background thread."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(l) for l in leaves]   # device->host copy happens here
    rebuilt = jax.tree.unflatten(treedef, host)
    t = threading.Thread(target=save, args=(ckpt_dir, step, rebuilt),
                         kwargs={"meta": meta, "keep": keep}, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir, keep):
    steps = sorted(pathlib.Path(ckpt_dir).glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = sorted(pathlib.Path(ckpt_dir).glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(ckpt_dir, abstract_tree, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``abstract_tree``; if ``shardings`` is
    given the leaves are placed with those shardings (which may correspond
    to a completely different mesh than the one that saved — ZeRO/elastic
    reshard on restore)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "leaves.npz")
    leaves, treedef = jax.tree.flatten(abstract_tree)
    host = [data[f"l{i}"] for i in range(len(leaves))]
    for h, a in zip(host, leaves):
        if tuple(h.shape) != tuple(a.shape):
            raise ValueError(f"shape mismatch {h.shape} vs {a.shape}")
    host = [_from_native(h, a.dtype) for h, a in zip(host, leaves)]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
        out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        out = [jax.numpy.asarray(h) for h in host]
    meta = json.loads((d / "meta.json").read_text())
    return jax.tree.unflatten(treedef, out), meta
