"""Roofline extraction from compiled dry-run artifacts.

Three terms (seconds, per device):
  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum over collective ops of ring-model bytes / ICI_BW

cost_analysis() on an SPMD-partitioned executable reports PER-DEVICE flops
and bytes (verified empirically). Collective bytes are parsed from the
partitioned HLO text (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, sync and -start async forms).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# --- TPU v5e-class hardware constants (per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, default_group: int = 1):
    """Returns {op: {count, result_bytes, wire_bytes}} per device (ring model)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rbytes = _bytes_of_type(m.group("rtype"))
        g = _group_size(line, default_group)
        if g <= 1:
            wire = 0
        elif op == "all-gather":
            wire = rbytes * (g - 1) // g
        elif op == "reduce-scatter":
            wire = rbytes * (g - 1)
        elif op == "all-reduce":
            wire = 2 * rbytes * (g - 1) // g
        elif op == "all-to-all":
            wire = rbytes * (g - 1) // g
        else:  # collective-permute
            wire = rbytes
        rec = out.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        rec["count"] += 1
        rec["result_bytes"] += rbytes
        rec["wire_bytes"] += wire
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float

    @property
    def compute_s(self):
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes_per_device / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    def asdict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def from_compiled(compiled) -> dict:
    """Extract per-device roofline + memory record from a compiled executable.

    Roofline terms use LOOP-CORRECTED counts (repro.roofline.hlo_graph):
    cost_analysis() counts while bodies once, so scanned layers / microbatch
    loops would otherwise be undercounted by their trip counts. The raw
    cost_analysis numbers are recorded alongside for reference.
    """
    from repro.roofline import hlo_graph

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    la = hlo_graph.analyze(hlo)
    rl = Roofline(la.dot_flops, la.traffic_bytes, la.wire_bytes)
    mem = compiled.memory_analysis()
    return {
        "roofline": rl.asdict(),
        "collectives": la.collectives,
        "while_trips": la.while_trips,
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_bytes": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        },
    }


def model_flops(cfg, shape, n_active_params: int) -> float:
    """Standard 6*N*D (active params) model-FLOPs estimate for the cell."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    return 2.0 * n_active_params * shape.global_batch  # decode: one token
