"""Hillclimb profiler: top traffic / collective contributors of a cell's
partitioned HLO, loop-multiplied. This is the 'profile' of the dry-run
methodology (no wall-clock on CPU): what to look at before forming a
hypothesis.

Usage (own process — forces 512 devices):
  PYTHONPATH=src python -m repro.roofline.diagnose --arch yi-34b \
      --shape decode_32k [--multipod] [--override unroll=True] [--top 20]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re

import jax

from repro.roofline import hlo_graph as H


def walk_items(hlo: str):
    comps = H.parse_computations(hlo)
    m = re.search(r"ENTRY\s+%?([\w.\-_]+)", hlo)
    entry = m.group(1) if m else list(comps)[-1]
    traffic, colls = [], []

    def walk(comp_name, mult, stack=()):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for inst in comp.instrs:
            op = inst.op
            base = op.replace("-start", "")
            if base in H.COLLECTIVES:
                rb = H._shape_elems_bytes(inst.type_str)
                g = H._group_size(inst.rest, 1)
                colls.append((mult * H._wire_bytes(base, rb, g), mult, base,
                              inst.name, comp_name))
            if op == "while":
                mb = re.search(r"body=%?([\w.\-_]+)", inst.rest)
                mc = re.search(r"condition=%?([\w.\-_]+)", inst.rest)
                trips = H._trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    walk(mb.group(1), mult * trips, stack + (comp_name,))
                continue
            if op in H._SKIP_TRAFFIC:
                continue
            if op == "fusion" and inst.called:
                sub = comps.get(inst.called[0])
                dus_out = H._dus_root_result_bytes(sub) if sub else None
                t = dus_out if dus_out is not None else \
                    H._shape_elems_bytes(inst.type_str)
                ops_ = H._OPERAND.findall(inst.rest.split(" calls=")[0])
                sliced = H._sliced_params(sub) if sub else {}
                for idx, opnd in enumerate(ops_):
                    if opnd in comp.types:
                        t += sliced.get(idx,
                                        H._shape_elems_bytes(comp.types[opnd]))
                traffic.append((mult * t, mult, op, inst.name, comp_name))
            elif op in ("dynamic-slice", "slice", "gather"):
                traffic.append((mult * 2 * H._shape_elems_bytes(inst.type_str),
                                mult, op, inst.name, comp_name))
            elif op == "dynamic-update-slice":
                ops_ = H._OPERAND.findall(inst.rest)
                upd = (H._shape_elems_bytes(comp.types[ops_[1]])
                       if len(ops_) > 1 and ops_[1] in comp.types else 0)
                traffic.append((mult * 2 * upd, mult, op, inst.name, comp_name))
            else:
                t = H._shape_elems_bytes(inst.type_str)
                for opnd in H._OPERAND.findall(inst.rest):
                    if opnd in comp.types:
                        t += H._shape_elems_bytes(comp.types[opnd])
                traffic.append((mult * t, mult, op, inst.name, comp_name))
            if op in ("call", "conditional") and inst.called:
                for c in inst.called:
                    walk(c, mult, stack + (comp_name,))

    walk(entry, 1.0)
    return traffic, colls


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args()

    from repro.configs.base import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_cell

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=")
        overrides[k] = eval(v)  # noqa: S307 - CLI convenience
    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multipod)
    cell = make_cell(cfg, SHAPES[args.shape], mesh, **overrides)
    from repro.launch.mesh import set_mesh
    with set_mesh(mesh):
        hlo = cell.lower().compile().as_text()
    traffic, colls = walk_items(hlo)
    traffic.sort(reverse=True)
    colls.sort(reverse=True)
    tt = sum(t[0] for t in traffic)
    tc = sum(c[0] for c in colls)
    print(f"== traffic {tt / 1e9:.1f} GB/dev (mem term "
          f"{tt / H.__dict__.get('HBM', 819e9):.3f}s) — top {args.top} ==")
    for t, mult, op, name, comp in traffic[:args.top]:
        print(f"  {t / 1e9:9.2f} GB x{mult:6.0f} {op:22s} {name[:48]} "
              f"[{comp[:28]}]")
    print(f"== collectives {tc / 1e9:.1f} GB wire/dev "
          f"({tc / 50e9:.3f}s) — top {args.top} ==")
    for t, mult, op, name, comp in colls[:args.top]:
        print(f"  {t / 1e9:9.2f} GB x{mult:6.0f} {op:22s} {name[:48]} "
              f"[{comp[:28]}]")


if __name__ == "__main__":
    main()
