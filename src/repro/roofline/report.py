"""Render the §Roofline table from experiments/dryrun JSON records.

Per (arch x shape) on the single-pod mesh: the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS (6ND train / 2ND prefill-decode, active
params for MoE), useful-FLOPs ratio, and a one-line "what would move the
dominant term" note.

Usage: PYTHONPATH=src python -m repro.roofline.report [--mesh pod] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

BASE = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SKIPPED_LONG = ["whisper-medium", "qwen2-7b", "yi-34b", "granite-20b",
                "minitron-8b", "deepseek-moe-16b", "paligemma-3b"]

NOTES = {
    ("compute", "train"): "cut remat recompute / larger microbatch",
    ("compute", "prefill"): "fused flash kernel; fewer replicated attn flops",
    ("compute", "decode"): "batch more tokens per step (decode is tiny)",
    ("memory", "train"): "fuse attention (Pallas) to kill score traffic; "
                         "keep weights resident across microbatches",
    ("memory", "prefill"): "flash fusion removes O(S*bk) intermediate traffic",
    ("memory", "decode"): "KV cache read dominates: quantize cache / GQA-pack",
    ("collective", "train"): "overlap grad RS/AG with backward; shard-stationary layout",
    ("collective", "prefill"): "avoid per-layer KV all-gather (scheme-A heads or CP)",
    ("collective", "decode"): "keep decode activations replicated; batch AR of stats",
}


def load(mesh: str):
    rows = []
    d = BASE / mesh
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if "__" in f.stem and r.get("tag"):
            continue  # tagged experiment variants, not baseline
        rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def fmt_row(r):
    rl = r["roofline"]
    kind = ("train" if r["shape"].startswith("train") else
            "prefill" if r["shape"].startswith("prefill") else "decode")
    dom = rl["dominant"]
    bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
    useful_s = r["model_flops_per_device"] / PEAK_FLOPS
    frac = useful_s / bound if bound else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"],
        "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
        "collective_s": rl["collective_s"], "dominant": dom,
        "model_flops": r["model_flops_total"],
        "useful_ratio": r.get("useful_flops_ratio", 0.0),
        "roofline_frac": frac,
        "hbm_gb": r["memory"]["peak_hbm_bytes"] / 2**30,
        "note": NOTES.get((dom, kind), ""),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = [fmt_row(r) for r in load(args.mesh)]
    if args.md:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | useful/HLO | roofline_frac | HBM GiB/dev | "
              "lever |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for w in rows:
            print(f"| {w['arch']} | {w['shape']} | {w['compute_s']:.4f} | "
                  f"{w['memory_s']:.4f} | {w['collective_s']:.4f} | "
                  f"{w['dominant']} | {w['useful_ratio']:.3f} | "
                  f"{w['roofline_frac']:.3f} | {w['hbm_gb']:.1f} | "
                  f"{w['note']} |")
        for a in SKIPPED_LONG:
            print(f"| {a} | long_500k | — | — | — | skipped | — | — | — | "
                  f"full attention: sub-quadratic required (DESIGN.md §4) |")
    else:
        for w in rows:
            print(w)


if __name__ == "__main__":
    main()
