"""Loop-aware HLO analysis.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
with scanned layers / microbatch accumulation is undercounted by the trip
count. This module parses the partitioned HLO text into its computation
graph, infers while-loop trip counts from the loop condition, and walks the
graph with multipliers to produce loop-corrected:

  * dot FLOPs (per device)
  * kernel HBM traffic (operands read + results written per top-level op)
  * collective wire bytes (ring model per op kind)

Elementwise FLOPs inside fusions are ignored (dot-dominated workloads);
noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^(?:ROOT\s+)?%([\w.\-_]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLED = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)="
    r"%?([\w.\-_]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w.\-_]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
    # `copy` on while carries is an aliasing artifact of the CPU pipeline;
    # TPU XLA keeps loop state in place. Excluding it keeps the HBM-traffic
    # model from charging the full carry per iteration.
    "copy", "copy-start", "copy-done",
}

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_elems_bytes(type_str: str):
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    called: list


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)   # %name -> type string


def parse_computations(hlo: str):
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                # header params define types: %p = type parameter(i) appear
                # as separate instrs in body, so nothing more to do here.
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        called = []
        mb = _BRANCHES.search(rest)
        if mb:
            called = [c.strip().lstrip("%") for c in mb.group(1).split(",")]
        else:
            called = [c for c in _CALLED.findall(rest)]
        inst = Instr(name, type_str.strip(), op, rest, called)
        cur.instrs.append(inst)
        cur.types[name] = inst.type_str
    return comps


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for inst in cond.instrs:
        # constant instrs parse as op="constant", rest="<value>)..."
        if inst.op == "constant" and inst.type_str.startswith("s32"):
            m = re.match(r"(\d+)\)", inst.rest or "")
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = 0
    for dt, dims in _SHAPE.findall(inst.type_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        out_elems += n
    m = _CONTRACT.search(inst.rest)
    contract = 1
    if m:
        ops = _OPERAND.findall(inst.rest)
        if ops:
            lhs_type = comp.types.get(ops[0], "")
            sm = _SHAPE.search(lhs_type)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d.strip()]
                for idx in m.group(1).split(","):
                    if idx.strip() and int(idx) < len(dims):
                        contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _sliced_params(comp: Computation) -> dict:
    """Parameter index -> bytes actually read, for fused computations where
    a parameter is consumed ONLY through dynamic-slice/slice/gather (the
    kernel touches just the slice, not the buffer)."""
    param_names = {}
    for inst in comp.instrs:
        if inst.op == "parameter":
            m = re.match(r"(\d+)\)", inst.rest or "")
            if m:
                param_names[inst.name] = int(m.group(1))
    uses = {n: [] for n in param_names}
    for inst in comp.instrs:
        if inst.op == "parameter":
            continue
        for opnd in _OPERAND.findall(inst.rest):
            if opnd in uses:
                uses[opnd].append(inst)
    out = {}
    for name, idx in param_names.items():
        insts = uses.get(name, [])
        if not insts:
            continue
        if all(i.op in ("dynamic-slice", "slice", "gather",
                        "dynamic-update-slice") for i in insts):
            total = 0
            ok = True
            for i in insts:
                if i.op == "dynamic-update-slice":
                    ops_ = _OPERAND.findall(i.rest)
                    if ops_ and ops_[0] == name and len(ops_) > 1 \
                            and ops_[1] in comp.types:
                        # param is the aliased target buffer: traffic is the
                        # written slice, not the buffer
                        total += _shape_elems_bytes(comp.types[ops_[1]])
                    else:
                        ok = False
                else:
                    total += _shape_elems_bytes(i.type_str)
            if ok:
                out[idx] = total
    return out


def _dus_root_result_bytes(comp: Computation):
    """If the fused computation's root is a dynamic-update-slice, the fusion
    output aliases the target buffer; written traffic = the update slice."""
    root = comp.instrs[-1] if comp.instrs else None
    if root is None or root.op != "dynamic-update-slice":
        return None
    ops_ = _OPERAND.findall(root.rest)
    if len(ops_) > 1 and ops_[1] in comp.types:
        return _shape_elems_bytes(comp.types[ops_[1]])
    return None


def _wire_bytes(op: str, rbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return rbytes * (g - 1) / g
    if op == "reduce-scatter":
        return rbytes * (g - 1)
    if op == "all-reduce":
        return 2.0 * rbytes * (g - 1) / g
    if op == "all-to-all":
        return rbytes * (g - 1) / g
    return float(rbytes)  # collective-permute


@dataclass
class LoopAwareCounts:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)

    @property
    def wire_bytes(self):
        return sum(v["wire_bytes"] for v in self.collectives.values())


def analyze(hlo: str, default_group: int = 1) -> LoopAwareCounts:
    comps = parse_computations(hlo)
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            pass
    # entry = last computation in file by HLO convention; find via ENTRY kw
    m = re.search(r"ENTRY\s+%?([\w.\-_]+)", hlo)
    entry = m.group(1) if m else list(comps)[-1]

    out = LoopAwareCounts()
    seen_fusion_cache = {}

    def walk(comp_name: str, mult: float, stack=()):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for inst in comp.instrs:
            op = inst.op
            base = op.replace("-start", "") if op.endswith("-start") else op
            if base in COLLECTIVES:
                rbytes = _shape_elems_bytes(inst.type_str)
                g = _group_size(inst.rest, default_group)
                rec = out.collectives.setdefault(
                    base, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
                rec["count"] += mult
                rec["result_bytes"] += mult * rbytes
                rec["wire_bytes"] += mult * _wire_bytes(base, rbytes, g)
            if op in ("dot", "convolution"):
                out.dot_flops += mult * _dot_flops(inst, comp)
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-_]+)", inst.rest)
                mc = re.search(r"condition=%?([\w.\-_]+)", inst.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps, cond) if cond else 1
                out.while_trips.append(trips)
                if body:
                    walk(body, mult * trips, stack + (comp_name,))
                continue
            if op == "fusion" and inst.called:
                # fusion = one kernel; traffic counted below; dots inside
                # fusions (rare on CPU) counted via recursion without traffic
                for c in inst.called:
                    sub = comps.get(c)
                    if sub:
                        for si in sub.instrs:
                            if si.op in ("dot", "convolution"):
                                out.dot_flops += mult * _dot_flops(si, sub)
                # slice-aware operand traffic: a fused dynamic-slice/gather
                # reads only the slice, not the whole operand buffer
                sub = comps.get(inst.called[0])
                dus_out = _dus_root_result_bytes(sub) if sub else None
                t = dus_out if dus_out is not None else \
                    _shape_elems_bytes(inst.type_str)
                ops_ = _OPERAND.findall(inst.rest.split(" calls=")[0])
                sliced = _sliced_params(sub) if sub else {}
                for idx, opnd in enumerate(ops_):
                    if opnd not in comp.types:
                        continue
                    if idx in sliced:
                        t += sliced[idx]
                    else:
                        t += _shape_elems_bytes(comp.types[opnd])
                out.traffic_bytes += mult * t
                continue
            elif op in ("call", "conditional", "custom-call") and inst.called:
                for c in inst.called:
                    walk(c, mult, stack + (comp_name,))
                # traffic is accounted inside the callee; charging the call
                # wrapper's operands too would double-count (CPU XLA wraps
                # each fusion in a parallel-task `call`)
                continue
            # HBM traffic: operands + result for every top-level kernel-ish op
            if op not in _SKIP_TRAFFIC and op != "while":
                if op in ("dynamic-slice", "slice"):
                    # reads only the slice (result-sized), writes it back
                    out.traffic_bytes += mult * 2 * _shape_elems_bytes(
                        inst.type_str)
                elif op == "gather":
                    out.traffic_bytes += mult * 2 * _shape_elems_bytes(
                        inst.type_str)
                elif op == "dynamic-update-slice":
                    # in-place on TPU (input/output aliasing): traffic is a
                    # read-modify-write of the updated slice, not the buffer
                    ops_ = _OPERAND.findall(inst.rest)
                    upd = (_shape_elems_bytes(comp.types[ops_[1]])
                           if len(ops_) > 1 and ops_[1] in comp.types else 0)
                    out.traffic_bytes += mult * 2 * upd
                else:
                    t = _shape_elems_bytes(inst.type_str)
                    for opnd in _OPERAND.findall(inst.rest):
                        if opnd in comp.types:
                            t += _shape_elems_bytes(comp.types[opnd])
                    out.traffic_bytes += mult * t

    walk(entry, 1.0)
    return out
