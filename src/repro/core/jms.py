"""JMS — JIRIAF Matching Service: aligns leased resources with user
requests (paper §3).

Post-PR-1 role: pure *facade* — owns no state and no policy. It projects
a bare (node list, JFM pool) view into a throwaway Cluster and runs the
same filter stages (Ready, tolerations, nodeSelector/affinity, site
selector/anti-affinity, chips+HBM resources, walltime lease > expected
duration + drain margin) and score stages (non-straggler, data locality,
site spread/latency, best-fit HBM, node spread) that the queue-based
``repro.core.scheduler.Scheduler`` — the owner of matching policy — runs
against the real Cluster store. Legacy callers keep working; new code
should declare pods into a ``Cluster`` and let the scheduler/controllers
converge.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.cluster import Cluster
from repro.core.jfm import FacilityManager
from repro.core.jrm import VirtualNode
from repro.core.scheduler import Scheduler
from repro.core.state_machine import Pod


@dataclass
class MatchResult:
    pod: str
    node: Optional[str]
    reason: str = ""


@dataclass
class MatchingService:
    fm: FacilityManager

    def _transient(self, nodes: List[VirtualNode], now: float) -> Scheduler:
        """Project the (nodes, JFM pool) view into a throwaway Cluster so
        the shared filter/score stages apply unmodified."""
        cluster = Cluster()
        for n in nodes:
            cluster.register_node(n, now)
        for n in nodes:
            rec = self.fm.pool.get(n.name)
            st = cluster.node_status[n.name]
            st.ready = bool(rec and rec.ready)
            st.straggler = bool(rec and rec.straggler)
        return Scheduler(cluster, enable_preemption=False)

    def filter_nodes(self, pod: Pod, nodes: List[VirtualNode], now: float,
                     expected_duration: float = 0.0) -> List[VirtualNode]:
        sched = self._transient(nodes, now)
        rec = sched.cluster.submit(_spec_only(pod), now,
                                   expected_duration=expected_duration)
        return [n for n in nodes if sched.feasible(rec, n, now) is None]

    def match(self, pod: Pod, nodes: List[VirtualNode], now: float,
              expected_duration: float = 0.0) -> MatchResult:
        sched = self._transient(nodes, now)
        rec = sched.cluster.submit(_spec_only(pod), now,
                                   expected_duration=expected_duration)
        node, reason = sched.select_node(rec, now)
        if node is None:
            return MatchResult(pod.name, None, "no node satisfies request")
        return MatchResult(pod.name, node.name, "best-fit")

    def bind(self, pod: Pod, nodes: List[VirtualNode], now: float,
             expected_duration: float = 0.0) -> MatchResult:
        res = self.match(pod, nodes, now, expected_duration)
        if res.node is not None:
            node = next(n for n in nodes if n.name == res.node)
            node.create_pod(pod, now)
        return res


def _spec_only(pod: Pod) -> Pod:
    """The transient cluster must not mutate the caller's pod."""
    import dataclasses
    return dataclasses.replace(pod, containers=list(pod.containers))
