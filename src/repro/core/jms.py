"""JMS — JIRIAF Matching Service: aligns leased resources with user
requests (paper §3). Affinity/taint-aware best-fit bin-packing; the
resource vector is (chips, HBM bytes) with HBM taken from the dry-run's
``memory_analysis()`` for the requested (arch x shape) — see launch/train.

Placement policy (TPU adaptation):
  1. filter: Ready, tolerated taints, nodeSelector + affinity match,
     walltime left > pod's expected duration + drain margin,
  2. prefer non-straggler nodes (heartbeat-latency label from JFM),
  3. best-fit on free HBM (tightest fit that still holds the pod).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.jfm import FacilityManager
from repro.core.jrm import VirtualNode
from repro.core.state_machine import Pod


@dataclass
class MatchResult:
    pod: str
    node: Optional[str]
    reason: str = ""


@dataclass
class MatchingService:
    fm: FacilityManager

    def filter_nodes(self, pod: Pod, nodes: List[VirtualNode], now: float,
                     expected_duration: float = 0.0) -> List[VirtualNode]:
        out = []
        for n in nodes:
            rec = self.fm.pool.get(n.name)
            if rec is None or not rec.ready:
                continue
            if not n.tolerates(pod):
                continue
            lab = n.labels(now)
            if any(lab.get(k) != v for k, v in pod.node_selector.items()):
                continue
            if pod.affinity and not n.matches(pod.affinity, now):
                continue
            if n.free_chips() < pod.request_chips:
                continue
            if n.free_hbm() < pod.request_hbm_bytes:
                continue
            left = n.alive_left(now)
            if left != float("inf") and \
                    left < expected_duration + n.drain_margin:
                continue
            out.append(n)
        return out

    def match(self, pod: Pod, nodes: List[VirtualNode], now: float,
              expected_duration: float = 0.0) -> MatchResult:
        cands = self.filter_nodes(pod, nodes, now, expected_duration)
        if not cands:
            return MatchResult(pod.name, None, "no node satisfies request")
        recs = self.fm.pool
        # non-stragglers first, then tightest HBM fit
        cands.sort(key=lambda n: (recs[n.name].straggler,
                                  n.free_hbm() - pod.request_hbm_bytes))
        return MatchResult(pod.name, cands[0].name, "best-fit")

    def bind(self, pod: Pod, nodes: List[VirtualNode], now: float,
             expected_duration: float = 0.0) -> MatchResult:
        res = self.match(pod, nodes, now, expected_duration)
        if res.node is not None:
            node = next(n for n in nodes if n.name == res.node)
            node.create_pod(pod, now)
        return res
