"""JCS — JIRIAF Central Service (paper §3): initiates pilot jobs through
the JRM, modeling the FireWorks/Slurm deployment path of §4.5 and the
40-node Perlmutter bring-up of §5.1 (staggered srun of node-setup.sh with
SSH tunnels), creating VirtualNodes against a simulated facility.

Post-PR-1 role: the JCS *owns* pilot provisioning — it is the only
component that mints VirtualNodes — and registers them straight into the
declarative Cluster store when one is attached; scheduling and lifecycle
are the store's controllers' job, not the JCS's.

Federation (this PR): ``launch_multi`` deploys one pilot per facility for
a multi-site workflow, and ``reprovision`` closes the §4.5.4 loop
*proactively* — when a site's aggregate remaining walltime (Cluster
``SiteView``) drops below the projected demand of the pods running there,
the JCS launches a fresh pilot at that site before the drain wave hits,
so capacity exists by the time the NodeLifecycleController evicts.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.jfe import FrontEnd, WorkflowRequest
from repro.core.jrm import SliceSpec, VirtualNode, start_vk


@dataclass
class SSHTunnel:
    """§4.5.3 / Fig. 3: one line of the port map."""
    kind: str            # apiserver | kubelet | custom-metrics | db
    local_port: int
    remote_port: int
    direction: str       # L (local forward) | R (remote forward)


@dataclass
class PilotJob:
    wf_id: int
    nodes: List[str]
    tunnels: List[SSHTunnel]
    state: str = "RUNNING"


@dataclass
class CentralService:
    frontend: FrontEnd
    apiserver_port: int = 38687
    kubelet_port_base: int = 10000      # paper: JRM ports in [10000, 19999]
    metrics_port_base: int = 20000      # custom metrics in [20000, 49999]
    stagger_s: float = 3.0              # §5.1: `sleep 3` between sruns
    nodes: Dict[str, VirtualNode] = field(default_factory=dict)
    pilots: Dict[int, PilotJob] = field(default_factory=dict)
    _port: itertools.count = field(default_factory=lambda: itertools.count(0))

    def launch_pilot(self, wf: WorkflowRequest, now: float,
                     slice_spec: Optional[SliceSpec] = None,
                     cluster=None) -> PilotJob:
        """Deploy wf.nnodes JRMs (nersc-slurm.sh analog): staggered start,
        per-node kubelet + exporter tunnels, walltime lease set 60s short of
        the Slurm walltime (§4.5.4). With ``cluster`` the nodes register
        (and first-heartbeat) straight into the declarative store."""
        names, tunnels = [], []
        for i in range(1, wf.nnodes + 1):
            off = next(self._port)
            name = f"{wf.nodename}{i:02d}"
            kubelet_port = self.kubelet_port_base + off
            node = start_vk(
                name, nodetype=wf.nodetype, site=wf.site,
                walltime=max(wf.walltime - 60.0, 0.0) if wf.walltime else 0.0,
                kubelet_port=kubelet_port,
                now=now + self.stagger_s * (i - 1),
                slice_spec=slice_spec or SliceSpec())
            self.nodes[name] = node
            names.append(name)
            tunnels.append(SSHTunnel("apiserver", self.apiserver_port,
                                     self.apiserver_port, "L"))
            tunnels.append(SSHTunnel("kubelet", kubelet_port, kubelet_port, "R"))
            for j, kind in enumerate(("ersap", "process", "ejfat")):
                tunnels.append(SSHTunnel(
                    f"custom-metrics/{kind}",
                    self.metrics_port_base + 10000 * j + off,
                    (2221, 1776, 8088)[j], "R"))
        wf.state = "RUNNING"
        pilot = PilotJob(wf.wf_id, names, tunnels)
        self.pilots[wf.wf_id] = pilot
        if cluster is not None:
            for name in names:
                cluster.register_node(self.nodes[name], now)
                cluster.heartbeat(name, max(now, self.nodes[name].created_at))
        return pilot

    def launch_multi(self, wfs: List[WorkflowRequest], now: float,
                     slice_spec: Optional[SliceSpec] = None,
                     cluster=None) -> List[PilotJob]:
        """Multi-facility workflow targeting: one pilot per site-scoped
        WorkflowRequest (see ``FrontEnd.add_multi_wf``)."""
        return [self.launch_pilot(wf, now, slice_spec, cluster=cluster)
                for wf in wfs]

    def node_list(self) -> List[VirtualNode]:
        return list(self.nodes.values())

    # -------------------------------------------- proactive provisioning
    def projected_demand(self, cluster, site: str, now: float,
                         horizon: float = 600.0) -> float:
        """Seconds of work the site's pods still owe: remaining expected
        duration per pod, ``horizon`` for open-ended pods."""
        total = 0.0
        for rec in cluster.pods.values():
            node = cluster.nodes.get(rec.pod.node) if rec.bound else None
            if node is None or node.site != site:
                continue
            if rec.expected_duration > 0:
                total += max(rec.expected_duration
                             - (now - rec.submitted_at), 0.0)
            else:
                total += horizon
        return total

    def reprovision(self, cluster, now: float, *, horizon: float = 600.0,
                    walltime: float = 3600.0,
                    slice_spec: Optional[SliceSpec] = None) -> List[PilotJob]:
        """Proactive per-site pilot re-provisioning: for every site whose
        aggregate remaining walltime (SiteView, drain margin already
        subtracted) no longer covers its projected demand, launch a fresh
        pilot there — sized by the shortfall, capped at 1:1 replacement of
        the expiring nodes — so the batch drain wave reschedules onto
        capacity that already exists. Self-limiting: launched nodes raise
        the site's supply, so the next call is a no-op until the new
        lease erodes too."""
        launched = []
        for site, view in cluster.site_views(now).items():
            demand = self.projected_demand(cluster, site, now, horizon)
            if view.remaining_walltime >= demand:
                continue
            pool = cluster.site_nodes(site)
            # replace only live capacity that is about to expire; dead or
            # already-drained nodes linger in the store but add no supply
            live = [n for n in pool
                    if (st := cluster.node_status.get(n.name)) is not None
                    and st.ready and st.schedulable and n.alive_left(now) > 0]
            expiring = [n for n in live
                        if n.alive_left(now) - n.drain_margin < horizon]
            # size the pilot by the shortfall a replacement lease actually
            # covers, never beyond 1:1 replacement of expiring nodes
            usable = max(walltime - 120.0, 1.0)   # -60 JRM offset, -60 margin
            shortfall = demand - view.remaining_walltime
            n_new = min(max(len(expiring), 1),
                        max(1, math.ceil(shortfall / usable)))
            wf = self.frontend.add_wf(
                f"{site}-re{len(self.pilots)}-", n_new,
                nodetype=pool[0].nodetype if pool else "cpu", site=site,
                walltime=walltime)
            pilot = self.launch_pilot(
                wf, now, slice_spec or (pool[0].slice_spec if pool else None),
                cluster=cluster)
            launched.append(pilot)
        return launched

    def teardown(self, wf_id: int, now: float):
        pilot = self.pilots.get(wf_id)
        if not pilot:
            return
        for name in pilot.nodes:
            node = self.nodes.pop(name, None)
            if node:
                for pod in list(node.pods):
                    node.delete_pod(pod, now)
        pilot.state = "COMPLETED"
        wf = self.frontend.table.get(wf_id)
        if wf:
            wf.state = "COMPLETED"
