"""JCS — JIRIAF Central Service: initiates pilot jobs through the JRM
(paper §3). Models the FireWorks/Slurm deployment path of §4.5 and the
40-node Perlmutter bring-up of §5.1 (staggered srun of node-setup.sh with
SSH tunnels), creating VirtualNodes against a simulated facility.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.jfe import FrontEnd, WorkflowRequest
from repro.core.jrm import SliceSpec, VirtualNode, start_vk


@dataclass
class SSHTunnel:
    """§4.5.3 / Fig. 3: one line of the port map."""
    kind: str            # apiserver | kubelet | custom-metrics | db
    local_port: int
    remote_port: int
    direction: str       # L (local forward) | R (remote forward)


@dataclass
class PilotJob:
    wf_id: int
    nodes: List[str]
    tunnels: List[SSHTunnel]
    state: str = "RUNNING"


@dataclass
class CentralService:
    frontend: FrontEnd
    apiserver_port: int = 38687
    kubelet_port_base: int = 10000      # paper: JRM ports in [10000, 19999]
    metrics_port_base: int = 20000      # custom metrics in [20000, 49999]
    stagger_s: float = 3.0              # §5.1: `sleep 3` between sruns
    nodes: Dict[str, VirtualNode] = field(default_factory=dict)
    pilots: Dict[int, PilotJob] = field(default_factory=dict)
    _port: itertools.count = field(default_factory=lambda: itertools.count(0))

    def launch_pilot(self, wf: WorkflowRequest, now: float,
                     slice_spec: Optional[SliceSpec] = None) -> PilotJob:
        """Deploy wf.nnodes JRMs (nersc-slurm.sh analog): staggered start,
        per-node kubelet + exporter tunnels, walltime lease set 60s short of
        the Slurm walltime (§4.5.4)."""
        names, tunnels = [], []
        for i in range(1, wf.nnodes + 1):
            off = next(self._port)
            name = f"{wf.nodename}{i:02d}"
            kubelet_port = self.kubelet_port_base + off
            node = start_vk(
                name, nodetype=wf.nodetype, site=wf.site,
                walltime=max(wf.walltime - 60.0, 0.0) if wf.walltime else 0.0,
                kubelet_port=kubelet_port,
                now=now + self.stagger_s * (i - 1),
                slice_spec=slice_spec or SliceSpec())
            self.nodes[name] = node
            names.append(name)
            tunnels.append(SSHTunnel("apiserver", self.apiserver_port,
                                     self.apiserver_port, "L"))
            tunnels.append(SSHTunnel("kubelet", kubelet_port, kubelet_port, "R"))
            for j, kind in enumerate(("ersap", "process", "ejfat")):
                tunnels.append(SSHTunnel(
                    f"custom-metrics/{kind}",
                    self.metrics_port_base + 10000 * j + off,
                    (2221, 1776, 8088)[j], "R"))
        wf.state = "RUNNING"
        pilot = PilotJob(wf.wf_id, names, tunnels)
        self.pilots[wf.wf_id] = pilot
        return pilot

    def node_list(self) -> List[VirtualNode]:
        return list(self.nodes.values())

    def teardown(self, wf_id: int, now: float):
        pilot = self.pilots.get(wf_id)
        if not pilot:
            return
        for name in pilot.nodes:
            node = self.nodes.pop(name, None)
            if node:
                for pod in list(node.pods):
                    node.delete_pod(pod, now)
        pilot.state = "COMPLETED"
        wf = self.frontend.table.get(wf_id)
        if wf:
            wf.state = "COMPLETED"
