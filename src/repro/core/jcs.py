"""JCS — JIRIAF Central Service (paper §3): initiates pilot jobs through
the JRM, modeling the FireWorks/Slurm deployment path of §4.5 and the
40-node Perlmutter bring-up of §5.1 (staggered srun of node-setup.sh with
SSH tunnels), creating VirtualNodes against a simulated facility.

Post-PR-1 role: the JCS *owns* pilot provisioning — it is the only
component that mints VirtualNodes — and registers them straight into the
declarative Cluster store when one is attached; scheduling and lifecycle
are the store's controllers' job, not the JCS's.

Federation: ``launch_multi`` deploys one pilot per facility for a
multi-site workflow, and ``reprovision`` closes the §4.5.4 loop
*proactively* — when a site's aggregate remaining walltime (Cluster
``SiteView``) drops below the projected demand of the pods running there,
the JCS launches a fresh pilot at that site before the drain wave hits,
so capacity exists by the time the NodeLifecycleController evicts.
Pilots are sized from live demand, not walltime shortfall alone: the
serving queue backlog (seconds of work the current replicas have not
absorbed) and the chip concurrency of capacity-starved pending pods both
raise the node count (quota-blocked pods never do — a fair-share cap is
not helped by more nodes).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.jfe import FrontEnd, WorkflowRequest
from repro.core.jrm import SliceSpec, VirtualNode, start_vk
# shared reject classifier, defined next to the filters whose reasons it
# parses: quota rejects never count as capacity starvation
from repro.core.scheduler import is_capacity_starved


@dataclass
class SSHTunnel:
    """§4.5.3 / Fig. 3: one line of the port map."""
    kind: str            # apiserver | kubelet | custom-metrics | db
    local_port: int
    remote_port: int
    direction: str       # L (local forward) | R (remote forward)


@dataclass
class PilotJob:
    wf_id: int
    nodes: List[str]
    tunnels: List[SSHTunnel]
    state: str = "RUNNING"


@dataclass
class CentralService:
    frontend: FrontEnd
    apiserver_port: int = 38687
    kubelet_port_base: int = 10000      # paper: JRM ports in [10000, 19999]
    metrics_port_base: int = 20000      # custom metrics in [20000, 49999]
    stagger_s: float = 3.0              # §5.1: `sleep 3` between sruns
    nodes: Dict[str, VirtualNode] = field(default_factory=dict)
    pilots: Dict[int, PilotJob] = field(default_factory=dict)
    _port: itertools.count = field(default_factory=lambda: itertools.count(0))

    def launch_pilot(self, wf: WorkflowRequest, now: float,
                     slice_spec: Optional[SliceSpec] = None,
                     cluster=None) -> PilotJob:
        """Deploy wf.nnodes JRMs (nersc-slurm.sh analog): staggered start,
        per-node kubelet + exporter tunnels, walltime lease set 60s short of
        the Slurm walltime (§4.5.4). With ``cluster`` the nodes register
        (and first-heartbeat) straight into the declarative store."""
        names, tunnels = [], []
        for i in range(1, wf.nnodes + 1):
            off = next(self._port)
            name = f"{wf.nodename}{i:02d}"
            kubelet_port = self.kubelet_port_base + off
            node = start_vk(
                name, nodetype=wf.nodetype, site=wf.site,
                walltime=max(wf.walltime - 60.0, 0.0) if wf.walltime else 0.0,
                kubelet_port=kubelet_port,
                now=now + self.stagger_s * (i - 1),
                slice_spec=slice_spec or SliceSpec())
            self.nodes[name] = node
            names.append(name)
            tunnels.append(SSHTunnel("apiserver", self.apiserver_port,
                                     self.apiserver_port, "L"))
            tunnels.append(SSHTunnel("kubelet", kubelet_port, kubelet_port, "R"))
            for j, kind in enumerate(("ersap", "process", "ejfat")):
                tunnels.append(SSHTunnel(
                    f"custom-metrics/{kind}",
                    self.metrics_port_base + 10000 * j + off,
                    (2221, 1776, 8088)[j], "R"))
        wf.state = "RUNNING"
        pilot = PilotJob(wf.wf_id, names, tunnels)
        self.pilots[wf.wf_id] = pilot
        if cluster is not None:
            for name in names:
                cluster.register_node(self.nodes[name], now)
                cluster.heartbeat(name, max(now, self.nodes[name].created_at))
        return pilot

    def launch_multi(self, wfs: List[WorkflowRequest], now: float,
                     slice_spec: Optional[SliceSpec] = None,
                     cluster=None) -> List[PilotJob]:
        """Multi-facility workflow targeting: one pilot per site-scoped
        WorkflowRequest (see ``FrontEnd.add_multi_wf``)."""
        return [self.launch_pilot(wf, now, slice_spec, cluster=cluster)
                for wf in wfs]

    def node_list(self) -> List[VirtualNode]:
        return list(self.nodes.values())

    # -------------------------------------------- proactive provisioning
    def projected_demand(self, cluster, site: str, now: float,
                         horizon: float = 600.0) -> float:
        """Seconds of work the site's pods still owe: remaining expected
        duration per pod, ``horizon`` for open-ended pods."""
        total = 0.0
        for rec in cluster.pods.values():
            node = cluster.nodes.get(rec.pod.node) if rec.bound else None
            if node is None or node.site != site:
                continue
            if rec.expected_duration > 0:
                total += max(rec.expected_duration
                             - (now - rec.submitted_at), 0.0)
            else:
                total += horizon
        return total

    @staticmethod
    def _starved_chips(cluster, now: float) -> Dict[str, List[int]]:
        """Per-pod chip requests of capacity-starved pending pods,
        attributed to one site each: pods the scheduler has already
        bounced for chips/HBM (never quota — fair-share caps are not
        helped by more nodes; a quota reject's message names the
        resource too, so quota parts are excluded before the capacity
        test) want a bigger pool. A pod naming sites goes to its first
        selectable site; an unconstrained pod to the site with the most
        free chips (one site only — counting it everywhere would launch
        a pilot per facility for a single pod)."""
        by_site: Dict[str, List[int]] = {}
        sites = cluster.site_names()
        if not sites:
            return by_site
        free = {s: cluster.site_view(s, now).free_chips for s in sites}
        for rec in cluster.pending_pods():
            if rec.attempts < 1:
                continue
            if not is_capacity_starved(rec.last_reason):
                continue
            cands = [s for s in rec.site_selector if s in free] \
                or [s for s in sites if s not in rec.site_anti_affinity]
            if not cands:
                continue
            site = max(cands, key=lambda s: free[s])
            by_site.setdefault(site, []).append(
                max(rec.pod.request_chips, 1))
        return by_site

    def reprovision(self, cluster, now: float, *, horizon: float = 600.0,
                    walltime: float = 3600.0,
                    slice_spec: Optional[SliceSpec] = None,
                    queue_backlog: float = 0.0,
                    service_rate: float = 0.0) -> List[PilotJob]:
        """Proactive per-site pilot re-provisioning, sized from three
        demand sources instead of walltime shortfall alone:

        1. **walltime shortfall** — the site's aggregate remaining
           walltime (SiteView, drain margin already subtracted) no longer
           covers the projected demand of the pods running there; sized
           by the shortfall, capped at 1:1 replacement of expiring nodes.
        2. **live queue backlog** — ``queue_backlog`` waiting requests at
           ``service_rate`` req/s per replica are ``backlog/rate`` seconds
           of serving work that existing replicas have not absorbed,
           attributed to each site by its share of bound pods.
        3. **chip concurrency** — pending pods the scheduler already
           bounced for chips/HBM (never quota-blocked ones: fair-share
           caps are not helped by more nodes) need net-new chips now,
           regardless of walltime runway.

        Self-limiting: launched nodes raise the site's supply and free
        chips, so the next call is a no-op until demand grows again."""
        launched = []
        starved = self._starved_chips(cluster, now)
        bound_by_site: Dict[str, int] = {}
        for rec in cluster.pods.values():
            node = cluster.nodes.get(rec.pod.node) if rec.bound else None
            if node is not None:
                bound_by_site[node.site] = bound_by_site.get(node.site, 0) + 1
        total_bound = sum(bound_by_site.values())
        for site, view in cluster.site_views(now).items():
            demand = self.projected_demand(cluster, site, now, horizon)
            if queue_backlog > 0 and service_rate > 0:
                share = bound_by_site.get(site, 0) / total_bound \
                    if total_bound else 1.0 / max(len(cluster.site_names()), 1)
                demand += (queue_backlog / service_rate) * share
            pool = cluster.site_nodes(site)
            chips_per_node = (slice_spec or
                              (pool[0].slice_spec if pool
                               else SliceSpec())).chips
            # fragmentation-aware shortfall: first-fit the starved pods'
            # requests onto the site's per-node free chips (aggregate
            # free is optimistic — two nodes with 1 free chip each
            # cannot host a 2-chip pod); whatever does not place needs
            # net-new nodes
            node_free = sorted(
                (n.free_chips() for n in pool
                 if (st := cluster.node_status.get(n.name)) is not None
                 and st.ready and st.schedulable), reverse=True)
            chips_short = 0
            for req in sorted(starved.get(site, ()), reverse=True):
                if req > chips_per_node:
                    # a replacement node of this slice size could not
                    # host it either — launching pilots for it would
                    # repeat every call without ever binding the pod
                    continue
                for i, f in enumerate(node_free):
                    if f >= req:
                        node_free[i] -= req
                        break
                else:
                    chips_short += req
            n_chip = math.ceil(chips_short / max(chips_per_node, 1))
            if view.remaining_walltime >= demand and n_chip == 0:
                continue
            # replace only live capacity that is about to expire; dead or
            # already-drained nodes linger in the store but add no supply
            live = [n for n in pool
                    if (st := cluster.node_status.get(n.name)) is not None
                    and st.ready and st.schedulable and n.alive_left(now) > 0]
            expiring = [n for n in live
                        if n.alive_left(now) - n.drain_margin < horizon]
            # size the pilot by the shortfall a replacement lease actually
            # covers, never beyond 1:1 replacement of expiring nodes; the
            # chip-concurrency demand is net-new and adds on top
            usable = max(walltime - 120.0, 1.0)   # -60 JRM offset, -60 margin
            n_wall = 0
            if demand > view.remaining_walltime:
                shortfall = demand - view.remaining_walltime
                n_wall = min(max(len(expiring), 1),
                             max(1, math.ceil(shortfall / usable)))
            n_new = max(n_wall, n_chip)
            if n_new <= 0:
                continue
            wf = self.frontend.add_wf(
                f"{site}-re{len(self.pilots)}-", n_new,
                nodetype=pool[0].nodetype if pool else "cpu", site=site,
                walltime=walltime)
            pilot = self.launch_pilot(
                wf, now, slice_spec or (pool[0].slice_spec if pool else None),
                cluster=cluster)
            launched.append(pilot)
        return launched

    def teardown(self, wf_id: int, now: float):
        pilot = self.pilots.get(wf_id)
        if not pilot:
            return
        for name in pilot.nodes:
            node = self.nodes.pop(name, None)
            if node:
                for pod in list(node.pods):
                    node.delete_pod(pod, now)
        pilot.state = "COMPLETED"
        wf = self.frontend.table.get(wf_id)
        if wf:
            wf.state = "COMPLETED"
