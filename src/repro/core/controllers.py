"""Controllers — reconcile desired state in the Cluster store (paper §3/§4).

``DeploymentController`` converges ``Deployment.replicas`` -> pods: it
creates missing pods (into the scheduler's pending queue), retires excess
ones newest-first, and replaces pods whose node vanished. Replacement pods
inherit the checkpointed runtime state their predecessor left behind.

``NodeLifecycleController`` closes the §4.5.4 walltime loop the seed only
annotated: when a node's lease enters the drain margin it cordons the
node, checkpoints every pod on it via ``repro.checkpoint`` (atomic on-disk
save; restored through the same path), evicts the pods, and parks their
state so the DeploymentController's replacements pick it up and the
scheduler re-places them on healthy nodes. Expired or heartbeat-dead nodes
are marked NotReady and their pods evicted without the graceful
checkpoint (the crash path of test_node_failure_reschedule).

``ControlPlane`` bundles store + scheduler + controllers into a single
``step(now)`` so drivers (StreamEngine, launch/serve, benchmarks) run one
reconcile call per tick. ``drain_site`` / ``drain_allocation`` extend the
drain loop to federation scale: a whole facility's node pool (one pilot
allocation) is cordoned up front and drained as a single checkpoint/evict
wave, and the displaced replicas reschedule cross-site with their state
restored.

QoS wiring: the ControlPlane hands the scheduler its
``checkpoint_cb`` — preemption victims snapshot through the same
``checkpoint_pod`` path as drained pods, so cross-priority eviction is
state-preserving end to end. Deployment pods inherit the template's
``priority_class`` / ``request_kv_pages``; a Deployment whose pods are
quota-blocked idles at the scheduler's max backoff with a single
FailedScheduling transition event instead of hot-looping (see
``scheduler.run_once``).
"""
from __future__ import annotations

import pathlib
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

from repro.checkpoint import checkpointer
from repro.core.cluster import KIND_POD, Cluster, PodRecord
from repro.core.scheduler import Scheduler


@dataclass
class DeploymentController:
    cluster: Cluster
    # state parked by the NodeLifecycleController, keyed by deployment:
    # [(predecessor pod name, runtime state), ...]
    pending_restores: Dict[str, List] = field(default_factory=dict)

    def park_state(self, deployment: str, pod_name: str, state: dict):
        self.pending_restores.setdefault(deployment, []).append(
            (pod_name, state))

    def reconcile(self, now: float) -> List[str]:
        """One pass: returns names of pods created this pass."""
        created = []
        for dep in self.cluster.deployments.values():
            live = self.cluster.pods_of(dep.name)
            # scale down: prefer retiring still-pending pods, then newest
            while len(live) > dep.replicas:
                victim = max(live, key=lambda r: (not r.bound,
                                                  r.submitted_at))
                self.cluster.evict(victim.name, now, reason="ScaledDown",
                                   message=f"deployment={dep.name}")
                live.remove(victim)
            # scale up / replace evicted pods
            while len(live) < dep.replicas:
                name = dep.next_pod_name()
                restored_from = restored_state = None
                stash = self.pending_restores.get(dep.name)
                if stash:
                    restored_from, restored_state = stash.pop(0)
                rec = self.cluster.submit(
                    dep.template.instantiate(name), now, owner=dep.name,
                    priority=dep.template.priority,
                    priority_class=dep.template.priority_class,
                    request_kv_pages=dep.template.request_kv_pages,
                    expected_duration=dep.template.expected_duration,
                    site_selector=dep.template.site_selector,
                    site_anti_affinity=dep.template.site_anti_affinity,
                    data_stream=dep.template.data_stream,
                    restored_from=restored_from,
                    restored_state=restored_state)
                live.append(rec)
                created.append(name)
            # any state still parked here wasn't consumed by a same-pass
            # replacement (replicas shrank meanwhile) — drop it, or a
            # future unrelated scale-up would inherit a retired pod's
            # counters
            self.pending_restores.pop(dep.name, None)
        return created


@dataclass
class NodeLifecycleController:
    cluster: Cluster
    deployment_ctrl: Optional[DeploymentController] = None
    ckpt_dir: Optional[str] = None       # defaults to a temp dir on first use
    stale_after: float = 30.0            # no heartbeat for this long = dead
    _drained: Set[str] = field(default_factory=set)
    _ckpt_steps: Dict[str, int] = field(default_factory=dict)

    def checkpoint_pod(self, rec: PodRecord, now: float) -> Optional[dict]:
        """Snapshot the pod's runtime state through repro.checkpoint: the
        same atomic save/restore path training and elastic scaling use.
        Called on the drain path below and (via the ControlPlane wiring)
        by the scheduler for preemption victims."""
        dep = self.cluster.deployments.get(rec.owner or "")
        provider = dep.template.checkpoint_state if dep else None
        if provider is None:
            return None
        state = provider(rec.name)
        if state is None:
            return None
        if self.ckpt_dir is None:
            self.ckpt_dir = tempfile.mkdtemp(prefix="jiriaf-drain-")
        tree = {k: np.asarray(v) for k, v in state.items()}
        step = self._ckpt_steps.get(rec.name, 0)
        pod_dir = pathlib.Path(self.ckpt_dir) / rec.name
        checkpointer.save(pod_dir, step, tree,
                          meta={"pod": rec.name, "node": rec.pod.node or "",
                                "time": now})
        self._ckpt_steps[rec.name] = step + 1
        # restore from disk so the round trip is exercised, not assumed
        restored, _meta = checkpointer.restore(pod_dir, tree, step=step)
        self.cluster.record(now, KIND_POD, rec.name, "Checkpointed",
                            f"dir={pod_dir} step={step}")
        return {k: np.asarray(v) for k, v in restored.items()}

    def _drain_node(self, name: str, now: float):
        self.cluster.cordon(name, now, reason="Draining")
        for rec in self.cluster.pods_on(name):
            state = self.checkpoint_pod(rec, now)
            evicted = self.cluster.evict(
                rec.name, now, reason="Evicted",
                message=f"node {name} draining")
            if evicted is None:
                continue
            if evicted.owner and self.deployment_ctrl is not None:
                self.deployment_ctrl.park_state(
                    evicted.owner, evicted.name, state or {})
        self._drained.add(name)

    def drain_allocation(self, names: List[str], now: float):
        """Batch drain a whole pilot allocation (§4.5.4 at site scale):
        cordon every node *first* — so a displaced pod can never be
        re-placed onto a sibling of the same expiring allocation — then
        run one checkpoint/evict wave. Parked state is restored by the
        DeploymentController's replacements, which the scheduler is free
        to re-place cross-site."""
        for name in names:
            if name in self.cluster.nodes:
                self.cluster.cordon(name, now, reason="Draining")
        for name in names:
            if name in self.cluster.nodes:
                self._drain_node(name, now)

    def _fail_node(self, name: str, now: float, why: str):
        st = self.cluster.node_status[name]
        if st.ready:
            self.cluster.set_node_status(name, now, ready=False,
                                         heartbeat_age=st.heartbeat_age)
        for rec in self.cluster.pods_on(name):
            evicted = self.cluster.evict(rec.name, now, reason="Evicted",
                                         message=f"node {name} {why}")
            # crash path: no checkpoint to park, replacement starts fresh
            if evicted and evicted.owner and self.deployment_ctrl is not None:
                self.deployment_ctrl.park_state(
                    evicted.owner, evicted.name, {})

    def reconcile(self, now: float):
        to_drain = []
        for name, node in list(self.cluster.nodes.items()):
            st = self.cluster.node_status.get(name)
            if st is None:
                continue
            if node.walltime > 0 and node.alive_left(now) <= 0:
                if node.ready or st.ready or self.cluster.pods_on(name):
                    node.ready = False
                    self._fail_node(name, now, "walltime expired")
                continue
            # staleness from the node's own heartbeat clock, so dead nodes
            # are caught even when no JFM feed refreshes heartbeat_age
            age = max(st.heartbeat_age, now - node.last_heartbeat)
            stale = age > self.stale_after
            if (stale or not st.ready) and \
                    (st.ready or self.cluster.pods_on(name)):
                self._fail_node(name, now,
                                "heartbeat stale" if stale else "not ready")
                continue
            if not st.ready:
                continue
            if node.draining(now) and name not in self._drained:
                to_drain.append(name)
        # same-pass expirations (one pilot allocation typically shares a
        # lease) drain as a single wave: cordon all first, then evict
        if to_drain:
            self.drain_allocation(to_drain, now)


@dataclass
class ControlPlane:
    """Store + scheduler + controllers behind one reconcile call."""
    cluster: Cluster
    scheduler: Scheduler = None
    deployments: DeploymentController = None
    nodes: NodeLifecycleController = None

    def __post_init__(self):
        if self.scheduler is None:
            self.scheduler = Scheduler(self.cluster)
        if self.deployments is None:
            self.deployments = DeploymentController(self.cluster)
        if self.nodes is None:
            self.nodes = NodeLifecycleController(
                self.cluster, deployment_ctrl=self.deployments)
        elif self.nodes.deployment_ctrl is None:
            self.nodes.deployment_ctrl = self.deployments
        if self.scheduler.checkpoint_cb is None:
            # preemption victims take the same §4.5.4 checkpoint path as
            # drained pods, so a preempted batch job resumes where it was
            self.scheduler.checkpoint_cb = self.nodes.checkpoint_pod

    def step(self, now: float):
        """One control-plane tick: lifecycle first (drains/evictions free
        capacity and park state), then replica convergence, then binding."""
        self.nodes.reconcile(now)
        self.deployments.reconcile(now)
        return self.scheduler.run_once(now)

    def drain_site(self, site: str, now: float):
        """Evacuate one whole facility (kill / maintenance / superseded
        pilot): batch-drain every node of ``site`` as a single
        checkpoint/evict wave, then converge replicas and re-bind them —
        cross-site, with restored state — in the same call."""
        names = [n.name for n in self.cluster.site_nodes(site)]
        self.cluster.record(now, "Node", site, "SiteDrain",
                            f"nodes={len(names)}")
        self.nodes.drain_allocation(names, now)
        self.deployments.reconcile(now)
        return self.scheduler.run_once(now)
