"""Controllers — reconcile desired state in the Cluster store (paper §3/§4).

``DeploymentController`` converges ``Deployment.replicas`` -> pods: it
creates missing pods (into the scheduler's pending queue), retires excess
ones newest-first, and replaces pods whose node vanished. Replacement pods
inherit the checkpointed runtime state their predecessor left behind.

``NodeLifecycleController`` closes the §4.5.4 walltime loop the seed only
annotated: when a node's lease enters the drain margin it cordons the
node, checkpoints every pod on it via ``repro.checkpoint`` (atomic on-disk
save; restored through the same path), evicts the pods, and parks their
state so the DeploymentController's replacements pick it up and the
scheduler re-places them on healthy nodes. Expired or heartbeat-dead nodes
are marked NotReady and their pods evicted without the graceful
checkpoint (the crash path of test_node_failure_reschedule).

``ControlPlane`` bundles store + scheduler + controllers into a single
``step(now)`` so drivers (StreamEngine, launch/serve, benchmarks) run one
reconcile call per tick. ``drain_site`` / ``drain_allocation`` extend the
drain loop to federation scale: a whole facility's node pool (one pilot
allocation) is cordoned up front and drained as a single checkpoint/evict
wave, and the displaced replicas reschedule cross-site with their state
restored.

QoS wiring: the ControlPlane hands the scheduler its
``checkpoint_cb`` — preemption victims snapshot through the same
``checkpoint_pod`` path as drained pods, so cross-priority eviction is
state-preserving end to end. Deployment pods inherit the template's
``priority_class`` / ``request_kv_pages``; a Deployment whose pods are
quota-blocked idles at the scheduler's max backoff with a single
FailedScheduling transition event instead of hot-looping (see
``scheduler.run_once``).
"""
from __future__ import annotations

import heapq
import pathlib
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.checkpoint import checkpointer
from repro.core.cluster import (ADDED, KIND_DEPLOYMENT, KIND_NODE, KIND_POD,
                                Cluster, PodRecord, WatchEvent)
from repro.core.scheduler import Scheduler


@dataclass
class DeploymentController:
    cluster: Cluster
    # state parked by the NodeLifecycleController, keyed by deployment:
    # [(predecessor pod name, runtime state), ...]
    pending_restores: Dict[str, List] = field(default_factory=dict)
    # polling=True reproduces the pre-event-driven behavior bit for bit:
    # every Deployment is reconciled every pass. Event-driven (default)
    # reconciles only Deployments a watch delta has marked dirty — a
    # spec write, or any delta of an owned pod (create/bind/evict/phase;
    # an evict always precedes its park_state, so parked restores are
    # consumed or dropped on exactly the pass polling would).
    polling: bool = False
    event_budget: int = 0       # max dirty Deployments per pass (0 = all)
    # insertion-ordered (dict-as-ordered-set): when the budget caps a
    # pass, the OLDEST-dirty Deployments go first, so one that keeps
    # re-dirtying itself (its own pod churn) cannot starve the rest
    _dirty: Dict[str, None] = field(default_factory=dict, init=False,
                                    repr=False)

    def __post_init__(self):
        self.cluster.watch(KIND_DEPLOYMENT, self._on_deployment_delta)
        self.cluster.watch(KIND_POD, self._on_pod_delta)
        for name in self.cluster.deployments:
            self._dirty.setdefault(name)

    def _on_deployment_delta(self, ev: WatchEvent) -> None:
        self._dirty.setdefault(ev.name)

    def _on_pod_delta(self, ev: WatchEvent) -> None:
        owner = getattr(ev.obj, "owner", None)
        if owner is not None:
            self._dirty.setdefault(owner)

    def park_state(self, deployment: str, pod_name: str, state: dict):
        self.pending_restores.setdefault(deployment, []).append(
            (pod_name, state))

    def reconcile(self, now: float) -> List[str]:
        """One pass: returns names of pods created this pass."""
        created = []
        chosen = None
        if not self.polling:
            # budget selection is dirty-FIFO (oldest first, fair); the
            # visit below stays in store order so dirty Deployments
            # reconcile in the same relative order the polling scan used
            names = list(self._dirty)
            if self.event_budget and len(names) > self.event_budget:
                names = names[:self.event_budget]
            chosen = set(names)
            for name in names:     # re-dirtied mid-pass -> back of queue
                self._dirty.pop(name, None)
        for dep in list(self.cluster.deployments.values()):
            if chosen is not None and dep.name not in chosen:
                continue
            live = self.cluster.pods_of(dep.name)
            # scale down: prefer retiring still-pending pods, then newest
            while len(live) > dep.replicas:
                victim = max(live, key=lambda r: (not r.bound,
                                                  r.submitted_at))
                self.cluster.evict(victim.name, now, reason="ScaledDown",
                                   message=f"deployment={dep.name}")
                live.remove(victim)
            # scale up / replace evicted pods
            while len(live) < dep.replicas:
                name = dep.next_pod_name()
                restored_from = restored_state = None
                stash = self.pending_restores.get(dep.name)
                if stash:
                    restored_from, restored_state = stash.pop(0)
                rec = self.cluster.submit(
                    dep.template.instantiate(name), now, owner=dep.name,
                    priority=dep.template.priority,
                    priority_class=dep.template.priority_class,
                    request_kv_pages=dep.template.request_kv_pages,
                    expected_duration=dep.template.expected_duration,
                    site_selector=dep.template.site_selector,
                    site_anti_affinity=dep.template.site_anti_affinity,
                    data_stream=dep.template.data_stream,
                    restored_from=restored_from,
                    restored_state=restored_state)
                live.append(rec)
                created.append(name)
            # any state still parked here wasn't consumed by a same-pass
            # replacement (replicas shrank meanwhile) — drop it, or a
            # future unrelated scale-up would inherit a retired pod's
            # counters
            self.pending_restores.pop(dep.name, None)
        return created


@dataclass
class NodeLifecycleController:
    cluster: Cluster
    deployment_ctrl: Optional[DeploymentController] = None
    ckpt_dir: Optional[str] = None       # defaults to a temp dir on first use
    stale_after: float = 30.0            # no heartbeat for this long = dead
    # two-phase drain support: with a positive interval, every
    # checkpointable bound pod gets a periodic *background* snapshot, so
    # a drain cut short by walltime/crash resumes from the last one
    # instead of the crash path's start-fresh. 0 keeps the old behavior.
    bg_checkpoint_every: float = 0.0
    # paced (interruptible) drains: evict at most this many pods per
    # reconcile pass. 0 = whole node in one pass (old behavior).
    drain_pods_per_tick: int = 0
    # bounded retry-with-backoff + wall timeout on the save/restore I/O
    # of the drain path (flaky shared filesystems are the steady state)
    ckpt_retries: int = 2
    ckpt_timeout: Optional[float] = 10.0
    # polling=True reconciles every node every pass (the reference
    # behavior). Event-driven (default) reconciles only nodes that are
    # *dirty* (a non-heartbeat Node delta arrived) or *due* (a deadline
    # from the lazy heap fired: walltime expiry, drain-margin entry, or
    # heartbeat staleness). Pod deltas never dirty a node: a pod can
    # only bind to a ready+schedulable node, so a bind cannot create
    # lifecycle-actionable state that a deadline or node delta doesn't
    # already cover.
    polling: bool = False
    event_budget: int = 0       # max nodes reconciled per pass (0 = all)
    # checkpoint bytes captured per pod by the most recent drain wave —
    # what the transfer-cost model charges when the displaced pod
    # re-binds at another site (cleared by the caller per wave)
    drain_bytes: Dict[str, int] = field(default_factory=dict)
    tracer: object = None       # optional observability-plane span sink
    _drained: Set[str] = field(default_factory=set)
    _ckpt_steps: Dict[str, int] = field(default_factory=dict)
    _last_bg_ckpt: Dict[str, float] = field(default_factory=dict)
    _not_ready_seen: Set[str] = field(default_factory=set)
    # insertion-ordered (dict-as-ordered-set), same fairness contract as
    # the DeploymentController: budget picks oldest-dirty first
    _dirty: Dict[str, None] = field(default_factory=dict, init=False,
                                    repr=False)
    # lazy deadline heap: (time, entry-kind, node). Walltime entries are
    # pushed at registration / walltime-cut; heartbeat-staleness entries
    # are re-armed from the *live* last_heartbeat at pop time, so the
    # 10k-per-tick heartbeat storm costs O(1) per heartbeat and the heap
    # stays O(nodes)
    _deadlines: List[Tuple[float, str, str]] = field(default_factory=list,
                                                     init=False, repr=False)
    _hb_armed: Set[str] = field(default_factory=set, init=False, repr=False)
    _reg_seq: Dict[str, int] = field(default_factory=dict, init=False,
                                     repr=False)

    def __post_init__(self):
        self.cluster.watch(KIND_NODE, self._on_node_delta)
        for name in self.cluster.nodes:
            self._track_node(name)

    def _track_node(self, name: str) -> None:
        self._reg_seq.setdefault(name, len(self._reg_seq))
        self._dirty.setdefault(name)
        self._push_walltime_deadlines(name)
        self._arm_heartbeat(name)

    def _push_walltime_deadlines(self, name: str) -> None:
        node = self.cluster.nodes.get(name)
        if node is None or node.walltime <= 0:
            return
        expiry = node.created_at + node.walltime
        heapq.heappush(self._deadlines, (expiry, "expiry", name))
        heapq.heappush(self._deadlines,
                       (expiry - node.drain_margin, "drain", name))

    def _arm_heartbeat(self, name: str) -> None:
        if name in self._hb_armed:
            return
        node = self.cluster.nodes.get(name)
        if node is None:
            return
        self._hb_armed.add(name)
        heapq.heappush(self._deadlines,
                       (node.last_heartbeat + self.stale_after, "hb", name))

    def _on_node_delta(self, ev: WatchEvent) -> None:
        if ev.reason == "heartbeat":
            # O(1) on the hot path: make sure a staleness deadline is
            # armed; its pop re-reads the live heartbeat clock
            self._arm_heartbeat(ev.name)
            return
        if ev.type == ADDED:
            self._track_node(ev.name)
            return
        self._dirty.setdefault(ev.name)
        if ev.reason == "walltime":
            # lease revised: the old heap entries pop harmlessly (the
            # body is idempotent); the new ones carry the revised times
            self._push_walltime_deadlines(ev.name)

    def _pop_due(self, now: float) -> Set[str]:
        due: Set[str] = set()
        while self._deadlines and self._deadlines[0][0] <= now:
            _, kind, name = heapq.heappop(self._deadlines)
            node = self.cluster.nodes.get(name)
            if kind == "hb":
                self._hb_armed.discard(name)
                if node is not None:
                    next_hb = node.last_heartbeat + self.stale_after
                    st = self.cluster.node_status.get(name)
                    actionable = (st is not None and st.ready) or \
                        bool(self.cluster.pods_on(name))
                    if next_hb > now:
                        self._arm_heartbeat(name)
                    elif actionable:
                        # stale this very tick: the body below handles
                        # it; re-arm epsilon-late so an exactly-at-the-
                        # boundary age (== stale_after, not >) is caught
                        # on the next pass, matching the polling scan
                        self._hb_armed.add(name)
                        heapq.heappush(self._deadlines,
                                       (now + 1e-9, "hb", name))
                        due.add(name)
                    # stale and inactionable (already failed, no pods):
                    # stay disarmed — the next heartbeat delta re-arms
                continue
            if node is not None:
                due.add(name)
        return due

    def checkpoint_pod(self, rec: PodRecord, now: float) -> Optional[dict]:
        """Snapshot the pod's runtime state through repro.checkpoint: the
        same atomic save/restore path training and elastic scaling use.
        Called on the drain path below, by the periodic background pass,
        and (via the ControlPlane wiring) by the scheduler for preemption
        victims."""
        node_st = self.cluster.node_status.get(rec.pod.node or "")
        if node_st is not None and not node_st.reachable:
            return None                  # kubelet unreachable: can't snapshot
        dep = self.cluster.deployments.get(rec.owner or "")
        provider = dep.template.checkpoint_state if dep else None
        if provider is None:
            return None
        state = provider(rec.name)
        if state is None:
            return None
        if self.ckpt_dir is None:
            self.ckpt_dir = tempfile.mkdtemp(prefix="jiriaf-drain-")
        tree = {k: np.asarray(v) for k, v in state.items()}
        step = self._ckpt_steps.get(rec.name, 0)
        pod_dir = pathlib.Path(self.ckpt_dir) / rec.name
        checkpointer.save(pod_dir, step, tree,
                          meta={"pod": rec.name, "node": rec.pod.node or "",
                                "time": now},
                          retries=self.ckpt_retries, retry_backoff=0.01,
                          timeout=self.ckpt_timeout)
        self._ckpt_steps[rec.name] = step + 1
        # restore from disk so the round trip is exercised, not assumed;
        # a generation that fails verification falls back to the last
        # good one rather than poisoning the restore
        try:
            restored, _meta = checkpointer.restore(
                pod_dir, tree, step=step, retries=self.ckpt_retries,
                retry_backoff=0.01, timeout=self.ckpt_timeout)
        except checkpointer.CheckpointCorruptError:
            restored, _meta = checkpointer.restore(pod_dir, tree)
        self.cluster.record(now, KIND_POD, rec.name, "Checkpointed",
                            f"dir={pod_dir} step={step}")
        if self.tracer is not None:
            self.tracer.span("checkpoint", now, pod=rec.name,
                             node=rec.pod.node or "", step=step)
        return {k: np.asarray(v) for k, v in restored.items()}

    def recover_from_disk(self, pod_name: str, now: float) -> dict:
        """Crash-path recovery: rebuild the pod's last *verified*
        checkpoint generation from disk alone (no live provider — the
        node is gone). Corrupted or truncated generations are skipped in
        favor of the last good one; no usable generation means {}."""
        if self.ckpt_dir is None:
            return {}
        pod_dir = pathlib.Path(self.ckpt_dir) / pod_name
        if not pod_dir.exists():
            return {}
        try:
            state, meta = checkpointer.load_tree(pod_dir)
        except (FileNotFoundError, checkpointer.CheckpointCorruptError,
                OSError):
            return {}
        self.cluster.record(now, KIND_POD, pod_name, "CrashRestored",
                            f"step={meta.get('step')} dir={pod_dir}")
        if self.tracer is not None:
            self.tracer.span("crash_restore", now, pod=pod_name,
                             step=meta.get("step"))
        return {k: np.asarray(v) for k, v in state.items()}

    def _drain_node(self, name: str, now: float):
        self.cluster.cordon(name, now, reason="Draining")
        pods = self.cluster.pods_on(name)
        if self.drain_pods_per_tick > 0:
            pods = pods[:self.drain_pods_per_tick]
        if self.tracer is not None and pods:
            self.tracer.span("drain_node", now, node=name,
                             pods=tuple(r.name for r in pods))
        for rec in pods:
            state = self.checkpoint_pod(rec, now)
            if state:
                self.drain_bytes[rec.name] = sum(
                    int(getattr(v, "nbytes", 0)) for v in state.values())
            evicted = self.cluster.evict(
                rec.name, now, reason="Evicted",
                message=f"node {name} draining")
            if evicted is None:
                continue
            if evicted.owner and self.deployment_ctrl is not None:
                self.deployment_ctrl.park_state(
                    evicted.owner, evicted.name, state or {})
        if not self.cluster.pods_on(name):
            self._drained.add(name)
        else:
            # paced drains continue next pass: keep the node dirty so
            # the event-driven loop returns to it without a new delta
            self._dirty.setdefault(name)

    def drain_allocation(self, names: List[str], now: float):
        """Batch drain a whole pilot allocation (§4.5.4 at site scale):
        cordon every node *first* — so a displaced pod can never be
        re-placed onto a sibling of the same expiring allocation — then
        run one checkpoint/evict wave. Parked state is restored by the
        DeploymentController's replacements, which the scheduler is free
        to re-place cross-site."""
        for name in names:
            if name in self.cluster.nodes:
                self.cluster.cordon(name, now, reason="Draining")
        for name in names:
            if name in self.cluster.nodes:
                self._drain_node(name, now)

    def _fail_node(self, name: str, now: float, why: str):
        st = self.cluster.node_status[name]
        if st.ready:
            self.cluster.set_node_status(name, now, ready=False,
                                         heartbeat_age=st.heartbeat_age)
        for rec in self.cluster.pods_on(name):
            # crash path resumes from the last good on-disk generation
            # (the periodic background pass, or a drain that got partway)
            # instead of the old start-fresh; {} when nothing usable
            state = self.recover_from_disk(rec.name, now)
            evicted = self.cluster.evict(rec.name, now, reason="Evicted",
                                         message=f"node {name} {why}")
            if evicted and evicted.owner and self.deployment_ctrl is not None:
                self.deployment_ctrl.park_state(
                    evicted.owner, evicted.name, state)

    def _background_checkpoints(self, now: float):
        """Periodic phase-1 snapshots of every checkpointable bound pod:
        the generation the crash path falls back to."""
        if self.bg_checkpoint_every <= 0:
            return
        for rec in list(self.cluster.pods.values()):
            if not rec.bound:
                continue
            last = self._last_bg_ckpt.get(rec.name)
            if last is not None and now - last < self.bg_checkpoint_every:
                continue
            try:
                got = self.checkpoint_pod(rec, now)
            except (OSError, checkpointer.CheckpointCorruptError):
                continue                # transient I/O: retry next pass
            if got is not None:
                self._last_bg_ckpt[rec.name] = now

    def _reconcile_node(self, name: str, now: float,
                        to_drain: List[str]) -> None:
        """The per-node reconcile body — shared verbatim between the
        polling scan and the event-driven dirty/due path, so the two
        modes can only differ in *which* nodes they visit, never in what
        they do to one. It is idempotent and convergent: visiting a node
        polling would not have visited is always a no-op."""
        node = self.cluster.nodes.get(name)
        st = self.cluster.node_status.get(name)
        if node is None or st is None:
            return
        if node.walltime > 0 and node.alive_left(now) <= 0:
            if node.ready or st.ready or self.cluster.pods_on(name):
                node.ready = False
                self._fail_node(name, now, "walltime expired")
            return
        # staleness from the node's own heartbeat clock, so dead nodes
        # are caught even when no JFM feed refreshes heartbeat_age
        age = max(st.heartbeat_age, now - node.last_heartbeat)
        stale = age > self.stale_after
        if stale and (st.ready or self.cluster.pods_on(name)):
            self._fail_node(name, now, "heartbeat stale")
            self._not_ready_seen.add(name)
            return
        if not st.ready:
            # flap window: a NotReady report with heartbeats still
            # fresh is NOT failed — wait out stale_after; most flaps
            # recover and cost nothing. (The old code evicted here.)
            self._not_ready_seen.add(name)
            return
        if name in self._not_ready_seen:
            # exactly one recovery event per NotReady episode
            self._not_ready_seen.discard(name)
            self.cluster.record(now, KIND_NODE, name, "NodeRecovered",
                                f"heartbeat_age={age:.0f}")
        if st.reachable and name in self.cluster.fence_epochs:
            # partition healed and the node is back + healthy: fence
            # its stale-epoch orphans before anything can double-serve
            self.cluster.fence_node(name, now)
        if node.draining(now) and name not in self._drained:
            to_drain.append(name)

    def reconcile(self, now: float):
        self._background_checkpoints(now)
        if self.polling:
            names = list(self.cluster.nodes)
        else:
            due = self._pop_due(now)
            # budget selection is dirty-FIFO (oldest first, then due
            # deadlines) so a node that re-dirties itself every pass (a
            # paced drain) cannot starve the rest
            fifo = list(self._dirty)
            fifo += [n for n in due if n not in self._dirty]
            self._dirty = {}
            if self.event_budget and len(fifo) > self.event_budget:
                for n in fifo[self.event_budget:]:
                    self._dirty.setdefault(n)
                fifo = fifo[:self.event_budget]
            # visit in registration order, exactly like the polling
            # scan's dict iteration, so multi-node waves (a shared
            # allocation expiring) produce an identical event trail
            names = sorted(set(fifo),
                           key=lambda n: self._reg_seq.get(n, 1 << 62))
        to_drain: List[str] = []
        for name in names:
            self._reconcile_node(name, now, to_drain)
        # same-pass expirations (one pilot allocation typically shares a
        # lease) drain as a single wave: cordon all first, then evict
        if to_drain:
            self.drain_allocation(to_drain, now)


@dataclass
class ControlPlane:
    """Store + scheduler + controllers behind one reconcile call.

    ``step`` is a *dispatch pump*: between ticks, watch deltas accumulate
    into each controller's dirty set (and the scheduler's capacity index
    and wake flags); one ``step`` drains them — lifecycle deadlines and
    dirty nodes, dirty Deployments, then the pending queue. ``polling``
    reproduces the pre-event-driven plane exactly (every object dirty
    every tick, full-scan placement, no wake): the differential harness
    in tests/test_event_plane.py runs both modes over the same scenario
    scripts and asserts identical stores, event trails, and token
    outputs. ``event_budget`` caps dirty objects reconciled per
    controller per tick; the remainder carries to the next tick."""
    cluster: Cluster
    scheduler: Scheduler = None
    deployments: DeploymentController = None
    nodes: NodeLifecycleController = None
    polling: bool = False
    event_budget: int = 0
    # failover cost hook: called as ``on_transfer(now, window_s)`` when a
    # drain_site wave re-binds displaced pods cross-site — window_s is
    # the topology-modeled checkpoint-transfer time the evacuation pays
    # (the engine serves degraded for its duration)
    on_transfer: object = None
    last_transfer_s: float = 0.0
    last_transfer_bytes: int = 0
    # observability plane (optional): ``tracer`` propagates to the
    # scheduler/lifecycle controller on first wire; ``profiler`` times
    # the three phases of every ``step``
    tracer: object = None
    profiler: object = None

    def __post_init__(self):
        if self.scheduler is None:
            self.scheduler = Scheduler(self.cluster)
        if self.deployments is None:
            self.deployments = DeploymentController(self.cluster)
        if self.nodes is None:
            self.nodes = NodeLifecycleController(
                self.cluster, deployment_ctrl=self.deployments)
        elif self.nodes.deployment_ctrl is None:
            self.nodes.deployment_ctrl = self.deployments
        if self.scheduler.checkpoint_cb is None:
            # preemption victims take the same §4.5.4 checkpoint path as
            # drained pods, so a preempted batch job resumes where it was
            self.scheduler.checkpoint_cb = self.nodes.checkpoint_pod
        if self.polling:
            self.deployments.polling = True
            self.nodes.polling = True
            self.scheduler.use_index = False
            self.scheduler.wake_on_freed = False
        if self.event_budget:
            self.deployments.event_budget = self.event_budget
            self.nodes.event_budget = self.event_budget

    def step(self, now: float):
        """One control-plane tick: lifecycle first (drains/evictions free
        capacity and park state), then replica convergence, then binding."""
        if self.profiler is None:
            self.nodes.reconcile(now)
            self.deployments.reconcile(now)
            return self.scheduler.run_once(now)
        with self.profiler.phase("tick.nodes_reconcile"):
            self.nodes.reconcile(now)
        with self.profiler.phase("tick.deploy_reconcile"):
            self.deployments.reconcile(now)
        with self.profiler.phase("tick.schedule"):
            return self.scheduler.run_once(now)

    def drain_site(self, site: str, now: float):
        """Evacuate one whole facility (kill / maintenance / superseded
        pilot): batch-drain every node of ``site`` as a single
        checkpoint/evict wave, then converge replicas and re-bind them —
        cross-site, with restored state — in the same call."""
        names = [n.name for n in self.cluster.site_nodes(site)]
        self.cluster.record(now, "Node", site, "SiteDrain",
                            f"nodes={len(names)}")
        self.nodes.drain_bytes.clear()
        self.nodes.drain_allocation(names, now)
        moved = dict(self.nodes.drain_bytes)
        self.deployments.reconcile(now)
        out = self.scheduler.run_once(now)
        # cost-modeled failover: checkpoint state does not teleport — pay
        # the topology's transfer time for every displaced pod that
        # re-bound at another site, take the max as the evacuation window
        # (transfers run in parallel) and report it to the engine so it
        # serves degraded until the state has actually arrived
        topo = getattr(self.scheduler, "topology", None)
        window, total = 0.0, 0
        if topo is not None and moved:
            for rec in self.cluster.pods.values():
                src_pod = rec.restored_from
                if src_pod not in moved or not rec.bound:
                    continue
                node = self.cluster.nodes.get(rec.pod.node)
                if node is not None and node.site != site:
                    window = max(window, topo.transfer_cost(
                        moved[src_pod], site, node.site))
                    total += moved[src_pod]
        self.last_transfer_s = window
        self.last_transfer_bytes = total
        if window > 0:
            self.cluster.record(now, "Node", site, "SiteDrainTransfer",
                                f"bytes={total} window={window:.3f}s")
            if self.tracer is not None:
                self.tracer.span("transfer_window", now, site=site,
                                 window=window, bytes=total)
            if self.on_transfer is not None:
                self.on_transfer(now, window)
        return out
