"""JFE — JIRIAF Front End (paper §3, §4.5.2): user workflow request table.

Mirrors the FireWorks main.sh verbs: add_wf / get_wf / delete_wf. A
workflow requests N nodes of a nodetype/site with a walltime — exactly the
env.list fields from §4.5.2 (nnodes, nodetype, walltime, account, qos,
nodename, site).

Post-PR-1 role: the JFE owns nothing but the request table — it is the
user-facing intake ahead of the declarative control plane; the JCS turns
its rows into pilots and the Cluster store's controllers do the rest.

Federation (this PR): ``add_multi_wf`` files one site-scoped
WorkflowRequest per facility under a shared ``group`` id, so a single
user workflow can target JLab + NERSC + ... at once (the §1 cross-
facility claim); ``JCS.launch_multi`` deploys the group as one pilot per
site."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class WorkflowRequest:
    wf_id: int
    nodename: str
    nnodes: int
    nodetype: str = "cpu"
    site: str = "perlmutter"
    walltime: float = 300.0
    account: str = "m3792"
    qos: str = "debug"
    state: str = "READY"      # READY -> RUNNING -> COMPLETED | ARCHIVED
    group: Optional[str] = None   # multi-site workflow this row belongs to


@dataclass
class FrontEnd:
    _counter: itertools.count = field(default_factory=lambda: itertools.count(1))
    _groups: itertools.count = field(default_factory=lambda: itertools.count(1))
    table: Dict[int, WorkflowRequest] = field(default_factory=dict)

    def add_wf(self, nodename: str, nnodes: int, **kw) -> WorkflowRequest:
        wf = WorkflowRequest(next(self._counter), nodename, nnodes, **kw)
        self.table[wf.wf_id] = wf
        return wf

    def add_multi_wf(self, nodename: str, site_nodes: Dict[str, int],
                     **kw) -> List[WorkflowRequest]:
        """One workflow spanning several facilities: a site-scoped request
        per entry of ``site_nodes`` (site -> nnodes), all sharing one
        ``group`` id (unique per call — two multi-site workflows never
        merge)."""
        group = f"{nodename}g{next(self._groups)}"
        return [self.add_wf(f"{nodename}{site}-", nnodes, site=site,
                            group=group, **kw)
                for site, nnodes in site_nodes.items()]

    def group_wfs(self, group: str) -> List[WorkflowRequest]:
        return [wf for wf in self.table.values() if wf.group == group]

    def get_wf(self) -> List[WorkflowRequest]:
        return list(self.table.values())

    def delete_wf(self, wf_id: int) -> Optional[WorkflowRequest]:
        wf = self.table.pop(wf_id, None)
        if wf:
            wf.state = "ARCHIVED"
        return wf
