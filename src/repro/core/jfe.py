"""JFE — JIRIAF Front End: user workflow request table (paper §3, §4.5.2).

Mirrors the FireWorks main.sh verbs: add_wf / get_wf / delete_wf. A
workflow requests N nodes of a nodetype/site with a walltime — exactly the
env.list fields from §4.5.2 (nnodes, nodetype, walltime, account, qos,
nodename, site)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class WorkflowRequest:
    wf_id: int
    nodename: str
    nnodes: int
    nodetype: str = "cpu"
    site: str = "perlmutter"
    walltime: float = 300.0
    account: str = "m3792"
    qos: str = "debug"
    state: str = "READY"      # READY -> RUNNING -> COMPLETED | ARCHIVED


@dataclass
class FrontEnd:
    _counter: itertools.count = field(default_factory=lambda: itertools.count(1))
    table: Dict[int, WorkflowRequest] = field(default_factory=dict)

    def add_wf(self, nodename: str, nnodes: int, **kw) -> WorkflowRequest:
        wf = WorkflowRequest(next(self._counter), nodename, nnodes, **kw)
        self.table[wf.wf_id] = wf
        return wf

    def get_wf(self) -> List[WorkflowRequest]:
        return list(self.table.values())

    def delete_wf(self, wf_id: int) -> Optional[WorkflowRequest]:
        wf = self.table.pop(wf_id, None)
        if wf:
            wf.state = "ARCHIVED"
        return wf
