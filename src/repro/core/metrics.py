"""Prometheus-operator analog (paper §4.6): metric registry, Services,
ServiceMonitors, and a scraping Prometheus instance with a tiny TSDB.

Pods created by VK share VKUBELET_POD_IP, so §4.6.3's same-pod-IP case is
modeled: Services must remap exporter ports to unique control-plane ports
(enforced at Service construction)."""
from __future__ import annotations

import bisect
import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

#: Bucket ladder for count-valued distributions (slot/page occupancy
#: peaks): powers of two up to a large pool, so the HPA/twin sees the
#: shape of per-tick peaks instead of a last-write-wins gauge.
COUNT_BUCKETS = (0.0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024,
                 4096, math.inf)


def _series_key(name: str, labels: Optional[Dict[str, str]]) -> str:
    """Canonical series key: ``name`` or ``name{k="v",...}`` with label
    keys sorted, so the same label set always maps to one series."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


def split_series(key: str) -> Tuple[str, str]:
    """Inverse-ish of ``_series_key``: ('base', '{k="v",...}' or '')."""
    i = key.find("{")
    if i < 0:
        return key, ""
    return key[:i], key[i:]


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, v: float = 1.0):
        self.value += v


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float):
        self.value = v

    def inc(self, v: float = 1.0):
        self.value += v

    def dec(self, v: float = 1.0):
        self.value -= v


@dataclass
class Histogram:
    buckets: Tuple[float, ...] = (0.005, 0.05, 0.5, 1, 5, 30, 120, math.inf)
    counts: List[int] = None
    total: float = 0.0
    n: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = [0] * len(self.buckets)

    def observe(self, v: float):
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.total += v
        self.n += 1

    @property
    def mean(self):
        return self.total / self.n if self.n else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile by linear interpolation within the
        bucket holding the target rank (Prometheus histogram_quantile
        semantics). Empty histogram -> 0.0; mass in the +Inf bucket
        reports the largest finite bound."""
        if self.n == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        rank = q * self.n
        acc = 0.0
        prev = 0.0
        for bound, cnt in zip(self.buckets, self.counts):
            if cnt:
                acc += cnt
                if acc >= rank:
                    if math.isinf(bound):
                        return prev
                    return prev + (bound - prev) * (1.0 - (acc - rank) / cnt)
            if not math.isinf(bound):
                prev = bound
        return prev


@dataclass
class Registry:
    """Per-pod exporter: metric name -> metric, exposed on a port."""
    port: int = 2221
    metrics: Dict[str, object] = field(default_factory=dict)

    def counter(self, name, labels: Optional[Dict[str, str]] = None) \
            -> Counter:
        key = _series_key(name, labels)
        m = self.metrics.get(key)
        if m is None:
            m = self.metrics[key] = Counter()
        return m

    def gauge(self, name, labels: Optional[Dict[str, str]] = None) -> Gauge:
        key = _series_key(name, labels)
        m = self.metrics.get(key)
        if m is None:
            m = self.metrics[key] = Gauge()
        return m

    def histogram(self, name, labels: Optional[Dict[str, str]] = None,
                  **kw) -> Histogram:
        key = _series_key(name, labels)
        m = self.metrics.get(key)
        if m is None:
            m = self.metrics[key] = Histogram(**kw)
        return m

    def collect(self) -> Dict[str, float]:
        out = {}
        for key, m in self.metrics.items():
            base, lbl = split_series(key)
            if isinstance(m, Histogram):
                out[base + "_sum" + lbl] = m.total
                out[base + "_count" + lbl] = m.n
            else:
                out[key] = m.value
        return out


@dataclass
class Endpoint:
    pod: str
    pod_ip: str
    port: int                 # exporter port on the pod
    cp_port: int              # remapped control-plane port (§4.6.3)
    registry: Registry


@dataclass
class Service:
    """Aggregates exporter endpoints of pods selected by label (§4.6.2).
    When pod IPs collide, cp_port remapping keeps endpoints distinct."""
    name: str
    selector: Dict[str, str]
    labels: Dict[str, str] = field(default_factory=dict)
    endpoints: List[Endpoint] = field(default_factory=list)

    def add_endpoint(self, ep: Endpoint):
        for e in self.endpoints:
            if e.pod_ip == ep.pod_ip and e.cp_port == ep.cp_port:
                raise ValueError(
                    f"service {self.name}: duplicate {ep.pod_ip}:{ep.cp_port}"
                    " — same-pod-IP endpoints must remap to unique CP ports"
                    " (paper §4.6.3)")
        self.endpoints.append(ep)

    def selects(self, pod_labels: Dict[str, str]) -> bool:
        return all(pod_labels.get(k) == v for k, v in self.selector.items())


@dataclass
class ServiceMonitor:
    name: str
    service_selector: Dict[str, str]

    def selects(self, svc: Service) -> bool:
        return all(svc.labels.get(k) == v
                   for k, v in self.service_selector.items())


@dataclass
class Prometheus:
    """Scrapes all endpoints of all Services matched by its ServiceMonitors
    into an in-memory TSDB: series[(metric, pod)] = [(t, value), ...]."""
    monitors: List[ServiceMonitor] = field(default_factory=list)
    services: List[Service] = field(default_factory=list)
    tsdb: Dict[Tuple[str, str], List[Tuple[float, float]]] = \
        field(default_factory=lambda: defaultdict(list))

    def scrape(self, now: float):
        n = 0
        for mon in self.monitors:
            for svc in self.services:
                if not mon.selects(svc):
                    continue
                for ep in svc.endpoints:
                    for name, val in ep.registry.collect().items():
                        self.tsdb[(name, ep.pod)].append((now, val))
                        n += 1
        return n

    def query_latest(self, metric: str) -> Dict[str, float]:
        out = {}
        for (name, pod), series in self.tsdb.items():
            if name == metric and series:
                out[pod] = series[-1][1]
        return out

    def query_range(self, metric: str, pod: str):
        return self.tsdb.get((metric, pod), [])
