"""JFM — JIRIAF Facility Manager: maintains the dynamic resource pool by
periodically scraping node state from each facility (paper §3)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.jrm import VirtualNode


@dataclass
class ResourceRecord:
    node: str
    site: str
    nodetype: str
    ready: bool
    free_chips: int
    free_hbm: int
    alive_left: float
    heartbeat_age: float
    heartbeat_latency: float
    straggler: bool = False


@dataclass
class FacilityManager:
    stale_after: float = 30.0          # heartbeats older than this = NotReady
    straggler_factor: float = 3.0      # latency > factor * median => straggler
    pool: Dict[str, ResourceRecord] = field(default_factory=dict)

    def scrape(self, nodes: List[VirtualNode], now: float) -> Dict[str, ResourceRecord]:
        lats = sorted(n.heartbeat_latency for n in nodes) or [0.0]
        median = lats[len(lats) // 2]
        self.pool = {}
        for n in nodes:
            age = now - n.last_heartbeat
            ready = n.ready and age <= self.stale_after
            self.pool[n.name] = ResourceRecord(
                node=n.name, site=n.site, nodetype=n.nodetype, ready=ready,
                free_chips=n.free_chips(), free_hbm=n.free_hbm(),
                alive_left=n.alive_left(now), heartbeat_age=age,
                heartbeat_latency=n.heartbeat_latency,
                straggler=(median > 0 and
                           n.heartbeat_latency > self.straggler_factor * median))
        return self.pool

    def feed(self, cluster, now: float) -> Dict[str, ResourceRecord]:
        """Declarative-control-plane role: JFM is a node-heartbeat feeder.

        Scrapes every node registered in the Cluster store and writes the
        derived condition (ready/staleness/straggler) back as NodeStatus,
        so the scheduler and the NodeLifecycleController consume one
        authoritative view instead of each poking nodes directly."""
        pool = self.scrape(list(cluster.nodes.values()), now)
        for name, rec in pool.items():
            cluster.set_node_status(
                name, now, ready=rec.ready,
                heartbeat_age=rec.heartbeat_age,
                heartbeat_latency=rec.heartbeat_latency,
                straggler=rec.straggler)
        return pool

    def available(self) -> List[ResourceRecord]:
        return [r for r in self.pool.values() if r.ready and r.free_chips > 0]

    def total_free_chips(self) -> int:
        return sum(r.free_chips for r in self.available())
