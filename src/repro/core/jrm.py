"""JRM — JIRIAF Resource Manager: Virtual-Kubelet nodes in userspace.

A ``VirtualNode`` is the VK of paper §4.1: labels jiriaf.nodetype /
jiriaf.site / jiriaf.alivetime, a walltime lease (NotReady when it expires
— the VK process is NOT terminated, per §4.2.3), the mock-provider taint,
and CreatePod/GetPods loops driving the §4.3 state machines.

TPU adaptation: a node fronts a mesh *slice* (chips + HBM). Containers are
jitted-workload thunks; the "pgid" is the workload handle. The §4.5.4
walltime margin is modeled by ``drain_margin``: pods are asked to
checkpoint when remaining lease < margin.

Post-PR-1 role: *owner* of the node-local truth — pod placement on the
node, container state machines, the walltime lease clock, and resource
accounting (free chips/HBM). Everything cluster-scoped (which node a pod
SHOULD land on, when to drain, replica counts) moved up into the
declarative control plane (``cluster.py`` + scheduler + controllers); the
``site`` identity on each node is what the federation layer's per-site
pools and site-aware scheduling stages key on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.state_machine import (Condition, ConditionStatus, Container,
                                      Pod, PodPhase, create_pod_container,
                                      get_pods_container)

DEFAULT_TAINT = {"key": "virtual-kubelet.io/provider", "value": "mock",
                 "effect": "NoSchedule"}


@dataclass
class SliceSpec:
    """The resources a node leases (TPU adaptation of a Slurm allocation)."""
    chips: int = 4
    hbm_bytes_per_chip: int = 16 * 1024**3
    devices: tuple = ()

    @property
    def hbm_bytes(self):
        return self.chips * self.hbm_bytes_per_chip


@dataclass
class VirtualNode:
    name: str
    nodetype: str = "cpu"
    site: str = "Local"
    walltime: float = 0.0            # 0 => no limit (JIRIAF_WALLTIME)
    slice_spec: SliceSpec = field(default_factory=SliceSpec)
    kubelet_port: int = 10250
    pod_ip: str = "172.17.0.1"       # VKUBELET_POD_IP
    drain_margin: float = 60.0       # §4.5.4: JRM walltime set 60s early
    created_at: float = 0.0
    taints: List[dict] = field(default_factory=lambda: [dict(DEFAULT_TAINT)])
    pods: Dict[str, Pod] = field(default_factory=dict)
    ready: bool = True
    last_heartbeat: float = 0.0
    heartbeat_latency: float = 0.0   # straggler signal for JMS placement

    # ----------------------------------------------------------- labels
    def labels(self, now: float) -> Dict[str, str]:
        lab = {
            "jiriaf.nodetype": self.nodetype,
            "jiriaf.site": self.site,
            "kubernetes.io/role": "agent",
        }
        if self.walltime > 0:
            lab["jiriaf.alivetime"] = str(max(0, int(self.alive_left(now))))
        return lab

    def alive_left(self, now: float) -> float:
        if self.walltime <= 0:
            return float("inf")
        return self.walltime - (now - self.created_at)

    def draining(self, now: float) -> bool:
        left = self.alive_left(now)
        return left != float("inf") and left <= self.drain_margin

    # ------------------------------------------------------------ pods
    def create_pod(self, pod: Pod, now: float) -> Pod:
        """CreatePod (§4.3): run every container through the create walk,
        then set creation-phase conditions."""
        if not self.tolerates(pod):
            raise PermissionError(
                f"pod {pod.name} lacks toleration for node taints")
        for cont in pod.containers:
            create_pod_container(cont, now)
        pod.node = self.name
        pod.set_conditions_create(now)
        self.pods[pod.name] = pod
        return pod

    def get_pods(self, now: float) -> List[Pod]:
        """GetPods (§4.3): refresh container states and pod conditions."""
        for pod in self.pods.values():
            for cont in pod.containers:
                get_pods_container(cont, now)
            pod.set_conditions_get(now)
        return list(self.pods.values())

    def delete_pod(self, name: str, now: float):
        """SIGTERM to the process group (pgid file) in the paper; workload
        cancellation here."""
        pod = self.pods.pop(name, None)
        if pod:
            for cont in pod.containers:
                cont.terminate(now)
        return pod

    def tolerates(self, pod: Pod) -> bool:
        for taint in self.taints:
            ok = any(t.get("key") == taint["key"] and
                     t.get("value") == taint["value"]
                     for t in pod.tolerations)
            if not ok:
                return False
        return True

    # ------------------------------------------------------------ tick
    def tick(self, now: float, latency: float = 0.0):
        """Heartbeat + walltime bookkeeping. On lease expiry the node turns
        NotReady but the VK process is not terminated (paper §4.2.3)."""
        self.last_heartbeat = now
        self.heartbeat_latency = latency
        if self.walltime > 0 and self.alive_left(now) <= 0:
            self.ready = False
        return self.ready

    def matches(self, expressions: List[dict], now: float) -> bool:
        """nodeAffinity matchExpressions: In / NotIn / Gt / Lt (§4.2.3)."""
        lab = self.labels(now)
        for expr in expressions:
            key, op = expr["key"], expr["operator"]
            vals = [str(v) for v in expr.get("values", [])]
            have = lab.get(key)
            if op == "In":
                if have not in vals:
                    return False
            elif op == "NotIn":
                if have in vals:
                    return False
            elif op == "Gt":
                if have is None or not vals or not float(have) > float(vals[0]):
                    return False
            elif op == "Lt":
                if have is None or not vals or not float(have) < float(vals[0]):
                    return False
            elif op == "Exists":
                if have is None:
                    return False
        return True

    def cut_walltime(self, now: float, remaining: float) -> float:
        """Facility-side lease revision (``scontrol update`` analog): the
        allocation now expires ``remaining`` seconds from ``now``. The
        chaos injector's walltime-cut fault goes through this seam so a
        drain can be caught mid-flight by an early expiry."""
        self.walltime = (now - self.created_at) + max(remaining, 0.0)
        return self.walltime

    # ------------------------------------------------------- resources
    def used_chips(self) -> int:
        return sum(p.request_chips for p in self.pods.values()
                   if p.phase in (PodPhase.PENDING, PodPhase.RUNNING))

    def used_hbm(self) -> int:
        return sum(p.request_hbm_bytes for p in self.pods.values()
                   if p.phase in (PodPhase.PENDING, PodPhase.RUNNING))

    def free_chips(self) -> int:
        return self.slice_spec.chips - self.used_chips()

    def free_hbm(self) -> int:
        return self.slice_spec.hbm_bytes - self.used_hbm()


def start_vk(nodename: str, *, nodetype="cpu", site="Local", walltime=0.0,
             kubelet_port=10250, pod_ip="172.17.0.1", now=0.0,
             slice_spec: Optional[SliceSpec] = None) -> VirtualNode:
    """start.sh analog (§4.1.1): environment-variable driven bring-up."""
    return VirtualNode(
        name=nodename, nodetype=nodetype, site=site, walltime=walltime,
        kubelet_port=kubelet_port, pod_ip=pod_ip, created_at=now,
        slice_spec=slice_spec or SliceSpec(), last_heartbeat=now)
