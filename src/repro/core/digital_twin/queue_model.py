"""Queue system model for the digital twin (paper §6).

Embeds Tables 8/9 verbatim, Eq. (3) M/M/1 theory, the §6.2 piecewise
ground-truth trajectory, and a discrete-time stochastic queue simulator
used by the benchmarks ("simulated stream processing system": a sender and
a receiver with a FIFO queue — ERSAP pipeline analog)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

# ---- Table 8: System Metrics for 16 Threads (state, lam, mu, units, obs, calc)
TABLE_16 = np.array([
    # state  lambda   mu      units  obs_lq  calc_lq
    [0, 162.0, 167.0, 16.0, 32.0, 33.74],
    [1, 163.0, 167.0, 16.0, 41.0, 43.48],
    [2, 164.0, 167.0, 16.0, 58.0, 60.52],
    [3, 165.0, 167.0, 16.0, 97.0, 98.01],
    [4, 166.0, 167.0, 16.0, 241.0, 248.00],
])

# ---- Table 9: System Metrics for 32 Threads ----
TABLE_32 = np.array([
    [0, 162.0, 222.0, 32.0, 1.56, 1.96],
    [1, 163.0, 222.0, 32.0, 2.5, 2.02],
    [2, 164.0, 222.0, 32.0, 2.56, 2.08],
    [3, 165.0, 222.0, 32.0, 3.5, 2.14],
    [4, 166.0, 222.0, 32.0, 3.56, 2.21],
])

N_STATES = 5
CONTROLS = (16, 32)

# The paper prints mu=167 in Table 8, but its Calc.Lq column is only
# reproducible with mu = 500/3 ~= 166.67 (e.g. state 4: 166^2/(166.67*0.67)
# = 248.0, whereas mu=167 gives 165.0). Table 9's mu=222 is exact. We keep
# the printed values in the tables and expose the recovered exact rates here.
MU_EXACT = {16: 500.0 / 3.0, 32: 222.0}


def calc_lq(lam: float, mu: float) -> float:
    """Eq. (3): L_q = lambda^2 / (mu * (mu - lambda))."""
    if mu <= lam:
        return float("inf")
    return lam * lam / (mu * (mu - lam))


def table_for(threads: int) -> np.ndarray:
    if threads == 16:
        return TABLE_16
    if threads == 32:
        return TABLE_32
    raise ValueError(threads)


def obs_lq(state: float, threads: int) -> float:
    """Interpolated observed queue length for a (possibly fractional) state."""
    tab = table_for(threads)
    return float(np.interp(np.clip(state, 0, N_STATES - 1),
                           tab[:, 0], tab[:, 4]))


def lam_of_state(state: float) -> float:
    return float(np.interp(np.clip(state, 0, N_STATES - 1),
                           TABLE_16[:, 0], TABLE_16[:, 1]))


def ground_truth(n_steps: int = 80) -> np.ndarray:
    """§6.2 piecewise state trajectory (clipped to the table's state range)."""
    s = 0.0
    out = []
    for t in range(n_steps):
        if t < 10:
            s += 0.4
        elif 20 <= t < 30:
            s -= 0.4
        elif 40 <= t < 50:
            s += 0.4
        elif 60 <= t < 70:
            s -= 0.4
        s = float(np.clip(s, 0.0, N_STATES - 1))
        out.append(s)
    return np.asarray(out)


def observe(state: float, threads: int, rng: np.random.Generator,
            noise_frac: float = 0.08) -> float:
    """Noisy Lq measurement around the interpolated table value."""
    mean = obs_lq(state, threads)
    return float(max(rng.normal(mean, noise_frac * mean), 1e-3))


@dataclass
class QueueSim:
    """Discrete-time M/M/1-ish stream queue: Poisson arrivals at lambda(state),
    service rate mu(threads). Used by bench_queue to regenerate Tables 8/9."""
    threads: int = 16
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.q = 0.0

    def mu(self) -> float:
        return MU_EXACT[self.threads]

    def run(self, lam: float, steps: int = 20000, dt: float = 0.01):
        """Simulate and return time-averaged queue length (excluding the
        in-service item: L_q)."""
        q = 0
        area = 0.0
        busy = 0.0
        mu = self.mu()
        for _ in range(steps):
            arrivals = self.rng.poisson(lam * dt)
            q += arrivals
            if q > 0:
                served = self.rng.poisson(mu * dt)
                q = max(q - served, 0)
                busy += dt
            area += q * dt
        return area / (steps * dt)
