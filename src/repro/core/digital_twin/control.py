"""Digital-twin control policy (paper §6.3).

The twin recommends the processing capacity (16 vs 32 threads in the
paper; N vs 2N serving replicas in the TPU adaptation): switch UP when the
expected queue length under the current control crosses ``lq_high``;
switch DOWN when even the low-capacity configuration would keep the queue
under ``lq_low``. A small hysteresis/switch cost prevents thrashing —
matching the control regions of Fig. 8."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

from repro.core.digital_twin.dbn import DigitalTwin
from repro.core.digital_twin.queue_model import CONTROLS


@dataclass
class ControlPolicy:
    lq_high: float = 55.0            # escalate when E[Lq|u=16] above this
    lq_low: float = 40.0             # de-escalate when E[Lq|16] below this
    horizon: int = 2                 # predictive steps (the "twin" advantage)
    history: List[Tuple[float, int, float]] = field(default_factory=list)

    def recommend(self, twin: DigitalTwin, current: int, now: float) -> int:
        lq16 = twin.expected_lq(16, self.horizon)
        rec = current
        if current == 16 and lq16 > self.lq_high:
            rec = 32
        elif current == 32 and lq16 < self.lq_low:
            rec = 16
        self.history.append((now, rec, lq16))
        return rec


def replicas_for_control(control: int, base_replicas: int = 1) -> int:
    """TPU adaptation: 16 threads -> N replicas, 32 threads -> 2N."""
    return base_replicas * (2 if control == 32 else 1)
