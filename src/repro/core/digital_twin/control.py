"""Digital-twin control policy (paper §6.3), extended to QoS actions.

The twin recommends the processing capacity (16 vs 32 threads in the
paper; N vs 2N serving replicas in the TPU adaptation): switch UP when the
expected queue length under the current control crosses ``lq_high``;
switch DOWN when even the low-capacity configuration would keep the queue
under ``lq_low``. A small hysteresis/switch cost prevents thrashing —
matching the control regions of Fig. 8.

``recommend_action`` extends those control regions to a **(replicas,
priority) action space**: alongside the capacity decision the policy
recommends the serving Deployment's priority class — escalated to
``latency-critical`` while the twin predicts a pressure spike (or the
serving slab's memory-pressure gauge runs hot), dropped back to
``standard`` once both signals clear a hysteresis band. On a shared
cluster the priority write is what makes the capacity write *landable*:
the scale-up replica preempts batch work instead of queueing behind
it."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

from repro.core.digital_twin.dbn import DigitalTwin
from repro.core.digital_twin.queue_model import CONTROLS


@dataclass
class ControlPolicy:
    lq_high: float = 55.0            # escalate when E[Lq|u=16] above this
    lq_low: float = 40.0             # de-escalate when E[Lq|16] below this
    horizon: int = 2                 # predictive steps (the "twin" advantage)
    history: List[Tuple[float, int, float]] = field(default_factory=list)
    # (replicas, priority) action space: the serving tier under pressure
    # and at rest, plus the slab-occupancy band that can force the high
    # tier even while the queue model still reads calm
    prio_high: str = "latency-critical"
    prio_low: str = "standard"
    occupancy_high: float = 0.9
    occupancy_low: float = 0.5
    action_history: List[Tuple[float, int, str]] = field(default_factory=list)

    def recommend(self, twin: DigitalTwin, current: int, now: float) -> int:
        lq16 = twin.expected_lq(16, self.horizon)
        rec = current
        if current == 16 and lq16 > self.lq_high:
            rec = 32
        elif current == 32 and lq16 < self.lq_low:
            rec = 16
        self.history.append((now, rec, lq16))
        return rec

    def recommend_action(self, twin: DigitalTwin, current: int, now: float,
                         occupancy: float = 0.0) -> Tuple[int, str]:
        """One (control, priority_class) recommendation. Priority follows
        the same predicted-pressure signal as capacity (escalated control
        => escalated tier) with ``occupancy`` as a second trigger, and a
        hysteresis band in between (keep the previous tier) so the tier
        does not flap while the queue hovers between the thresholds."""
        control = self.recommend(twin, current, now)
        prev = self.action_history[-1][2] if self.action_history \
            else self.prio_low
        if control == 32 or occupancy >= self.occupancy_high:
            pclass = self.prio_high
        elif occupancy <= self.occupancy_low:
            pclass = self.prio_low
        else:
            pclass = prev
        self.action_history.append((now, control, pclass))
        return control, pclass


def replicas_for_control(control: int, base_replicas: int = 1) -> int:
    """TPU adaptation: 16 threads -> N replicas, 32 threads -> 2N."""
    return base_replicas * (2 if control == 32 else 1)
