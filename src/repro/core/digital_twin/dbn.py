"""Dynamic Bayesian Network digital twin (paper §6, after Kapteyn et al.).

Nodes per §6.1 / Fig. 7: digital state D(t) in {0..4}, control U(t) in
{16, 32}, observation O(t) = measured queue length. Filtering and
prediction are VECTORIZED JAX (jit-compiled einsums over the CPTs) — the
twin runs inside the same JAX runtime as the workloads it supervises.

  belief_t  ∝  P(O_t | D_t, U_t) * sum_{D'} P(D_t | D_{t-1}=D') belief_{t-1}

The observation CPT is a log-normal around the Table 8/9 interpolated
queue lengths (queue lengths span 1.5 .. 248, so log-space keeps states
distinguishable — the paper's §6.4 notes indistinguishable Calc.Lq as a
failure mode; log-space is our mitigation)."""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.digital_twin.queue_model import (CONTROLS, N_STATES,
                                                 TABLE_16, TABLE_32)


def transition_matrix(p_stay: float = 0.6, p_step: float = 0.2) -> jnp.ndarray:
    """Reflecting random-walk CPT P(D_t | D_{t-1}) over 5 states."""
    T = np.zeros((N_STATES, N_STATES))
    for s in range(N_STATES):
        T[s, s] += p_stay
        T[s, max(s - 1, 0)] += p_step
        T[s, min(s + 1, N_STATES - 1)] += p_step
    return jnp.asarray(T / T.sum(axis=1, keepdims=True))


def observation_means() -> jnp.ndarray:
    """(n_controls, n_states) mean Obs.Lq from Tables 8/9."""
    return jnp.asarray(np.stack([TABLE_16[:, 4], TABLE_32[:, 4]]))


@functools.partial(jax.jit, static_argnames=())
def _filter_step(belief, obs, u_idx, trans, means, sigma):
    pred = belief @ trans                                   # (S,)
    mu_log = jnp.log(means[u_idx])                          # (S,)
    ll = -0.5 * jnp.square((jnp.log(obs) - mu_log) / sigma)
    like = jnp.exp(ll - jnp.max(ll))
    post = pred * like
    return post / jnp.maximum(post.sum(), 1e-30)


@functools.partial(jax.jit, static_argnames=("k_steps",))
def _predict(belief, trans, k_steps):
    def step(b, _):
        return b @ trans, None
    out, _ = jax.lax.scan(step, belief, None, length=k_steps)
    return out


@dataclass
class DigitalTwin:
    sigma: float = 0.25              # log-space observation noise
    trans: jnp.ndarray = field(default_factory=transition_matrix)
    means: jnp.ndarray = field(default_factory=observation_means)
    belief: jnp.ndarray = field(
        default_factory=lambda: jnp.ones(N_STATES) / N_STATES)

    def assimilate(self, obs_lq: float, control: int) -> jnp.ndarray:
        """One filtering update given a queue-length measurement under the
        currently applied control."""
        u_idx = CONTROLS.index(control)
        self.belief = _filter_step(self.belief, jnp.float32(obs_lq),
                                   u_idx, self.trans, self.means,
                                   jnp.float32(self.sigma))
        return self.belief

    def estimate(self) -> float:
        """Posterior-mean state."""
        return float(jnp.sum(self.belief * jnp.arange(N_STATES)))

    def map_state(self) -> int:
        return int(jnp.argmax(self.belief))

    def predict(self, k_steps: int = 1) -> jnp.ndarray:
        return _predict(self.belief, self.trans, k_steps)

    def expected_lq(self, control: int, k_steps: int = 1) -> float:
        """E[Lq] under `control` after k prediction steps."""
        b = self.predict(k_steps)
        u_idx = CONTROLS.index(control)
        return float(jnp.sum(b * self.means[u_idx]))

    def reset(self):
        self.belief = jnp.ones(N_STATES) / N_STATES
