"""Horizontal Pod Autoscaler — paper §4.4.

Implements Eq. (1): desired = ceil(current * metric / target), with the
readiness-gating logic of the Kubernetes replica calculator quoted in
§4.4.2 (cpuInitializationPeriod / delayOfInitialReadinessStatus) and the
five-minute scale-down stabilization window observed in §4.4.5.

The metric is pluggable: the paper uses CPU utilization; the TPU serving
adaptation feeds queue depth / tokens-per-second from the streaming engine
(see DESIGN.md §2) — the formula and gating are identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.state_machine import ConditionStatus, Pod


@dataclass
class HPAConfig:
    target: float                      # target metric value per pod
    min_replicas: int = 1
    max_replicas: int = 10
    cpu_initialization_period: float = 300.0
    delay_of_initial_readiness: float = 30.0
    scale_down_stabilization: float = 300.0   # §4.4.5: five minutes
    tolerance: float = 0.1             # K8s default: 10% deadband
    metric_window: float = 60.0


@dataclass
class MetricSample:
    value: float
    timestamp: float
    window: float = 60.0


def pod_is_unready(pod: Pod, sample: Optional[MetricSample], now: float,
                   cfg: HPAConfig, resource_is_cpu_like: bool = True) -> bool:
    """Faithful port of the §4.4.2 snippet."""
    if not resource_is_cpu_like:
        return False
    cond = pod.condition("PodReady")
    if cond is None or pod.start_time is None:
        return True
    if pod.start_time + cfg.cpu_initialization_period > now:
        # within initialization: unready if not Ready OR the sample predates
        # the last readiness transition (+ window)
        return (cond.status == ConditionStatus.FALSE or
                (sample is not None and
                 sample.timestamp < cond.last_transition_time + sample.window))
    return (cond.status == ConditionStatus.FALSE and
            pod.start_time + cfg.delay_of_initial_readiness >
            cond.last_transition_time)


def desired_replicas(current: int, metric: float, target: float) -> int:
    """Eq. (1): ceil(current * metric / target). §4.4.4 example:
    current=4, metric=90, target=50 -> ceil(7.2) = 8."""
    if target <= 0:
        raise ValueError("target must be positive")
    return math.ceil(current * metric / target)


@dataclass
class HPA:
    cfg: HPAConfig
    # history of (time, desired) for scale-down stabilization
    _recommendations: List[Tuple[float, int]] = field(default_factory=list)
    last_scale_time: Optional[float] = None

    def evaluate(self, pods: List[Pod],
                 samples: Dict[str, MetricSample], now: float) -> int:
        """One reconcile loop: returns the replica count to converge to."""
        current = max(len(pods), 1)
        ready_vals = []
        for pod in pods:
            sample = samples.get(pod.name)
            if pod_is_unready(pod, sample, now, self.cfg):
                continue
            if sample is not None:
                ready_vals.append(sample.value)
        if not ready_vals:
            return len(pods)
        metric = sum(ready_vals) / len(ready_vals)
        ratio = metric / self.cfg.target
        if abs(ratio - 1.0) <= self.cfg.tolerance:
            desired = current
        else:
            desired = desired_replicas(current, metric, self.cfg.target)
        desired = max(self.cfg.min_replicas,
                      min(self.cfg.max_replicas, desired))
        # scale-down stabilization: use the max recommendation in the window
        self._recommendations.append((now, desired))
        cutoff = now - self.cfg.scale_down_stabilization
        self._recommendations = [(t, d) for t, d in self._recommendations
                                 if t >= cutoff]
        if desired < current:
            desired = max(d for _, d in self._recommendations)
            desired = min(desired, current)
        if desired != current:
            self.last_scale_time = now
        return desired
