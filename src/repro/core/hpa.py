"""Horizontal Pod Autoscaler — paper §4.4, pressure-aware.

Implements Eq. (1): desired = ceil(current * metric / target), with the
readiness-gating logic of the Kubernetes replica calculator quoted in
§4.4.2 (cpuInitializationPeriod / delayOfInitialReadinessStatus) and the
five-minute scale-down stabilization window observed in §4.4.5.

Two evaluation surfaces share the formula and the stabilization window:

- ``evaluate`` — the paper-faithful per-pod metric path (CPU-like
  samples, readiness gating).
- ``evaluate_signals`` — the multi-signal serving path (k8s
  multi-metric semantics: each signal proposes a replica count via
  Eq. (1), the **max** proposal wins). ``PressureSignals`` carries the
  three serving pressure inputs: FIFO queue depth, aggregate decode
  tokens/s, and **slab occupancy** — the serving runtime's KV
  memory-pressure gauge (paged: ``ersap_kv_pages`` / pool; dense:
  ``ersap_slab_slots_used`` / slots; fleet mean, so a scale-up visibly
  lowers it and the loop converges). Occupancy is what queue depth
  cannot see: replicas whose slabs are full cannot absorb another
  request even while the queue looks short.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.state_machine import ConditionStatus, Pod


@dataclass
class HPAConfig:
    target: float                      # target metric value per pod
    min_replicas: int = 1
    max_replicas: int = 10
    cpu_initialization_period: float = 300.0
    delay_of_initial_readiness: float = 30.0
    scale_down_stabilization: float = 300.0   # §4.4.5: five minutes
    tolerance: float = 0.1             # K8s default: 10% deadband
    metric_window: float = 60.0
    # multi-signal targets (evaluate_signals); 0 disables a signal.
    # ``target`` doubles as the per-replica queue-depth target there.
    tokens_target: float = 0.0         # per-replica tokens/s at capacity
    occupancy_target: float = 0.0      # slab occupancy fraction (e.g. 0.85)


@dataclass
class PressureSignals:
    """One tick's serving pressure inputs (see module docstring)."""
    queue_depth: float = 0.0           # requests waiting in the FIFO
    tokens_per_s: float = 0.0          # aggregate decode throughput
    slab_occupancy: float = 0.0        # mean per-replica KV occupancy [0,1]


@dataclass
class MetricSample:
    value: float
    timestamp: float
    window: float = 60.0


def pod_is_unready(pod: Pod, sample: Optional[MetricSample], now: float,
                   cfg: HPAConfig, resource_is_cpu_like: bool = True) -> bool:
    """Faithful port of the §4.4.2 snippet."""
    if not resource_is_cpu_like:
        return False
    cond = pod.condition("PodReady")
    if cond is None or pod.start_time is None:
        return True
    if pod.start_time + cfg.cpu_initialization_period > now:
        # within initialization: unready if not Ready OR the sample predates
        # the last readiness transition (+ window)
        return (cond.status == ConditionStatus.FALSE or
                (sample is not None and
                 sample.timestamp < cond.last_transition_time + sample.window))
    return (cond.status == ConditionStatus.FALSE and
            pod.start_time + cfg.delay_of_initial_readiness >
            cond.last_transition_time)


def desired_replicas(current: int, metric: float, target: float) -> int:
    """Eq. (1): ceil(current * metric / target). §4.4.4 example:
    current=4, metric=90, target=50 -> ceil(7.2) = 8."""
    if target <= 0:
        raise ValueError("target must be positive")
    return math.ceil(current * metric / target)


@dataclass
class HPA:
    cfg: HPAConfig
    # history of (time, desired) for scale-down stabilization
    _recommendations: List[Tuple[float, int]] = field(default_factory=list)
    last_scale_time: Optional[float] = None

    def evaluate(self, pods: List[Pod],
                 samples: Dict[str, MetricSample], now: float) -> int:
        """One reconcile loop: returns the replica count to converge to."""
        current = max(len(pods), 1)
        ready_vals = []
        for pod in pods:
            sample = samples.get(pod.name)
            if pod_is_unready(pod, sample, now, self.cfg):
                continue
            if sample is not None:
                ready_vals.append(sample.value)
        if not ready_vals:
            return len(pods)
        metric = sum(ready_vals) / len(ready_vals)
        return self._stabilize(
            current, self._propose(current, metric, self.cfg.target), now)

    def _propose(self, current: int, metric: float, target: float) -> int:
        """Eq. (1) with the K8s tolerance deadband."""
        if abs(metric / target - 1.0) <= self.cfg.tolerance:
            return current
        return desired_replicas(current, metric, target)

    def _stabilize(self, current: int, desired: int, now: float) -> int:
        """Clamp + §4.4.5 scale-down stabilization (max recommendation in
        the window wins on the way down)."""
        desired = max(self.cfg.min_replicas,
                      min(self.cfg.max_replicas, desired))
        self._recommendations.append((now, desired))
        cutoff = now - self.cfg.scale_down_stabilization
        self._recommendations = [(t, d) for t, d in self._recommendations
                                 if t >= cutoff]
        if desired < current:
            desired = max(d for _, d in self._recommendations)
            desired = min(desired, current)
        if desired != current:
            self.last_scale_time = now
        return desired

    def evaluate_signals(self, current: int, sig: PressureSignals,
                         now: float) -> int:
        """Multi-signal reconcile (k8s multi-metric semantics): each
        enabled signal proposes a replica count via Eq. (1); the max
        proposal wins, then the shared stabilization window applies.
        Queue depth and tokens/s are per-replica averages against their
        targets; occupancy is already a per-replica fraction (the fleet
        mean), so it compares to ``occupancy_target`` directly — a
        saturated fleet scales up even with a short queue."""
        current = max(current, 1)
        proposals = [self._propose(current, sig.queue_depth / current,
                                   self.cfg.target)]
        if self.cfg.tokens_target > 0:
            proposals.append(self._propose(
                current, sig.tokens_per_s / current, self.cfg.tokens_target))
        if self.cfg.occupancy_target > 0:
            proposals.append(self._propose(
                current, sig.slab_occupancy, self.cfg.occupancy_target))
        return self._stabilize(current, max(proposals), now)
