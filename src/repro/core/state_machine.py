"""Container/pod lifecycle state machines — faithful port of paper §4.3.

Tables 6 and 7 are reproduced verbatim as the CREATE_UIDS / GET_UIDS
indices. In the paper a "container" is a BASH script run as a process
group (pgid file, stdout/stderr files); in this TPU adaptation a container
is a compiled JAX workload handle — the filesystem probes map to runtime
probes (see DESIGN.md §2) but the STATES AND TRANSITIONS are identical:

  CreatePod walks a container through volume staging, file copy, command
  start, pgid capture, stdout/stderr creation, cmd wait, pgid write, and
  finally containerStarted(8).

  GetPods periodically re-derives container status: created -> getPids ->
  stderr probe -> stderrNotEmpty(3) | completed(4) | running(5).

Pod conditions (PodScheduled / PodInitialized / PodReady with
LastTransitionTime) follow §4.3.3 and §4.4.3 so the HPA replica calculator
sees exactly the readiness semantics Kubernetes expects.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

# ---- Table 6: UID Index for CreatePod method (verbatim) ----
CREATE_UIDS = {
    "create-cont-readDefaultVolDirError": 0,
    "create-cont-copyFileError": 1,
    "create-cont-cmdStartError": 2,
    "create-cont-getPgidError": 3,
    "create-cont-createStdoutFileError": 4,
    "create-cont-createStderrFileError": 5,
    "create-cont-cmdWaitError": 6,
    "create-cont-writePgidError": 7,
    "create-cont-containerStarted": 8,
}

# ---- Table 7: UID Index for GetPods method (verbatim) ----
GET_UIDS = {
    "get-cont-create": 0,
    "get-cont-getPidsError": 1,
    "get-cont-getStderrFileInfoError": 2,
    "get-cont-stderrNotEmpty": 3,
    "get-cont-completed": 4,
    "get-cont-running": 5,
}

# CreatePod stage order (a failure at stage k emits the matching error UID)
CREATE_STAGES = [
    "readDefaultVolDir", "copyFile", "cmdStart", "getPgid",
    "createStdoutFile", "createStderrFile", "cmdWait", "writePgid",
]
_STAGE_TO_UID = {
    "readDefaultVolDir": "create-cont-readDefaultVolDirError",
    "copyFile": "create-cont-copyFileError",
    "cmdStart": "create-cont-cmdStartError",
    "getPgid": "create-cont-getPgidError",
    "createStdoutFile": "create-cont-createStdoutFileError",
    "createStderrFile": "create-cont-createStderrFileError",
    "cmdWait": "create-cont-cmdWaitError",
    "writePgid": "create-cont-writePgidError",
}


class ContainerPhase(str, enum.Enum):
    WAITING = "Waiting"
    RUNNING = "Running"
    TERMINATED = "Terminated"


class PodPhase(str, enum.Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"


class ConditionStatus(str, enum.Enum):
    TRUE = "True"
    FALSE = "False"


@dataclass
class Condition:
    type: str                      # PodScheduled | PodInitialized | PodReady
    status: ConditionStatus
    last_transition_time: float


@dataclass
class ContainerState:
    phase: ContainerPhase = ContainerPhase.WAITING
    uid: str = "get-cont-create"
    uid_index: int = 0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    exit_code: Optional[int] = None
    pgid: Optional[int] = None     # workload handle id in the TPU adaptation

    def transition(self, uid: str, table: Dict[str, int]):
        self.uid = uid
        self.uid_index = table[uid]


@dataclass
class Container:
    name: str
    command: Optional[Callable] = None      # the workload thunk
    state: ContainerState = field(default_factory=ContainerState)
    stderr: str = ""                         # captured failure text
    stdout: List[str] = field(default_factory=list)
    _finished: bool = False

    # hooks let tests inject failures at any CreatePod stage
    fail_at: Optional[str] = None

    def finish(self):
        """Workload signals natural completion; the next GetPods walk
        observes it and transitions to get-cont-completed."""
        self._finished = True

    def terminate(self, now: float) -> ContainerState:
        """Public SIGTERM analog (paper: kill the pgid process group).

        Marks the workload finished and immediately re-derives the state
        through the GetPods walk, so callers never have to poke
        ``_finished`` directly."""
        self._finished = True
        return get_pods_container(self, now)


_PGID_COUNTER = [1000]


def create_pod_container(cont: Container, now: float) -> ContainerState:
    """CreatePod state walk (paper Fig. 2 left column + Table 6)."""
    for stage in CREATE_STAGES:
        if cont.fail_at == stage:
            cont.state.transition(_STAGE_TO_UID[stage], CREATE_UIDS)
            cont.state.phase = ContainerPhase.TERMINATED
            cont.state.finished_at = now
            cont.state.exit_code = 1
            cont.stderr = f"{stage} failed"
            return cont.state
        if stage == "getPgid":
            _PGID_COUNTER[0] += 1
            cont.state.pgid = _PGID_COUNTER[0]
    cont.state.transition("create-cont-containerStarted", CREATE_UIDS)
    cont.state.phase = ContainerPhase.RUNNING
    cont.state.started_at = now
    return cont.state


def get_pods_container(cont: Container, now: float) -> ContainerState:
    """GetPods monitor walk (paper Fig. 2 right column + Table 7)."""
    st = cont.state
    if st.phase == ContainerPhase.WAITING:
        st.transition("get-cont-create", GET_UIDS)
        return st
    if st.pgid is None and st.phase == ContainerPhase.RUNNING:
        st.transition("get-cont-getPidsError", GET_UIDS)
        st.phase = ContainerPhase.TERMINATED
        st.finished_at = now
        st.exit_code = 1
        return st
    if cont.stderr:
        st.transition("get-cont-stderrNotEmpty", GET_UIDS)
        st.phase = ContainerPhase.TERMINATED
        st.finished_at = st.finished_at or now
        st.exit_code = 1
        return st
    if cont._finished:
        st.transition("get-cont-completed", GET_UIDS)
        st.phase = ContainerPhase.TERMINATED
        st.finished_at = st.finished_at or now
        st.exit_code = 0
        return st
    st.transition("get-cont-running", GET_UIDS)
    return st


@dataclass
class Pod:
    name: str
    containers: List[Container]
    labels: Dict[str, str] = field(default_factory=dict)
    node_selector: Dict[str, str] = field(default_factory=dict)
    affinity: List[dict] = field(default_factory=list)   # matchExpressions
    tolerations: List[dict] = field(default_factory=list)
    node: Optional[str] = None
    start_time: Optional[float] = None
    conditions: List[Condition] = field(default_factory=list)
    # resource request vector used by JMS bin-packing (TPU adaptation:
    # chips + HBM bytes measured by the dry-run)
    request_chips: int = 0
    request_hbm_bytes: int = 0

    @property
    def phase(self) -> PodPhase:
        states = [c.state.phase for c in self.containers]
        if any(c.stderr for c in self.containers):
            return PodPhase.FAILED
        if all(s == ContainerPhase.TERMINATED for s in states):
            codes = [c.state.exit_code or 0 for c in self.containers]
            return PodPhase.FAILED if any(codes) else PodPhase.SUCCEEDED
        if any(s == ContainerPhase.RUNNING for s in states):
            return PodPhase.RUNNING
        return PodPhase.PENDING

    @property
    def ready(self) -> bool:
        return (self.phase == PodPhase.RUNNING and
                all(c.state.phase == ContainerPhase.RUNNING
                    for c in self.containers))

    def condition(self, ctype: str) -> Optional[Condition]:
        for c in self.conditions:
            if c.type == ctype:
                return c
        return None

    def set_conditions_create(self, now: float):
        """Pod Creation Phase conditions (§4.4.3)."""
        ready = ConditionStatus.TRUE if self.ready else ConditionStatus.FALSE
        self.start_time = now
        self.conditions = [
            Condition("PodScheduled", ConditionStatus.TRUE, now),
            Condition("PodReady", ready, now),
            Condition("PodInitialized", ConditionStatus.TRUE, now),
        ]

    def set_conditions_get(self, now: float):
        """Pod Retrieving Phase conditions (§4.4.3): PodReady's transition
        time tracks the FIRST container's start time, as in the paper."""
        prev_start = self.start_time if self.start_time is not None else now
        first = self.containers[0] if self.containers else None
        first_started = (first.state.started_at if first and
                         first.state.started_at is not None else prev_start)
        ready = ConditionStatus.TRUE if self.ready else ConditionStatus.FALSE
        old_ready = self.condition("PodReady")
        ready_tt = first_started
        if old_ready is not None and old_ready.status == ready:
            ready_tt = old_ready.last_transition_time
        self.conditions = [
            Condition("PodScheduled", ConditionStatus.TRUE, prev_start),
            Condition("PodInitialized", ConditionStatus.TRUE, prev_start),
            Condition("PodReady", ready, ready_tt),
        ]
