"""Elastic data-parallel scaling (HPA/twin decision -> new mesh).

A serving deployment is R replicas x TP chips. Scaling re-builds the mesh
as (R', TP), re-lowers prefill/decode, and resharsd params onto the new
topology (device_put through the checkpoint/restore path — the same code
path that handles node-failure recovery, so elasticity and fault tolerance
are one mechanism)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_mesh
from repro.models import model_api as MA
from repro.sharding.api import ShardCtx, tree_shardings


@dataclass
class ElasticServing:
    cfg: ArchConfig
    tp: int = 1
    replicas: int = 0
    mesh: object = None
    ctx: Optional[ShardCtx] = None
    params: object = None
    prefill_fn: object = None
    decode_fn: object = None
    scale_events: list = field(default_factory=list)

    def max_replicas(self) -> int:
        return max(len(jax.devices()) // self.tp, 1)

    def build(self, replicas: int, host_params=None, now: float = 0.0):
        """(Re)build at ``replicas`` data-parallel replicas."""
        replicas = min(max(replicas, 1), self.max_replicas())
        if host_params is None:
            host_params = self.host_params()
        mesh = make_mesh((replicas, self.tp), ("data", "model"))
        ctx = ShardCtx(mesh)
        mod = MA.get_module(self.cfg)
        aparams = mod.abstract_params(self.cfg)
        psh = tree_shardings(ctx, aparams, mod.param_axes(self.cfg))
        params = jax.tree.map(
            lambda h, s: jax.device_put(h, s), host_params, psh)
        cfgl = self.cfg

        def prefill(params, tokens):
            return mod.prefill(params, tokens, cfgl, ctx)

        def decode(params, token, cache):
            return mod.decode_step(params, token, cache, cfgl, ctx)

        self.prefill_fn = jax.jit(prefill)
        self.decode_fn = jax.jit(decode)
        old = self.replicas
        self.mesh, self.ctx, self.params = mesh, ctx, params
        self.replicas = replicas
        if old != replicas:
            self.scale_events.append((now, old, replicas))
        return self

    def host_params(self):
        if self.params is None:
            raise RuntimeError("no params yet — call build(host_params=...)")
        return jax.tree.map(np.asarray, self.params)

    def scale_to(self, replicas: int, now: float = 0.0):
        replicas = min(max(replicas, 1), self.max_replicas())
        if replicas == self.replicas:
            return self
        return self.build(replicas, now=now)
