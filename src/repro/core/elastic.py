"""Elastic data-parallel scaling (HPA/twin decision -> new mesh).

A serving deployment is R replicas x TP chips. Scaling re-builds the mesh
as (R', TP), re-lowers prefill/decode, and reshards params onto the new
topology (device_put through the checkpoint/restore path — the same code
path that handles node-failure recovery, so elasticity and fault tolerance
are one mechanism).

Compiled artifacts are cached per (replicas, tp): scaling back to a
previously-seen size reuses the mesh, the jitted prefill/decode closures
(so jax's own trace cache keeps hitting — re-lowering was the dominant
scale-up cost), and the serving-runtime kernel set. The decode closure
donates its cache argument, so the per-token KV update is in-place
instead of a full slab copy per step."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_mesh
from repro.models import model_api as MA
from repro.sharding.api import ShardCtx, tree_shardings


@dataclass
class ElasticServing:
    cfg: ArchConfig
    tp: int = 1
    replicas: int = 0
    mesh: object = None
    ctx: Optional[ShardCtx] = None
    params: object = None
    prefill_fn: object = None
    decode_fn: object = None
    scale_events: list = field(default_factory=list)
    build_gen: int = 0                     # bumped on every (re)build
    # (replicas, tp) -> (mesh, ctx, prefill_fn, decode_fn, param_shardings)
    _compiled: Dict[Tuple[int, int], tuple] = field(default_factory=dict)
    _kernels: Dict[tuple, object] = field(default_factory=dict)

    def max_replicas(self) -> int:
        return max(len(jax.devices()) // self.tp, 1)

    def _lowered(self, replicas: int):
        key = (replicas, self.tp)
        if key in self._compiled:
            return self._compiled[key]
        mesh = make_mesh((replicas, self.tp), ("data", "model"))
        ctx = ShardCtx(mesh)
        mod = MA.get_module(self.cfg)
        aparams = mod.abstract_params(self.cfg)
        psh = tree_shardings(ctx, aparams, mod.param_axes(self.cfg))
        cfgl = self.cfg

        def prefill(params, tokens):
            return mod.prefill(params, tokens, cfgl, ctx)

        def decode(params, token, cache):
            return mod.decode_step(params, token, cache, cfgl, ctx)

        entry = (mesh, ctx, jax.jit(prefill),
                 jax.jit(decode, donate_argnums=(2,)), psh)
        self._compiled[key] = entry
        return entry

    def build(self, replicas: int, host_params=None, now: float = 0.0):
        """(Re)build at ``replicas`` data-parallel replicas."""
        replicas = min(max(replicas, 1), self.max_replicas())
        if host_params is None:
            host_params = self.host_params()
        mesh, ctx, prefill_fn, decode_fn, psh = self._lowered(replicas)
        params = jax.tree.map(
            lambda h, s: jax.device_put(h, s), host_params, psh)
        self.prefill_fn, self.decode_fn = prefill_fn, decode_fn
        old = self.replicas
        self.mesh, self.ctx, self.params = mesh, ctx, params
        self.replicas = replicas
        self.build_gen += 1
        if old != replicas:
            self.scale_events.append((now, old, replicas))
        return self

    def runtime_kernels(self, rcfg):
        """Serving-runtime kernel set for the *current* topology, cached per
        (replicas, tp, rcfg) so re-scaling to a seen size skips re-tracing."""
        from repro.streaming.runtime import RuntimeKernels
        key = (self.replicas, self.tp, rcfg)
        if key not in self._kernels:
            self._kernels[key] = RuntimeKernels(self.cfg, rcfg, self.ctx)
        return self._kernels[key]

    def host_params(self):
        if self.params is None:
            raise RuntimeError("no params yet — call build(host_params=...)")
        return jax.tree.map(np.asarray, self.params)

    def scale_to(self, replicas: int, now: float = 0.0):
        replicas = min(max(replicas, 1), self.max_replicas())
        if replicas == self.replicas:
            return self
        return self.build(replicas, now=now)
