"""Reconciling scheduler — queue-based refactor of the JMS (paper §3).

The seed's ``MatchingService.bind`` filtered, sorted, and mutated nodes in
one imperative shot. Here scheduling is a control loop over the Cluster
store's pending queue:

  * pluggable **filter stages** (predicates: Ready/schedulable,
    tolerations, nodeSelector, affinity, chips/HBM resources, walltime
    lease vs expected duration + drain margin),
  * pluggable **score stages** (non-straggler preference, best-fit HBM —
    the tightest feasible fit wins),
  * **retry with exponential backoff** for unschedulable pods (the queue
    is re-examined every ``run_once``; a FailedScheduling event is
    emitted once per *reason transition*, not per retry — a
    quota-blocked pod parked for minutes logs one line, not hundreds —
    and quota rejections back off at ``backoff_max`` immediately, since
    waiting cannot free a fair-share cap),
  * **QoS preemption**: a pod that cannot fit may evict strictly
    lower-priority *preemptible* pods from a healthy (never draining)
    node — cost-ranked across nodes by (victim priority sum, victim
    count). Victims are checkpointed through the §4.5.4 loop
    (``checkpoint_cb``, wired by the ControlPlane to the
    NodeLifecycleController) and requeued with their spec and state
    intact — preemption moves work, it never loses it. Equal-or-higher
    priority is never preempted.

``MatchingService`` (jms.py) remains as a thin one-shot facade over the
same filter/score stages for legacy callers.

Multi-site federation (paper §1/§4: one control plane spanning JLab,
NERSC, ...): a ``SiteTopology`` — the configurable inter-site latency
matrix plus the map of data streams to their home site — makes site a
first-class scheduling input:

  * ``filter_site``: hard site selector / anti-affinity on the PodRecord,
  * ``score_data_locality``: pin a pod toward the site holding its input
    stream (pay the inter-site latency everywhere else),
  * ``score_site_spread``: spread an owner's replicas across sites so one
    facility outage takes out as few replicas as possible,
  * ``score_site_latency``: among equally-spread sites prefer the one
    closest (by the latency matrix) to the owner's existing footprint.

All four are neutral when the cluster is single-site or the pod carries
no site spec, so single-facility behavior is unchanged.
"""
from __future__ import annotations

import bisect
import itertools
import zlib
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.cluster import (ADDED, DELETED, MODIFIED, KIND_DEPLOYMENT,
                                KIND_NODE, KIND_POD, KIND_QUOTA, Cluster,
                                PodRecord, WatchEvent)
from repro.core.jrm import VirtualNode
from repro.core.state_machine import PodPhase

# A filter returns None when the node is feasible, else a reject reason.
FilterStage = Callable[[PodRecord, VirtualNode, "Scheduler", float],
                       Optional[str]]
# A scorer returns a number; higher is better.
ScoreStage = Callable[[PodRecord, VirtualNode, "Scheduler", float], float]


def _jitter_u(name: str, attempt: int) -> float:
    """Deterministic uniform-ish [0, 1) from (pod, attempt): reproducible
    across runs (no RNG state to thread through the control plane), but
    decorrelated across pods so simultaneous failures spread out."""
    return (zlib.crc32(f"{name}#{attempt}".encode()) & 0xFFFFFFFF) / 2**32


@dataclass
class SiteTopology:
    """Federation config: symmetric inter-site latency matrix (ms), the
    home site of each named data stream (EJFAT/ERSAP source pinning),
    and a symmetric inter-site bandwidth matrix (Gbps) feeding the
    checkpoint-transfer cost model."""
    latency_ms: Dict[Tuple[str, str], float] = field(default_factory=dict)
    data_sites: Dict[str, str] = field(default_factory=dict)
    default_latency_ms: float = 100.0     # unlisted site pairs
    bandwidth_gbps: Dict[Tuple[str, str], float] = field(default_factory=dict)
    default_bandwidth_gbps: float = 1.0   # unlisted site pairs

    def latency(self, a: str, b: str) -> float:
        if a == b:
            return 0.0
        return self.latency_ms.get(
            (a, b), self.latency_ms.get((b, a), self.default_latency_ms))

    def connect(self, a: str, b: str, ms: float) -> "SiteTopology":
        self.latency_ms[(a, b)] = ms
        return self

    def bandwidth(self, a: str, b: str) -> float:
        if a == b:
            return float("inf")           # intra-site: no WAN hop
        return self.bandwidth_gbps.get(
            (a, b), self.bandwidth_gbps.get((b, a),
                                            self.default_bandwidth_gbps))

    def set_bandwidth(self, a: str, b: str, gbps: float) -> "SiteTopology":
        self.bandwidth_gbps[(a, b)] = gbps
        return self

    def transfer_cost(self, state_bytes: int, src: str, dst: str) -> float:
        """Seconds to move ``state_bytes`` of checkpoint state from
        ``src`` to ``dst``: one RTT-ish latency hit plus serialization
        over the site pair's bandwidth. 0 for intra-site moves — the
        cost model `drain_site` and preemption ranking pay instead of
        assuming state teleports between facilities."""
        if src == dst or state_bytes <= 0:
            return 0.0
        bw = self.bandwidth(a=src, b=dst)
        ser = 0.0 if bw == float("inf") else \
            state_bytes * 8 / (bw * 1e9)
        return self.latency(src, dst) / 1000.0 + ser

    @staticmethod
    def parse(spec: str, data_spec: str = "",
              bw_spec: str = "") -> "SiteTopology":
        """``"jlab:nersc:40,nersc:ornl:18"`` -> latency entries;
        ``"ejfat=jlab"`` -> data-stream home sites;
        ``"jlab:nersc:10"`` (bw_spec) -> bandwidth entries in Gbps."""
        topo = SiteTopology()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            a, b, ms = part.split(":")
            topo.connect(a, b, float(ms))
        for part in data_spec.split(","):
            part = part.strip()
            if not part:
                continue
            stream, site = part.split("=")
            topo.data_sites[stream] = site
        for part in bw_spec.split(","):
            part = part.strip()
            if not part:
                continue
            a, b, gbps = part.split(":")
            topo.set_bandwidth(a, b, float(gbps))
        return topo


# ------------------------------------------------------------ filter stages

def filter_node_ready(rec, node, sched, now):
    st = sched.cluster.node_status.get(node.name)
    if st is None or not st.ready:
        return "node not ready"
    if not st.schedulable:
        return "node cordoned"
    if node.draining(now):
        return "node draining"
    return None


def filter_tolerations(rec, node, sched, now):
    if not node.tolerates(rec.pod):
        return "taint not tolerated"
    return None


def filter_node_selector(rec, node, sched, now):
    lab = node.labels(now)
    for k, v in rec.pod.node_selector.items():
        if lab.get(k) != v:
            return f"nodeSelector {k}={v} unmatched"
    return None


def filter_affinity(rec, node, sched, now):
    if rec.pod.affinity and not node.matches(rec.pod.affinity, now):
        return "affinity unmatched"
    return None


def filter_resources(rec, node, sched, now):
    if node.free_chips() < rec.pod.request_chips:
        return "insufficient chips"
    if node.free_hbm() < rec.pod.request_hbm_bytes:
        return "insufficient HBM"
    return None


def filter_walltime(rec, node, sched, now):
    """§4.5.4: only place work that can finish before the drain margin."""
    left = node.alive_left(now)
    if left != float("inf") and \
            left < rec.expected_duration + node.drain_margin:
        return "walltime lease too short"
    return None


def filter_site(rec, node, sched, now):
    """Federation: hard site selector + anti-affinity on the PodRecord."""
    if rec.site_selector and node.site not in rec.site_selector:
        return f"site {node.site} not in selector {list(rec.site_selector)}"
    if node.site in rec.site_anti_affinity:
        return f"site {node.site} excluded by anti-affinity"
    return None


def filter_quota(rec, node, sched, now):
    """QoS: the owner's fair-share quota (cluster-wide and per-site) must
    cover this pod's chips/HBM/kv-page requests on top of what the owner
    already has bound. Usage is derived from the store by the ledger, so
    preempt -> requeue -> reschedule re-balances the books automatically.
    Neutral when no quotas are declared."""
    return sched.cluster.ledger.check(rec, node)


DEFAULT_FILTERS: List[FilterStage] = [
    filter_node_ready, filter_tolerations, filter_node_selector,
    filter_affinity, filter_site, filter_quota, filter_resources,
    filter_walltime,
]


# The one classifier over select_node's composed reject string
# ("node: reason; node: reason; ..."), kept next to the filters that
# emit the reasons so wording and parsing cannot drift apart
# (consumers: run_once's quota park, jcs reprovision's starved-chips).

def _reject_reasons(reason: str) -> List[str]:
    """Per-node reject reasons with the 'node: ' prefix stripped (so a
    node or owner name never masquerades as a reject kind)."""
    return [p.split(": ", 1)[-1] for p in reason.split("; ") if p]


def is_quota_blocked(reason: str) -> bool:
    """Every node rejected the pod for its owner's quota (filter_quota):
    waiting cannot help — only a spec write or scale-down frees share."""
    parts = _reject_reasons(reason)
    return bool(parts) and all(p.startswith("quota:") for p in parts)


def is_capacity_starved(reason: str) -> bool:
    """Some node rejected the pod for chips/HBM (filter_resources) —
    the rejections more capacity could actually cure; quota rejects
    (whose message also names the resource) are excluded."""
    return any(p.startswith("insufficient")
               for p in _reject_reasons(reason)
               if not p.startswith("quota:"))


# ------------------------------------------------------------- score stages

# Scorers are compared LEXICOGRAPHICALLY in list order: a later stage only
# breaks ties left by every earlier stage, so magnitudes never leak across
# stages.

def score_non_straggler(rec, node, sched, now):
    """Stage 1: avoid straggler nodes (heartbeat-latency signal from JFM)."""
    st = sched.cluster.node_status.get(node.name)
    return -1.0 if (st is not None and st.straggler) else 0.0


def _peer_sites(rec, sched) -> Dict[str, int]:
    """Bound replicas of ``rec``'s owner, counted per site. Served from
    the scheduler's delta-maintained capacity index (O(1)); the polling
    reference path (``use_index=False``) falls back to a full pod-table
    scan memoized on the cluster's watch version — without the memo,
    scoring every candidate node (x2 site stages) per pod turned the
    §5.1 forty-node bring-up O(pods^2 x nodes)."""
    if rec.owner is None:
        return {}
    idx = sched._index
    if idx is not None and sched.use_index:
        return idx.owner_sites.get(rec.owner, {})
    key = (rec.owner, sched.cluster.version)
    cached = sched._peer_site_cache
    if cached is not None and cached[0] == key:
        return cached[1]
    out: Dict[str, int] = {}
    for peer in sched.cluster.pods_of(rec.owner):
        node = sched.cluster.nodes.get(peer.pod.node) if peer.bound else None
        if node is not None:
            out[node.site] = out.get(node.site, 0) + 1
    sched._peer_site_cache = (key, out)
    return out


def score_data_locality(rec, node, sched, now):
    """Stage 2: pin toward the site holding the pod's input stream; any
    other site pays that stream's inter-site latency."""
    topo = sched.topology
    if topo is None or rec.data_stream is None:
        return 0.0
    home = topo.data_sites.get(rec.data_stream)
    if home is None:
        return 0.0
    return -topo.latency(home, node.site)


def score_site_spread(rec, node, sched, now):
    """Stage 3: spread an owner's replicas across sites — a whole-facility
    outage (walltime cliff, network partition) takes out as few replicas
    as possible."""
    return -float(_peer_sites(rec, sched).get(node.site, 0))


def score_site_latency(rec, node, sched, now):
    """Stage 4: latency-weighted cross-site spreading — among equally
    spread candidates, prefer the site closest (by the topology matrix) to
    where the owner's replicas already run, so cross-site spillover lands
    on the cheapest link."""
    topo = sched.topology
    if topo is None:
        return 0.0
    peers = _peer_sites(rec, sched)
    others = [s for s in peers if s != node.site]
    if not others:
        return 0.0
    return -sum(topo.latency(node.site, s) * peers[s]
                for s in others) / sum(peers[s] for s in others)


def score_bestfit_hbm(rec, node, sched, now):
    """Stage 5: tightest absolute HBM fit that still holds the pod (the
    seed JMS policy)."""
    return -(node.free_hbm() - rec.pod.request_hbm_bytes)


def score_spread(rec, node, sched, now):
    """Stage 6: balance pods across nodes so one drained lease takes out
    as few replicas as possible."""
    return -node.used_chips() / max(float(node.slice_spec.chips), 1.0)


DEFAULT_SCORERS: List[ScoreStage] = [
    score_non_straggler, score_data_locality, score_site_spread,
    score_site_latency, score_bestfit_hbm, score_spread,
]


# ---------------------------------------------------------- capacity index

def _spec_signature(rec: PodRecord) -> tuple:
    """Everything the DEFAULT filter chain reads off the pod record: two
    pending pods with equal signatures are rejected by exactly the same
    nodes for exactly the same reasons at one (store version, now)."""
    return (rec.owner, rec.pod.request_chips, rec.pod.request_hbm_bytes,
            rec.request_kv_pages, rec.expected_duration,
            rec.site_selector, rec.site_anti_affinity,
            tuple(sorted(rec.pod.node_selector.items())),
            tuple(tuple(sorted(t.items())) for t in rec.pod.tolerations),
            repr(rec.pod.affinity))


_TERMINAL = (PodPhase.SUCCEEDED, PodPhase.FAILED)


class CapacityIndex:
    """Incremental per-node / per-site free-capacity index, maintained
    from watch deltas — the generalization of the memoized-on-version
    pattern the quota ledger and peer-site scoring used, except deltas
    update it in O(log nodes) instead of invalidating it wholesale.

    Structure: eligible nodes (ready, schedulable, reachable) are
    grouped by ``(site, straggler)`` — the only node attributes the
    DEFAULT score stages read besides free HBM and used fraction, so
    every score stage is constant within a group except
    ``score_bestfit_hbm`` and ``score_spread``. Each group keeps its
    entries sorted ascending by ``(free_hbm, used_frac, reg_seq)``.

    Equivalence with the full-scan ``max(candidates, key=score)``:

    * within a group, the score tuple varies only in
      ``(-(free_hbm - req), -used_frac)`` and the full scan's tie-break
      (first node in registration order wins ``max``) is ``-reg_seq`` —
      so the *lexicographically smallest* ``(free_hbm, used_frac,
      reg_seq)`` entry with ``free_hbm >= req`` is the within-group
      argmax. ``bisect`` finds it; the walk runs the full live filter
      chain per entry (draining, walltime, quota and any time-dependent
      predicate stay authoritative — the index only orders candidates).
    * across groups, the winners compete on the full live score with
      ``-reg_seq`` as tie-break, reproducing global ``max`` exactly.

    Invalidation rules (see docs/ARCHITECTURE.md): Pod ``bind`` /
    ``DELETED`` / ``phase`` deltas reindex the touched node and adjust
    the per-owner site counts + preemption-victim histogram; Node
    ``ADDED``/``DELETED``/status deltas reindex that node; ``heartbeat``
    deltas are ignored by construction (they change no capacity).
    ``verify()`` recomputes everything from the store and raises on any
    drift — the property suite and the scale bench call it."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        # (site, straggler) -> ascending [(free_hbm, used_frac, seq, name)]
        self.groups: Dict[Tuple[str, bool], list] = {}
        self.node_entry: Dict[str, tuple] = {}   # name -> indexed snapshot
        self.reg_seq: Dict[str, int] = {}
        self.site_free_chips: Counter = Counter()
        self.site_free_hbm: Counter = Counter()
        self.owner_sites: Dict[str, Dict[str, int]] = {}
        self._counted_site: Dict[str, Tuple[Optional[str], str]] = {}
        self._victims: Counter = Counter()       # priority -> victim count
        self._victim_prio: Dict[str, int] = {}
        self._victims_dirty = False
        self._reg = itertools.count(1)
        for name in cluster.nodes:
            self.add_node(name)
        for rec in cluster.pods.values():
            if rec.bound:
                self._count_pod(rec)

    # ------------------------------------------------------ node deltas
    def add_node(self, name: str) -> None:
        self.reg_seq[name] = next(self._reg)
        self.reindex_node(name)
        # a re-registered node may still carry bound pods from its
        # previous incarnation: count them back in
        for rec in self.cluster.pods_on(name):
            self._count_pod(rec)

    def remove_node(self, name: str) -> None:
        self._drop_entry(name)
        self.reg_seq.pop(name, None)
        # bound pods now point at a vanished node: the full scan's
        # peer-site counting skips them, so the index must too
        for rec in self.cluster.pods_on(name):
            self._uncount_pod(rec.name)

    def _drop_entry(self, name: str) -> None:
        old = self.node_entry.pop(name, None)
        if old is None:
            return
        gkey, entry, free_chips = old
        grp = self.groups.get(gkey)
        if grp is not None:
            i = bisect.bisect_left(grp, entry)
            if i < len(grp) and grp[i] == entry:
                del grp[i]
            if not grp:
                del self.groups[gkey]
        self.site_free_chips[gkey[0]] -= free_chips
        self.site_free_hbm[gkey[0]] -= entry[0]

    def reindex_node(self, name: str) -> bool:
        """Recompute one node's eligibility and sort keys from the
        authoritative node/status objects. Returns True when the node
        gained schedulable capacity (became eligible, or free capacity
        grew) — the scheduler's capacity-freed wake signal."""
        old = self.node_entry.get(name)
        self._drop_entry(name)
        node = self.cluster.nodes.get(name)
        st = self.cluster.node_status.get(name)
        if node is None or st is None:
            return False
        if not (st.ready and st.schedulable and st.reachable):
            return False
        free_hbm = node.free_hbm()
        free_chips = node.free_chips()
        used_frac = node.used_chips() / max(float(node.slice_spec.chips),
                                            1.0)
        gkey = (node.site, bool(st.straggler))
        entry = (free_hbm, used_frac, self.reg_seq.get(name, 0), name)
        grp = self.groups.setdefault(gkey, [])
        bisect.insort(grp, entry)
        self.node_entry[name] = (gkey, entry, free_chips)
        self.site_free_chips[gkey[0]] += free_chips
        self.site_free_hbm[gkey[0]] += free_hbm
        if old is None:
            return True
        return free_chips > old[2] or free_hbm > old[1][0]

    # ------------------------------------------------------- pod deltas
    def on_pod_event(self, ev: WatchEvent) -> None:
        rec = ev.obj
        if rec is None:
            return
        if ev.type == MODIFIED and ev.reason == "bind":
            self.reindex_node(rec.pod.node)
            self._count_pod(rec)
        elif ev.type == DELETED and rec.pod.node is not None:
            self.reindex_node(rec.pod.node)
            self._uncount_pod(rec.name)
        elif ev.type == MODIFIED and ev.reason == "phase":
            if rec.pod.node is not None:
                self.reindex_node(rec.pod.node)
            if rec.pod.phase in _TERMINAL:
                self._uncount_pod(rec.name)

    def _count_pod(self, rec: PodRecord) -> None:
        """Start counting a bound, live pod in the per-owner site counts
        (peer-site scoring) and the preemption-victim histogram."""
        if rec.name in self._counted_site or rec.pod.phase in _TERMINAL:
            return
        node = self.cluster.nodes.get(rec.pod.node)
        if node is None:
            return
        self._counted_site[rec.name] = (rec.owner, node.site)
        if rec.owner is not None:
            sites = self.owner_sites.setdefault(rec.owner, {})
            sites[node.site] = sites.get(node.site, 0) + 1
        if rec.preemptible:
            self._victim_prio[rec.name] = rec.priority
            self._victims[rec.priority] += 1

    def _uncount_pod(self, name: str) -> None:
        counted = self._counted_site.pop(name, None)
        if counted is None:
            return
        owner, site = counted
        if owner is not None:
            sites = self.owner_sites.get(owner)
            if sites is not None:
                sites[site] -= 1
                if sites[site] <= 0:
                    del sites[site]
                if not sites:
                    del self.owner_sites[owner]
        prio = self._victim_prio.pop(name, None)
        if prio is not None:
            self._victims[prio] -= 1
            if self._victims[prio] <= 0:
                del self._victims[prio]

    # ----------------------------------------------- preemption victims
    def mark_victims_dirty(self) -> None:
        """set_priority re-tiers bound pods through a Deployment delta
        (no per-pod deltas): rebuild the histogram lazily on next use."""
        self._victims_dirty = True

    def _rebuild_victims(self) -> None:
        self._victims.clear()
        self._victim_prio.clear()
        for name, (owner, site) in self._counted_site.items():
            rec = self.cluster.pods.get(name)
            if rec is not None and rec.preemptible:
                self._victim_prio[name] = rec.priority
                self._victims[rec.priority] += 1
        self._victims_dirty = False

    def has_victims_below(self, priority: int) -> bool:
        """O(#tiers) early-out for the preemption scan: no bound
        preemptible pod below ``priority`` means ``_try_preempt`` cannot
        succeed anywhere — skip its full node walk."""
        if self._victims_dirty:
            self._rebuild_victims()
        return any(p < priority for p in self._victims)

    # ---------------------------------------------------------- lookup
    def select(self, rec: PodRecord, sched: "Scheduler",
               now: float) -> Optional[VirtualNode]:
        """First live-feasible entry per group (= within-group argmax,
        see class docstring), then the global max over group winners on
        the full score with registration order as tie-break."""
        best = None
        best_key = None
        req_hbm = rec.pod.request_hbm_bytes
        for (site, straggler), entries in self.groups.items():
            if rec.site_selector and site not in rec.site_selector:
                continue
            if site in rec.site_anti_affinity:
                continue
            i = bisect.bisect_left(entries, (req_hbm,))
            while i < len(entries):
                _, _, seq, name = entries[i]
                node = self.cluster.nodes.get(name)
                if node is not None and \
                        sched.feasible(rec, node, now) is None:
                    key = (sched.score(rec, node, now), -seq)
                    if best_key is None or key > best_key:
                        best, best_key = node, key
                    break
                i += 1
        return best

    # ---------------------------------------------------------- verify
    def verify(self, now: float = 0.0) -> None:
        """Full from-scratch recompute vs the incremental state; raises
        AssertionError naming the first drift. The property suite runs
        it after randomized op interleavings; the scale bench runs it
        once after churn."""
        cl = self.cluster
        want_entries: Dict[str, tuple] = {}
        want_chips: Counter = Counter()
        want_hbm: Counter = Counter()
        for name, node in cl.nodes.items():
            st = cl.node_status.get(name)
            if st is None or not (st.ready and st.schedulable
                                  and st.reachable):
                continue
            gkey = (node.site, bool(st.straggler))
            used_frac = node.used_chips() / max(
                float(node.slice_spec.chips), 1.0)
            want_entries[name] = (gkey, (node.free_hbm(), used_frac,
                                         self.reg_seq.get(name, 0), name))
            want_chips[node.site] += node.free_chips()
            want_hbm[node.site] += node.free_hbm()
        have = {n: (g, e) for n, (g, e, _) in self.node_entry.items()}
        assert have == want_entries, \
            f"node entries drifted: {have} != {want_entries}"
        for gkey, grp in self.groups.items():
            assert grp == sorted(grp), f"group {gkey} unsorted: {grp}"
            for entry in grp:
                name = entry[3]
                assert want_entries.get(name) == (gkey, entry), \
                    f"stale group entry {entry} in {gkey}"
        assert +self.site_free_chips == +want_chips, \
            f"site free chips drifted: {self.site_free_chips} != {want_chips}"
        assert +self.site_free_hbm == +want_hbm, \
            f"site free HBM drifted: {self.site_free_hbm} != {want_hbm}"
        want_sites: Dict[str, Dict[str, int]] = {}
        want_victims: Counter = Counter()
        for rec in cl.pods.values():
            if not rec.bound or rec.pod.phase in _TERMINAL:
                continue
            node = cl.nodes.get(rec.pod.node)
            if node is None:
                continue
            if rec.owner is not None:
                sites = want_sites.setdefault(rec.owner, {})
                sites[node.site] = sites.get(node.site, 0) + 1
            if rec.preemptible:
                want_victims[rec.priority] += 1
        assert self.owner_sites == want_sites, \
            f"owner sites drifted: {self.owner_sites} != {want_sites}"
        if self._victims_dirty:
            self._rebuild_victims()
        assert +self._victims == +want_victims, \
            f"victim histogram drifted: {self._victims} != {want_victims}"


@dataclass
class Decision:
    pod: str
    node: Optional[str]
    reason: str = ""
    preempted: Tuple[str, ...] = ()


@dataclass
class Scheduler:
    cluster: Cluster
    filters: List[FilterStage] = field(
        default_factory=lambda: list(DEFAULT_FILTERS))
    scorers: List[ScoreStage] = field(
        default_factory=lambda: list(DEFAULT_SCORERS))
    backoff_base: float = 5.0
    backoff_max: float = 60.0
    # decorrelation jitter on the exponential backoff: each retry is
    # stretched by up to this fraction, derived deterministically from
    # (pod name, attempt) — a mass node failure requeues hundreds of
    # pods at the same instant, and without jitter they all retry (and
    # all fail, and all retry again) in synchronized storms. 0 disables.
    backoff_jitter: float = 0.25
    enable_preemption: bool = True
    topology: Optional[SiteTopology] = None     # federation config
    # §4.5.4 hook for preemption victims: ControlPlane wires this to
    # NodeLifecycleController.checkpoint_pod so an evicted victim's
    # runtime state rides its requeued record (None -> no checkpoint)
    checkpoint_cb: Optional[Callable[[PodRecord, float], Optional[dict]]] = \
        None
    # event-driven switches. ``use_index`` routes placement through the
    # delta-maintained CapacityIndex fast path (bisect per group instead
    # of a full node scan); ``wake_on_freed`` re-arms parked
    # FailedScheduling pods the moment a capacity-freed or
    # quota-released delta arrives, demoting the jittered backoff to a
    # fallback. Both False reproduces the pure polling scheduler —
    # the differential harness pins the two paths against each other.
    use_index: bool = True
    wake_on_freed: bool = True
    tracer: object = None       # optional: schedule/preempt spans
    _peer_site_cache: Optional[tuple] = field(default=None, repr=False)
    _index: Optional[CapacityIndex] = field(default=None, init=False,
                                            repr=False)
    _wake_capacity: bool = field(default=False, init=False, repr=False)
    _wake_quota_owners: Set[str] = field(default_factory=set, init=False,
                                         repr=False)
    _scan_stamp: Optional[tuple] = field(default=None, init=False,
                                         repr=False)
    _scan_cache: Dict[tuple, str] = field(default_factory=dict, init=False,
                                          repr=False)

    def __post_init__(self):
        self._index = CapacityIndex(self.cluster)
        self.cluster.watch(KIND_POD, self._on_pod_delta)
        self.cluster.watch(KIND_NODE, self._on_node_delta)
        self.cluster.watch(KIND_QUOTA, self._on_quota_delta)
        self.cluster.watch(KIND_DEPLOYMENT, self._on_deployment_delta)

    # ---------------------------------------------------- delta intake
    def _on_pod_delta(self, ev: WatchEvent) -> None:
        self._index.on_pod_event(ev)
        rec = ev.obj
        if ev.type == DELETED and rec is not None \
                and rec.pod.node is not None:
            # a bound pod left: its chips/HBM and its quota share are
            # both free again
            self._wake_capacity = True
            if rec.owner is not None and \
                    any(k[0] == rec.owner for k in self.cluster.quotas):
                self._wake_quota_owners.add(rec.owner)

    def _on_node_delta(self, ev: WatchEvent) -> None:
        if ev.reason == "heartbeat":
            return      # no capacity or eligibility change, by contract
        if ev.type == ADDED:
            self._index.add_node(ev.name)
            self._wake_capacity = True
        elif ev.type == DELETED:
            self._index.remove_node(ev.name)
        elif self._index.reindex_node(ev.name):
            self._wake_capacity = True

    def _on_quota_delta(self, ev: WatchEvent) -> None:
        self._wake_quota_owners.add(ev.name)    # ev.name is the owner

    def _on_deployment_delta(self, ev: WatchEvent) -> None:
        self._index.mark_victims_dirty()

    @property
    def _fast_path(self) -> bool:
        """The bisect shortcut is only provably identical to the full
        scan under the DEFAULT stage lists (the equivalence argument in
        CapacityIndex leans on what those stages read); any custom stage
        falls back to the authoritative scan."""
        return (self.use_index and self._index is not None
                and self.scorers == DEFAULT_SCORERS
                and self.filters == DEFAULT_FILTERS)

    # ------------------------------------------------------ single pod
    def feasible(self, rec: PodRecord, node: VirtualNode,
                 now: float) -> Optional[str]:
        for f in self.filters:
            reason = f(rec, node, self, now)
            if reason is not None:
                return reason
        return None

    def score(self, rec: PodRecord, node: VirtualNode,
              now: float) -> Tuple[float, ...]:
        """Lexicographic key: scorers[0] dominates, later ones break ties."""
        return tuple(s(rec, node, self, now) for s in self.scorers)

    def select_node(self, rec: PodRecord,
                    now: float) -> Tuple[Optional[VirtualNode], str]:
        if self._fast_path:
            node = self._index.select(rec, self, now)
            if node is not None:
                return node, "best-fit"
            # no indexed candidate: the authoritative scan composes the
            # polling-identical per-node reject string — memoized per
            # (spec signature, store version, now) so a thousand parked
            # clones cost one scan, not a thousand — and, should a node
            # the index missed (a kubelet-side phase change that never
            # reached note_pod_phase) be live-feasible, binds it exactly
            # as the polling path would
            return self._scan_memo(rec, now)
        return self._scan(rec, now)

    def _scan(self, rec: PodRecord,
              now: float) -> Tuple[Optional[VirtualNode], str]:
        reasons = []
        cands = []
        for node in self.cluster.nodes.values():
            reason = self.feasible(rec, node, now)
            if reason is None:
                cands.append(node)
            else:
                reasons.append(f"{node.name}: {reason}")
        if not cands:
            return None, "; ".join(reasons) or "no nodes registered"
        best = max(cands, key=lambda n: self.score(rec, n, now))
        return best, "best-fit"

    def _scan_memo(self, rec: PodRecord,
                   now: float) -> Tuple[Optional[VirtualNode], str]:
        stamp = (self.cluster.version, now)
        if self._scan_stamp != stamp:
            self._scan_stamp = stamp
            self._scan_cache.clear()
        sig = _spec_signature(rec)
        hit = self._scan_cache.get(sig)
        if hit is not None:
            return None, hit
        node, reason = self._scan(rec, now)
        if node is None:
            self._scan_cache[sig] = reason
        return node, reason

    # ------------------------------------------------------ preemption
    def _try_preempt(self, rec: PodRecord, now: float) -> Optional[Decision]:
        """Evict strictly lower-priority *preemptible* pods from one
        healthy node so ``rec`` fits — cost-ranked across nodes by
        (victim priority sum, victim count), so the cheapest eviction set
        cluster-wide wins. Victims are checkpointed (``checkpoint_cb``,
        the §4.5.4 path) and requeued with their spec and state intact —
        preemption moves work, it never loses it. Equal-or-higher
        priority and non-preemptible classes are never victims."""
        if self.use_index and self._index is not None and \
                not self._index.has_victims_below(rec.priority):
            # histogram early-out: zero bound preemptible pods below this
            # priority anywhere -> the walk below cannot choose victims
            return None
        best = None
        for node in self.cluster.nodes.values():
            # every non-capacity constraint still applies to the preemptor:
            # only chips/HBM may be freed by evicting, never tolerations,
            # selectors, affinity, the owner's quota, or the walltime
            # lease (which also keeps draining nodes out)
            infeasible = any(
                f(rec, node, self, now) is not None
                for f in self.filters if f is not filter_resources)
            if infeasible:
                continue
            victims = sorted(
                (v for v in self.cluster.pods_on(node.name)
                 if v.priority < rec.priority and v.preemptible
                 and v.pod.phase not in (PodPhase.SUCCEEDED,
                                         PodPhase.FAILED)),
                # cheapest tier first; within a tier the youngest pod
                # (least progress to lose) goes first
                key=lambda v: (v.priority, -v.submitted_at))
            freed_chips = node.free_chips()
            freed_hbm = node.free_hbm()
            chosen = []
            for v in victims:
                if freed_chips >= rec.pod.request_chips and \
                        freed_hbm >= rec.pod.request_hbm_bytes:
                    break
                chosen.append(v)
                freed_chips += v.pod.request_chips
                freed_hbm += v.pod.request_hbm_bytes
            if not chosen or freed_chips < rec.pod.request_chips or \
                    freed_hbm < rec.pod.request_hbm_bytes:
                # zero victims means select_node already rejected this node
                # for a non-preemptable reason — nothing to free here
                continue
            # cost-ranked by (victim priority sum, checkpoint-transfer
            # seconds to re-home the victims' state off this node's site,
            # victim count): between equal-priority eviction sets, prefer
            # the one whose state is cheap to move — without a topology
            # (or stateless victims) the transfer term is 0 everywhere
            # and the ranking reduces to the old (priority, count) order
            cost = (sum(v.priority for v in chosen),
                    round(self._transfer_penalty(chosen, node), 6),
                    len(chosen))
            if best is None or cost < best[0]:
                best = (cost, node, chosen)
        if best is None:
            return None
        _, node, chosen = best
        names = []
        for v in chosen:
            state = self.checkpoint_cb(v, now) \
                if self.checkpoint_cb is not None else None
            evicted = self.cluster.evict(
                v.name, now, reason="Preempted",
                message=f"for {rec.name} (priority {rec.priority})")
            if evicted is None:
                continue
            # requeue the victim: same spec, fresh scheduling bookkeeping,
            # and the just-taken checkpoint (falling back to whatever
            # state the record already carried)
            requeued = self.cluster.submit(
                _reset_pod(evicted.pod), now, owner=evicted.owner,
                priority=evicted.priority,
                priority_class=evicted.priority_class,
                preemptible=evicted.preemptible,
                request_kv_pages=evicted.request_kv_pages,
                expected_duration=evicted.expected_duration,
                site_selector=evicted.site_selector,
                site_anti_affinity=evicted.site_anti_affinity,
                data_stream=evicted.data_stream,
                restored_from=v.name if state is not None
                else evicted.restored_from,
                restored_state=state if state is not None
                else evicted.restored_state)
            requeued.next_retry = now   # eligible immediately
            names.append(v.name)
        self.cluster.assign(rec.name, node.name, now)
        return Decision(rec.name, node.name, "preempted", tuple(names))

    def _victim_state_bytes(self, v: PodRecord) -> int:
        """Checkpoint footprint estimate for a preemption victim: the
        actual restored-state array bytes when the pod carries state,
        else a nominal footprint from its declared KV page pool (2 KiB
        per page stands in for the page's KV payload)."""
        st = v.restored_state
        if st:
            return sum(int(getattr(x, "nbytes", 0)) for x in st.values())
        return int(v.request_kv_pages) * 2048

    def _transfer_penalty(self, chosen, node) -> float:
        """Summed cheapest-destination transfer seconds for the victims'
        checkpoint state, were it re-homed off ``node``'s site."""
        if self.topology is None:
            return 0.0
        sites = {n.site for n in self.cluster.nodes.values()} - {node.site}
        if not sites:
            return 0.0
        total = 0.0
        for v in chosen:
            b = self._victim_state_bytes(v)
            if b:
                total += min(self.topology.transfer_cost(b, node.site, s)
                             for s in sites)
        return total

    # -------------------------------------------------- wake-on-freed
    def _woken(self, rec: PodRecord, wake_cap: bool,
               wake_owners: Set[str]) -> bool:
        """Does a freed-capacity / released-quota delta re-arm this
        parked pod right now (ahead of its backoff timer)? Only pods
        parked by a scheduling *failure* wake — a pod deferred by hand
        or re-tiered by ``set_priority`` keeps its explicit timer — and
        a quota-blocked pod only wakes for its own owner's quota (more
        chips cannot cure a fair-share cap, and vice versa)."""
        if rec.attempts == 0 or not rec.last_reason:
            return False
        if is_quota_blocked(rec.last_reason):
            return rec.owner in wake_owners
        return wake_cap

    # ------------------------------------------------------- main loop
    def run_once(self, now: float) -> List[Decision]:
        """One reconcile pass over the pending queue, ordered by
        (priority desc, fair-share ratio asc, FIFO): among equal
        priorities the owner furthest below its quota binds first. Pods
        in backoff are skipped until their retry time — unless a
        capacity-freed or quota-released delta arrived since the last
        pass (``wake_on_freed``), which re-arms the pods that delta
        could actually help; the jittered exponential backoff remains
        as the fallback for anything the wake signals miss."""
        out = []
        ledger = self.cluster.ledger
        fair = bool(self.cluster.quotas)
        wake_cap, wake_owners = False, frozenset()
        if self.wake_on_freed:
            wake_cap = self._wake_capacity
            wake_owners = self._wake_quota_owners
        self._wake_capacity = False
        self._wake_quota_owners = set()
        pending = sorted(
            self.cluster.pending_pods(),
            key=lambda r: (-r.priority,
                           ledger.dominant_share(r.owner) if fair else 0.0,
                           r.submitted_at))
        for rec in pending:
            if rec.name not in self.cluster.pods:
                continue                     # preempted away this pass
            if rec.next_retry > now and \
                    not self._woken(rec, wake_cap, wake_owners):
                continue
            node, reason = self.select_node(rec, now)
            if node is not None:
                self.cluster.assign(rec.name, node.name, now)
                if self.tracer is not None:
                    self.tracer.span("schedule", now, pod=rec.name,
                                     node=node.name, reason=reason)
                out.append(Decision(rec.name, node.name, reason))
                continue
            if self.enable_preemption:
                dec = self._try_preempt(rec, now)
                if dec is not None:
                    if self.tracer is not None:
                        self.tracer.span("preempt", now, pod=dec.pod,
                                         node=dec.node,
                                         victims=tuple(dec.preempted))
                    out.append(dec)
                    continue
            rec.attempts += 1
            changed = reason != rec.last_reason
            rec.last_reason = reason
            # a quota-blocked pod cannot be helped by waiting (only a
            # spec write or a scale-down frees fair share) — park it at
            # the max backoff instead of hot-looping up to it
            if is_quota_blocked(reason):
                backoff = self.backoff_max
            else:
                backoff = min(self.backoff_base * (2 ** (rec.attempts - 1)),
                              self.backoff_max)
                backoff *= 1.0 + self.backoff_jitter * _jitter_u(
                    rec.name, rec.attempts)
            rec.next_retry = now + backoff
            if changed:
                # one event per reason *transition*, not per retry: a pod
                # parked behind a quota for minutes is one audit line
                self.cluster.record(
                    now, KIND_POD, rec.name, "FailedScheduling",
                    f"attempt={rec.attempts} retry_in={backoff:.0f}s"
                    f": {reason}")
            out.append(Decision(rec.name, None, reason))
        return out


def _reset_pod(pod):
    """Fresh incarnation of an evicted pod's spec for requeueing."""
    import dataclasses

    from repro.core.state_machine import Container
    return dataclasses.replace(
        pod, node=None, start_time=None, conditions=[],
        containers=[Container(name=c.name, command=c.command,
                              fail_at=c.fail_at) for c in pod.containers])
