"""Reconciling scheduler — queue-based refactor of the JMS (paper §3).

The seed's ``MatchingService.bind`` filtered, sorted, and mutated nodes in
one imperative shot. Here scheduling is a control loop over the Cluster
store's pending queue:

  * pluggable **filter stages** (predicates: Ready/schedulable,
    tolerations, nodeSelector, affinity, chips/HBM resources, walltime
    lease vs expected duration + drain margin),
  * pluggable **score stages** (non-straggler preference, best-fit HBM —
    the tightest feasible fit wins),
  * **retry with exponential backoff** for unschedulable pods (the queue
    is re-examined every ``run_once``; a FailedScheduling event is
    emitted once per *reason transition*, not per retry — a
    quota-blocked pod parked for minutes logs one line, not hundreds —
    and quota rejections back off at ``backoff_max`` immediately, since
    waiting cannot free a fair-share cap),
  * **QoS preemption**: a pod that cannot fit may evict strictly
    lower-priority *preemptible* pods from a healthy (never draining)
    node — cost-ranked across nodes by (victim priority sum, victim
    count). Victims are checkpointed through the §4.5.4 loop
    (``checkpoint_cb``, wired by the ControlPlane to the
    NodeLifecycleController) and requeued with their spec and state
    intact — preemption moves work, it never loses it. Equal-or-higher
    priority is never preempted.

``MatchingService`` (jms.py) remains as a thin one-shot facade over the
same filter/score stages for legacy callers.

Multi-site federation (paper §1/§4: one control plane spanning JLab,
NERSC, ...): a ``SiteTopology`` — the configurable inter-site latency
matrix plus the map of data streams to their home site — makes site a
first-class scheduling input:

  * ``filter_site``: hard site selector / anti-affinity on the PodRecord,
  * ``score_data_locality``: pin a pod toward the site holding its input
    stream (pay the inter-site latency everywhere else),
  * ``score_site_spread``: spread an owner's replicas across sites so one
    facility outage takes out as few replicas as possible,
  * ``score_site_latency``: among equally-spread sites prefer the one
    closest (by the latency matrix) to the owner's existing footprint.

All four are neutral when the cluster is single-site or the pod carries
no site spec, so single-facility behavior is unchanged.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import KIND_POD, Cluster, PodRecord
from repro.core.jrm import VirtualNode
from repro.core.state_machine import PodPhase

# A filter returns None when the node is feasible, else a reject reason.
FilterStage = Callable[[PodRecord, VirtualNode, "Scheduler", float],
                       Optional[str]]
# A scorer returns a number; higher is better.
ScoreStage = Callable[[PodRecord, VirtualNode, "Scheduler", float], float]


def _jitter_u(name: str, attempt: int) -> float:
    """Deterministic uniform-ish [0, 1) from (pod, attempt): reproducible
    across runs (no RNG state to thread through the control plane), but
    decorrelated across pods so simultaneous failures spread out."""
    return (zlib.crc32(f"{name}#{attempt}".encode()) & 0xFFFFFFFF) / 2**32


@dataclass
class SiteTopology:
    """Federation config: symmetric inter-site latency matrix (ms) and the
    home site of each named data stream (EJFAT/ERSAP source pinning)."""
    latency_ms: Dict[Tuple[str, str], float] = field(default_factory=dict)
    data_sites: Dict[str, str] = field(default_factory=dict)
    default_latency_ms: float = 100.0     # unlisted site pairs

    def latency(self, a: str, b: str) -> float:
        if a == b:
            return 0.0
        return self.latency_ms.get(
            (a, b), self.latency_ms.get((b, a), self.default_latency_ms))

    def connect(self, a: str, b: str, ms: float) -> "SiteTopology":
        self.latency_ms[(a, b)] = ms
        return self

    @staticmethod
    def parse(spec: str, data_spec: str = "") -> "SiteTopology":
        """``"jlab:nersc:40,nersc:ornl:18"`` -> latency entries;
        ``"ejfat=jlab"`` -> data-stream home sites."""
        topo = SiteTopology()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            a, b, ms = part.split(":")
            topo.connect(a, b, float(ms))
        for part in data_spec.split(","):
            part = part.strip()
            if not part:
                continue
            stream, site = part.split("=")
            topo.data_sites[stream] = site
        return topo


# ------------------------------------------------------------ filter stages

def filter_node_ready(rec, node, sched, now):
    st = sched.cluster.node_status.get(node.name)
    if st is None or not st.ready:
        return "node not ready"
    if not st.schedulable:
        return "node cordoned"
    if node.draining(now):
        return "node draining"
    return None


def filter_tolerations(rec, node, sched, now):
    if not node.tolerates(rec.pod):
        return "taint not tolerated"
    return None


def filter_node_selector(rec, node, sched, now):
    lab = node.labels(now)
    for k, v in rec.pod.node_selector.items():
        if lab.get(k) != v:
            return f"nodeSelector {k}={v} unmatched"
    return None


def filter_affinity(rec, node, sched, now):
    if rec.pod.affinity and not node.matches(rec.pod.affinity, now):
        return "affinity unmatched"
    return None


def filter_resources(rec, node, sched, now):
    if node.free_chips() < rec.pod.request_chips:
        return "insufficient chips"
    if node.free_hbm() < rec.pod.request_hbm_bytes:
        return "insufficient HBM"
    return None


def filter_walltime(rec, node, sched, now):
    """§4.5.4: only place work that can finish before the drain margin."""
    left = node.alive_left(now)
    if left != float("inf") and \
            left < rec.expected_duration + node.drain_margin:
        return "walltime lease too short"
    return None


def filter_site(rec, node, sched, now):
    """Federation: hard site selector + anti-affinity on the PodRecord."""
    if rec.site_selector and node.site not in rec.site_selector:
        return f"site {node.site} not in selector {list(rec.site_selector)}"
    if node.site in rec.site_anti_affinity:
        return f"site {node.site} excluded by anti-affinity"
    return None


def filter_quota(rec, node, sched, now):
    """QoS: the owner's fair-share quota (cluster-wide and per-site) must
    cover this pod's chips/HBM/kv-page requests on top of what the owner
    already has bound. Usage is derived from the store by the ledger, so
    preempt -> requeue -> reschedule re-balances the books automatically.
    Neutral when no quotas are declared."""
    return sched.cluster.ledger.check(rec, node)


DEFAULT_FILTERS: List[FilterStage] = [
    filter_node_ready, filter_tolerations, filter_node_selector,
    filter_affinity, filter_site, filter_quota, filter_resources,
    filter_walltime,
]


# The one classifier over select_node's composed reject string
# ("node: reason; node: reason; ..."), kept next to the filters that
# emit the reasons so wording and parsing cannot drift apart
# (consumers: run_once's quota park, jcs reprovision's starved-chips).

def _reject_reasons(reason: str) -> List[str]:
    """Per-node reject reasons with the 'node: ' prefix stripped (so a
    node or owner name never masquerades as a reject kind)."""
    return [p.split(": ", 1)[-1] for p in reason.split("; ") if p]


def is_quota_blocked(reason: str) -> bool:
    """Every node rejected the pod for its owner's quota (filter_quota):
    waiting cannot help — only a spec write or scale-down frees share."""
    parts = _reject_reasons(reason)
    return bool(parts) and all(p.startswith("quota:") for p in parts)


def is_capacity_starved(reason: str) -> bool:
    """Some node rejected the pod for chips/HBM (filter_resources) —
    the rejections more capacity could actually cure; quota rejects
    (whose message also names the resource) are excluded."""
    return any(p.startswith("insufficient")
               for p in _reject_reasons(reason)
               if not p.startswith("quota:"))


# ------------------------------------------------------------- score stages

# Scorers are compared LEXICOGRAPHICALLY in list order: a later stage only
# breaks ties left by every earlier stage, so magnitudes never leak across
# stages.

def score_non_straggler(rec, node, sched, now):
    """Stage 1: avoid straggler nodes (heartbeat-latency signal from JFM)."""
    st = sched.cluster.node_status.get(node.name)
    return -1.0 if (st is not None and st.straggler) else 0.0


def _peer_sites(rec, sched) -> Dict[str, int]:
    """Bound replicas of ``rec``'s owner, counted per site. Memoized on
    the cluster's watch version: scoring evaluates every candidate node
    (x2 site stages) per pod, and rescanning the pod table each time
    turned the §5.1 forty-node bring-up O(pods^2 x nodes)."""
    if rec.owner is None:
        return {}
    key = (rec.owner, sched.cluster.version)
    cached = sched._peer_site_cache
    if cached is not None and cached[0] == key:
        return cached[1]
    out: Dict[str, int] = {}
    for peer in sched.cluster.pods_of(rec.owner):
        node = sched.cluster.nodes.get(peer.pod.node) if peer.bound else None
        if node is not None:
            out[node.site] = out.get(node.site, 0) + 1
    sched._peer_site_cache = (key, out)
    return out


def score_data_locality(rec, node, sched, now):
    """Stage 2: pin toward the site holding the pod's input stream; any
    other site pays that stream's inter-site latency."""
    topo = sched.topology
    if topo is None or rec.data_stream is None:
        return 0.0
    home = topo.data_sites.get(rec.data_stream)
    if home is None:
        return 0.0
    return -topo.latency(home, node.site)


def score_site_spread(rec, node, sched, now):
    """Stage 3: spread an owner's replicas across sites — a whole-facility
    outage (walltime cliff, network partition) takes out as few replicas
    as possible."""
    return -float(_peer_sites(rec, sched).get(node.site, 0))


def score_site_latency(rec, node, sched, now):
    """Stage 4: latency-weighted cross-site spreading — among equally
    spread candidates, prefer the site closest (by the topology matrix) to
    where the owner's replicas already run, so cross-site spillover lands
    on the cheapest link."""
    topo = sched.topology
    if topo is None:
        return 0.0
    peers = _peer_sites(rec, sched)
    others = [s for s in peers if s != node.site]
    if not others:
        return 0.0
    return -sum(topo.latency(node.site, s) * peers[s]
                for s in others) / sum(peers[s] for s in others)


def score_bestfit_hbm(rec, node, sched, now):
    """Stage 5: tightest absolute HBM fit that still holds the pod (the
    seed JMS policy)."""
    return -(node.free_hbm() - rec.pod.request_hbm_bytes)


def score_spread(rec, node, sched, now):
    """Stage 6: balance pods across nodes so one drained lease takes out
    as few replicas as possible."""
    return -node.used_chips() / max(float(node.slice_spec.chips), 1.0)


DEFAULT_SCORERS: List[ScoreStage] = [
    score_non_straggler, score_data_locality, score_site_spread,
    score_site_latency, score_bestfit_hbm, score_spread,
]


@dataclass
class Decision:
    pod: str
    node: Optional[str]
    reason: str = ""
    preempted: Tuple[str, ...] = ()


@dataclass
class Scheduler:
    cluster: Cluster
    filters: List[FilterStage] = field(
        default_factory=lambda: list(DEFAULT_FILTERS))
    scorers: List[ScoreStage] = field(
        default_factory=lambda: list(DEFAULT_SCORERS))
    backoff_base: float = 5.0
    backoff_max: float = 60.0
    # decorrelation jitter on the exponential backoff: each retry is
    # stretched by up to this fraction, derived deterministically from
    # (pod name, attempt) — a mass node failure requeues hundreds of
    # pods at the same instant, and without jitter they all retry (and
    # all fail, and all retry again) in synchronized storms. 0 disables.
    backoff_jitter: float = 0.25
    enable_preemption: bool = True
    topology: Optional[SiteTopology] = None     # federation config
    # §4.5.4 hook for preemption victims: ControlPlane wires this to
    # NodeLifecycleController.checkpoint_pod so an evicted victim's
    # runtime state rides its requeued record (None -> no checkpoint)
    checkpoint_cb: Optional[Callable[[PodRecord, float], Optional[dict]]] = \
        None
    _peer_site_cache: Optional[tuple] = field(default=None, repr=False)

    # ------------------------------------------------------ single pod
    def feasible(self, rec: PodRecord, node: VirtualNode,
                 now: float) -> Optional[str]:
        for f in self.filters:
            reason = f(rec, node, self, now)
            if reason is not None:
                return reason
        return None

    def score(self, rec: PodRecord, node: VirtualNode,
              now: float) -> Tuple[float, ...]:
        """Lexicographic key: scorers[0] dominates, later ones break ties."""
        return tuple(s(rec, node, self, now) for s in self.scorers)

    def select_node(self, rec: PodRecord,
                    now: float) -> Tuple[Optional[VirtualNode], str]:
        reasons = []
        cands = []
        for node in self.cluster.nodes.values():
            reason = self.feasible(rec, node, now)
            if reason is None:
                cands.append(node)
            else:
                reasons.append(f"{node.name}: {reason}")
        if not cands:
            return None, "; ".join(reasons) or "no nodes registered"
        best = max(cands, key=lambda n: self.score(rec, n, now))
        return best, "best-fit"

    # ------------------------------------------------------ preemption
    def _try_preempt(self, rec: PodRecord, now: float) -> Optional[Decision]:
        """Evict strictly lower-priority *preemptible* pods from one
        healthy node so ``rec`` fits — cost-ranked across nodes by
        (victim priority sum, victim count), so the cheapest eviction set
        cluster-wide wins. Victims are checkpointed (``checkpoint_cb``,
        the §4.5.4 path) and requeued with their spec and state intact —
        preemption moves work, it never loses it. Equal-or-higher
        priority and non-preemptible classes are never victims."""
        best = None
        for node in self.cluster.nodes.values():
            # every non-capacity constraint still applies to the preemptor:
            # only chips/HBM may be freed by evicting, never tolerations,
            # selectors, affinity, the owner's quota, or the walltime
            # lease (which also keeps draining nodes out)
            infeasible = any(
                f(rec, node, self, now) is not None
                for f in self.filters if f is not filter_resources)
            if infeasible:
                continue
            victims = sorted(
                (v for v in self.cluster.pods_on(node.name)
                 if v.priority < rec.priority and v.preemptible
                 and v.pod.phase not in (PodPhase.SUCCEEDED,
                                         PodPhase.FAILED)),
                # cheapest tier first; within a tier the youngest pod
                # (least progress to lose) goes first
                key=lambda v: (v.priority, -v.submitted_at))
            freed_chips = node.free_chips()
            freed_hbm = node.free_hbm()
            chosen = []
            for v in victims:
                if freed_chips >= rec.pod.request_chips and \
                        freed_hbm >= rec.pod.request_hbm_bytes:
                    break
                chosen.append(v)
                freed_chips += v.pod.request_chips
                freed_hbm += v.pod.request_hbm_bytes
            if not chosen or freed_chips < rec.pod.request_chips or \
                    freed_hbm < rec.pod.request_hbm_bytes:
                # zero victims means select_node already rejected this node
                # for a non-preemptable reason — nothing to free here
                continue
            cost = sum(v.priority for v in chosen), len(chosen)
            if best is None or cost < best[0]:
                best = (cost, node, chosen)
        if best is None:
            return None
        _, node, chosen = best
        names = []
        for v in chosen:
            state = self.checkpoint_cb(v, now) \
                if self.checkpoint_cb is not None else None
            evicted = self.cluster.evict(
                v.name, now, reason="Preempted",
                message=f"for {rec.name} (priority {rec.priority})")
            if evicted is None:
                continue
            # requeue the victim: same spec, fresh scheduling bookkeeping,
            # and the just-taken checkpoint (falling back to whatever
            # state the record already carried)
            requeued = self.cluster.submit(
                _reset_pod(evicted.pod), now, owner=evicted.owner,
                priority=evicted.priority,
                priority_class=evicted.priority_class,
                preemptible=evicted.preemptible,
                request_kv_pages=evicted.request_kv_pages,
                expected_duration=evicted.expected_duration,
                site_selector=evicted.site_selector,
                site_anti_affinity=evicted.site_anti_affinity,
                data_stream=evicted.data_stream,
                restored_from=v.name if state is not None
                else evicted.restored_from,
                restored_state=state if state is not None
                else evicted.restored_state)
            requeued.next_retry = now   # eligible immediately
            names.append(v.name)
        self.cluster.assign(rec.name, node.name, now)
        return Decision(rec.name, node.name, "preempted", tuple(names))

    # ------------------------------------------------------- main loop
    def run_once(self, now: float) -> List[Decision]:
        """One reconcile pass over the pending queue, ordered by
        (priority desc, fair-share ratio asc, FIFO): among equal
        priorities the owner furthest below its quota binds first. Pods
        in backoff are skipped until their retry time."""
        out = []
        ledger = self.cluster.ledger
        fair = bool(self.cluster.quotas)
        pending = sorted(
            self.cluster.pending_pods(),
            key=lambda r: (-r.priority,
                           ledger.dominant_share(r.owner) if fair else 0.0,
                           r.submitted_at))
        for rec in pending:
            if rec.name not in self.cluster.pods:
                continue                     # preempted away this pass
            if rec.next_retry > now:
                continue
            node, reason = self.select_node(rec, now)
            if node is not None:
                self.cluster.assign(rec.name, node.name, now)
                out.append(Decision(rec.name, node.name, reason))
                continue
            if self.enable_preemption:
                dec = self._try_preempt(rec, now)
                if dec is not None:
                    out.append(dec)
                    continue
            rec.attempts += 1
            changed = reason != rec.last_reason
            rec.last_reason = reason
            # a quota-blocked pod cannot be helped by waiting (only a
            # spec write or a scale-down frees fair share) — park it at
            # the max backoff instead of hot-looping up to it
            if is_quota_blocked(reason):
                backoff = self.backoff_max
            else:
                backoff = min(self.backoff_base * (2 ** (rec.attempts - 1)),
                              self.backoff_max)
                backoff *= 1.0 + self.backoff_jitter * _jitter_u(
                    rec.name, rec.attempts)
            rec.next_retry = now + backoff
            if changed:
                # one event per reason *transition*, not per retry: a pod
                # parked behind a quota for minutes is one audit line
                self.cluster.record(
                    now, KIND_POD, rec.name, "FailedScheduling",
                    f"attempt={rec.attempts} retry_in={backoff:.0f}s"
                    f": {reason}")
            out.append(Decision(rec.name, None, reason))
        return out


def _reset_pod(pod):
    """Fresh incarnation of an evicted pod's spec for requeueing."""
    import dataclasses

    from repro.core.state_machine import Container
    return dataclasses.replace(
        pod, node=None, start_time=None, conditions=[],
        containers=[Container(name=c.name, command=c.command,
                              fail_at=c.fail_at) for c in pod.containers])
