"""Cluster — the declarative control plane's API-server analog.

The seed wired JRM/JMS/JFM together imperatively: callers hand-created
pods by naming convention and mutated nodes directly. This module is the
pivot to Kubernetes semantics (paper §3-§4): *desired state* lives in a
typed object store, *controllers* reconcile it, and every state change is
observable through a watch bus and an Event store.

Module map (object-store / scheduler / controller split):

  cluster.py  (this file)
      Typed object store for Nodes, Pods, Deployments, Events.
      - ``Cluster`` keeps the authoritative dicts, assigns/evicts pods on
        ``VirtualNode``s (the kubelet action), and emits ``WatchEvent``s
        (ADDED/MODIFIED/DELETED) to subscribers plus human-readable
        ``ClusterEvent``s (Scheduled / Draining / Evicted / Rescheduled
        ...) to the event store — the §4.5.4 walltime loop becomes an
        auditable trail.
      - ``Deployment`` + ``PodTemplate`` hold desired state only
        (``replicas``); nothing here creates pods.
      - ``NodeStatus`` is the JFM-fed heartbeat record (jfm.feed()).

  scheduler.py
      Queue-based scheduler (refactor of JMS): pending pods go through
      pluggable filter stages (ready, tolerations, selector/affinity,
      resources, walltime lease) and score stages (non-straggler,
      best-fit HBM), with retry/backoff for unschedulable pods and
      drain-aware priority preemption.

  controllers.py
      ``DeploymentController`` converges ``spec.replicas`` -> pods;
      ``NodeLifecycleController`` watches walltime leases, checkpoints
      pods on draining nodes via ``repro.checkpoint``, evicts them and
      hands their state to the replacement pod (closing §4.5.4);
      ``ControlPlane`` bundles both with the scheduler into one
      ``step(now)`` reconcile loop.

Writers (HPA, the digital-twin policy, users) only touch *spec* fields;
observers (StreamEngine, benchmarks, tests) read *status* and the event
trail. That inversion is what unlocks node churn, multi-site pools, and
preemption without request loss in one architecture.

Multi-site federation: every ``VirtualNode`` carries a ``site`` identity
(JLab / NERSC / ... — paper §1, §4), and the store exposes per-site pools
(``site_nodes``) plus aggregate ``SiteView``s (capacity, remaining
walltime after the drain margin, heartbeat health). Scheduling consumes
sites through the filter/score stages in ``scheduler.py``; the JCS uses
``SiteView.remaining_walltime`` to re-provision pilots proactively.

QoS (``qos.py``): the store also holds ``PriorityClass`` objects and
per-owner fair-share ``Quota``s. Pods carry ``priority_class`` /
``preemptible`` (resolved from the class at submit); ``set_priority`` is
the priority analog of ``scale`` — a spec write the digital twin / HPA
use to escalate the serving Deployment during pressure spikes, applied
to live and pending pods so preemption order follows immediately. The
``ledger`` (a ``qos.QuotaLedger``) derives per-owner usage from bound
pods and backs the scheduler's ``filter_quota`` stage.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import qos
from repro.core.jrm import VirtualNode
from repro.core.state_machine import Container, Pod, PodPhase

# Watch event types (k8s watch semantics)
ADDED = "ADDED"
MODIFIED = "MODIFIED"
DELETED = "DELETED"

KIND_NODE = "Node"
KIND_POD = "Pod"
KIND_DEPLOYMENT = "Deployment"
KIND_PRIORITY_CLASS = "PriorityClass"
KIND_QUOTA = "Quota"


@dataclass
class WatchEvent:
    kind: str                 # Node | Pod | Deployment
    type: str                 # ADDED | MODIFIED | DELETED
    name: str
    obj: object = None
    # what changed, for O(1) subscriber dispatch without diffing the
    # object: "heartbeat" | "status" | "bind" | "phase" | "walltime" |
    # "reachable" | "fence" | "cordon" | "spec" | "" (structural)
    reason: str = ""


@dataclass
class ClusterEvent:
    """k8s Event analog: one line of the audit trail."""
    time: float
    kind: str
    name: str                 # object the event is about
    reason: str               # Scheduled | Draining | Evicted | ...
    message: str = ""


@dataclass
class SiteView:
    """Aggregate status of one facility's node pool (the cross-facility
    §1/§4 claim made queryable): capacity, walltime runway, health."""
    name: str
    nodes: int = 0
    ready_nodes: int = 0
    draining_nodes: int = 0
    total_chips: int = 0
    free_chips: int = 0
    total_hbm: int = 0
    free_hbm: int = 0
    pods: int = 0
    # sum over ready schedulable nodes of usable lease time (alive_left
    # minus the §4.5.4 drain margin); inf when any node has no walltime
    remaining_walltime: float = 0.0
    min_walltime: float = float("inf")
    max_heartbeat_age: float = 0.0


@dataclass
class NodeStatus:
    """Heartbeat-derived node condition, fed by jfm.FacilityManager."""
    ready: bool = True
    schedulable: bool = True          # False once cordoned for draining
    heartbeat_age: float = 0.0
    heartbeat_latency: float = 0.0
    straggler: bool = False
    last_transition: float = 0.0
    # False while a network partition separates the node from the control
    # plane: the node may be alive and serving, but heartbeats don't
    # arrive and kubelet calls (CreatePod/DeletePod) can't reach it
    reachable: bool = True


def _default_containers(name: str) -> List[Container]:
    return [Container(name="engine")]


@dataclass
class PodTemplate:
    """Spec stamped onto every pod a Deployment owns."""
    labels: Dict[str, str] = field(default_factory=dict)
    tolerations: List[dict] = field(default_factory=list)
    node_selector: Dict[str, str] = field(default_factory=dict)
    affinity: List[dict] = field(default_factory=list)
    request_chips: int = 0
    request_hbm_bytes: int = 0
    # declared KV page-pool footprint per replica (paged serving): the
    # statically-enforceable currency of the kv_pages quota dimension
    request_kv_pages: int = 0
    expected_duration: float = 0.0
    priority: int = 0
    # QoS: named tier; when set it resolves to priority/preemptible at
    # submit (the numeric ``priority`` above is the classless fallback)
    priority_class: str = ""
    # federation spec: hard site constraints + the input stream whose home
    # site the data-locality scorer pins toward (scheduler.SiteTopology)
    site_selector: Tuple[str, ...] = ()
    site_anti_affinity: Tuple[str, ...] = ()
    data_stream: Optional[str] = None
    container_factory: Callable[[str], List[Container]] = _default_containers
    # drain support: returns the pod's checkpointable runtime state
    # (a pytree of numpy-convertible leaves) for repro.checkpoint
    checkpoint_state: Optional[Callable[[str], dict]] = None

    def instantiate(self, name: str) -> Pod:
        return Pod(name=name,
                   containers=self.container_factory(name),
                   labels=dict(self.labels),
                   node_selector=dict(self.node_selector),
                   affinity=[dict(a) for a in self.affinity],
                   tolerations=[dict(t) for t in self.tolerations],
                   request_chips=self.request_chips,
                   request_hbm_bytes=self.request_hbm_bytes)


@dataclass
class Deployment:
    """Desired state only: ``replicas`` is written by HPA / the digital
    twin / users; the DeploymentController converges actual pods to it."""
    name: str
    replicas: int
    template: PodTemplate = field(default_factory=PodTemplate)
    next_ordinal: int = 0             # monotonic pod-name counter

    def next_pod_name(self) -> str:
        name = f"{self.name}-{self.next_ordinal}"
        self.next_ordinal += 1
        return name


@dataclass
class PodRecord:
    """A pod plus the control-plane metadata the bare state-machine Pod
    doesn't carry (owner, priority, scheduling bookkeeping)."""
    pod: Pod
    owner: Optional[str] = None            # owning Deployment name
    priority: int = 0
    priority_class: str = ""               # QoS tier the priority came from
    preemptible: bool = True               # may be a preemption victim
    request_kv_pages: int = 0              # declared KV pool footprint
    expected_duration: float = 0.0
    submitted_at: float = 0.0
    # federation spec (copied from the PodTemplate; see scheduler stages)
    site_selector: Tuple[str, ...] = ()
    site_anti_affinity: Tuple[str, ...] = ()
    data_stream: Optional[str] = None
    # scheduler bookkeeping (retry/backoff)
    attempts: int = 0
    next_retry: float = 0.0
    last_reason: str = ""
    # drain/reschedule lineage
    restored_from: Optional[str] = None    # predecessor pod name
    restored_state: Optional[dict] = None  # checkpointed runtime state
    # epoch fencing: monotonically increasing cluster-wide binding
    # counter stamped at assign(); a node that rejoins after a partition
    # only holds bindings at-or-below its recorded fence floor, so its
    # orphaned pods are discarded instead of double-serving (split-brain)
    binding_epoch: int = 0
    # submission-order stamp (store index materializations sort on it so
    # pods_on returns submission order, not bind order)
    seq: int = 0

    @property
    def name(self) -> str:
        return self.pod.name

    @property
    def bound(self) -> bool:
        return self.pod.node is not None


class Cluster:
    """Typed object store + watch bus + event trail (see module map)."""

    def __init__(self, events_cap: int = 0):
        self.nodes: Dict[str, VirtualNode] = {}
        self.node_status: Dict[str, NodeStatus] = {}
        self.pods: Dict[str, PodRecord] = {}
        self.deployments: Dict[str, Deployment] = {}
        self.events: List[ClusterEvent] = []
        # ring cap on the event trail for long soaks (0 = unbounded);
        # ``events_truncated`` is the explicit marker audits check so a
        # trimmed trail is distinguishable from a short one
        self.events_cap = int(events_cap)
        self.events_truncated = 0
        # QoS objects: named tiers + per-owner fair-share caps, and the
        # derived-usage ledger the scheduler's quota filter consults
        self.priority_classes: Dict[str, qos.PriorityClass] = \
            qos.default_priority_classes()
        self.quotas: Dict[Tuple[str, Optional[str]], qos.Quota] = {}
        # epoch fencing state: last issued binding epoch, plus per-node
        # fence floors (highest epoch evicted while the node was
        # unreachable — anything at or below is stale on rejoin)
        self.binding_epoch = 0
        self.fence_epochs: Dict[str, int] = {}
        self.version = 0              # bumps on every watch emission
        self._watchers: Dict[str, List[Callable[[WatchEvent], None]]] = {}
        self._uid = itertools.count(1)
        # watch-bus dispatch queue (breadth-ordered delivery even when a
        # subscriber's callback writes back into the store) + counters
        self._dispatch_queue: deque = deque()
        self._dispatching = False
        self.deltas_emitted = 0       # WatchEvents produced
        self.deltas_dispatched = 0    # callback deliveries performed
        # secondary store indices, maintained at the mutation sites so
        # pending_pods / pods_on / pods_of are O(result) not O(store)
        self._pod_seq = itertools.count(1)    # submission order stamp
        self._pending: Dict[str, PodRecord] = {}
        self._pods_by_owner: Dict[str, Dict[str, PodRecord]] = {}
        self._pods_by_node: Dict[str, Dict[str, PodRecord]] = {}
        # the ledger subscribes to the watch bus, so it must come last
        self.ledger = qos.QuotaLedger(self)

    # ------------------------------------------------------- watch bus
    def watch(self, kind: str, callback: Callable[[WatchEvent], None]):
        """Subscribe ``callback`` to ``kind`` deltas. Returns an
        unsubscribe handle; calling it (even from inside a dispatch, even
        from the callback itself) is safe — an unsubscribed callback is
        never invoked again, including for deltas already queued."""
        subs = self._watchers.setdefault(kind, [])
        subs.append(callback)

        def _unsubscribe():
            try:
                subs.remove(callback)
            except ValueError:
                pass
        return _unsubscribe

    def _emit(self, kind: str, type_: str, name: str, obj=None,
              reason: str = ""):
        """Queue-based dispatch: if a callback writes back into the store,
        the nested delta is appended to the queue and delivered after the
        current one finishes its subscriber list — every subscriber sees
        every delta exactly once, in emission order, with no recursion."""
        self.version += 1
        self.deltas_emitted += 1
        self._dispatch_queue.append(WatchEvent(kind, type_, name, obj,
                                               reason))
        if self._dispatching:
            return
        self._dispatching = True
        try:
            while self._dispatch_queue:
                ev = self._dispatch_queue.popleft()
                subs = self._watchers.get(ev.kind)
                if not subs:
                    continue
                for cb in list(subs):
                    if cb not in subs:      # unsubscribed mid-dispatch
                        continue
                    self.deltas_dispatched += 1
                    cb(ev)
        finally:
            self._dispatching = False

    # ----------------------------------------------------- event store
    def record(self, now: float, kind: str, name: str, reason: str,
               message: str = ""):
        self.events.append(ClusterEvent(now, kind, name, reason, message))
        if self.events_cap and len(self.events) > self.events_cap:
            drop = len(self.events) - self.events_cap
            del self.events[:drop]
            self.events_truncated += drop

    def events_for(self, name: str) -> List[ClusterEvent]:
        return [e for e in self.events if e.name == name]

    def event_reasons(self, name: Optional[str] = None) -> List[str]:
        evs = self.events if name is None else self.events_for(name)
        return [e.reason for e in evs]

    # ----------------------------------------------------------- nodes
    def register_node(self, node: VirtualNode, now: float = 0.0):
        self.nodes[node.name] = node
        self.node_status[node.name] = NodeStatus(
            ready=node.ready, last_transition=now)
        self._emit(KIND_NODE, ADDED, node.name, node)
        self.record(now, KIND_NODE, node.name, "Registered",
                    f"site={node.site} chips={node.slice_spec.chips}")
        return node

    def deregister_node(self, name: str, now: float = 0.0):
        node = self.nodes.pop(name, None)
        self.node_status.pop(name, None)
        if node is not None:
            self._emit(KIND_NODE, DELETED, name, node)
        return node

    def heartbeat(self, name: str, now: float, latency: float = 0.0):
        """Node-side heartbeat: ticks the VK lease clock and refreshes the
        status record. JFM's feed() refines straggler/staleness on top.
        Heartbeats from a partitioned node never arrive — the API-server
        boundary drops them, so staleness accrues and the lifecycle
        controller eventually declares the node dead."""
        node = self.nodes[name]
        st0 = self.node_status.get(name)
        if st0 is not None and not st0.reachable:
            return False
        node.tick(now, latency=latency)
        st = self.node_status[name]
        st.heartbeat_age = 0.0
        st.heartbeat_latency = latency
        if st.ready != node.ready:
            st.ready = node.ready
            st.last_transition = now
            self.record(now, KIND_NODE, name,
                        "Ready" if node.ready else "NotReady",
                        f"alive_left={node.alive_left(now):.0f}")
            self._emit(KIND_NODE, MODIFIED, name, node, reason="status")
        # every heartbeat is a delta (reason="heartbeat"): the lifecycle
        # controller's staleness clock keys off it, and it is the bulk of
        # the bus load at scale — subscribers must handle it in O(1) and
        # must NOT treat it as a capacity or eligibility change
        self._emit(KIND_NODE, MODIFIED, name, node, reason="heartbeat")
        return node.ready

    def set_node_status(self, name: str, now: float, *, ready: bool,
                        heartbeat_age: float = 0.0,
                        heartbeat_latency: float = 0.0,
                        straggler: bool = False):
        """JFM feed path: overwrite the scraped condition."""
        st = self.node_status.setdefault(name, NodeStatus())
        changed = st.ready != ready
        straggler_changed = st.straggler != straggler
        st.heartbeat_age = heartbeat_age
        st.heartbeat_latency = heartbeat_latency
        st.straggler = straggler
        if changed:
            st.ready = ready
            st.last_transition = now
            self.record(now, KIND_NODE, name,
                        "Ready" if ready else "NotReady",
                        f"heartbeat_age={heartbeat_age:.0f}")
            self._emit(KIND_NODE, MODIFIED, name, self.nodes.get(name),
                       reason="status")
        elif straggler_changed:
            # a straggler flip regroups the node in the scheduler's
            # capacity index even when readiness is unchanged
            self._emit(KIND_NODE, MODIFIED, name, self.nodes.get(name),
                       reason="status")

    def set_reachable(self, name: str, now: float, reachable: bool):
        """Partition / rejoin transition at the API-server boundary. A
        rejoin does NOT fence by itself — the lifecycle controller calls
        ``fence_node`` once it observes the node back and healthy."""
        st = self.node_status[name]
        if st.reachable == reachable:
            return
        st.reachable = reachable
        self.record(now, KIND_NODE, name,
                    "Rejoined" if reachable else "Partitioned",
                    f"fence_epoch={self.fence_epochs.get(name, 0)}")
        self._emit(KIND_NODE, MODIFIED, name, self.nodes.get(name),
                   reason="reachable")

    def orphaned_pods(self, node_name: str) -> List[Pod]:
        """Pod objects still held by the node's kubelet with no matching
        record in the store (evicted while the node was unreachable)."""
        node = self.nodes.get(node_name)
        if node is None:
            return []
        out = []
        for pod in list(node.pods.values()):
            rec = self.pods.get(pod.name)
            if rec is None or rec.pod is not pod:
                out.append(pod)
        return out

    def fence_node(self, name: str, now: float) -> List[str]:
        """Epoch fence on rejoin: every orphaned pod on the node was
        bound at or below the node's fence floor and has since been
        re-served elsewhere under a higher epoch — delete it so the stale
        replica can never double-emit. Returns the fenced pod names."""
        node = self.nodes.get(name)
        if node is None:
            return []
        floor = self.fence_epochs.pop(name, 0)
        fenced = []
        for pod in self.orphaned_pods(name):
            node.delete_pod(pod.name, now)
            fenced.append(pod.name)
            self.record(now, KIND_POD, pod.name, "Fenced",
                        f"node={name} epoch<={floor} "
                        f"current_epoch={self.binding_epoch}")
        if fenced:
            self._emit(KIND_NODE, MODIFIED, name, node, reason="fence")
        return fenced

    def cordon(self, name: str, now: float, reason: str = "Draining"):
        st = self.node_status[name]
        if st.schedulable:
            st.schedulable = False
            self.record(now, KIND_NODE, name, reason,
                        f"alive_left={self.nodes[name].alive_left(now):.0f}")
            self._emit(KIND_NODE, MODIFIED, name, self.nodes[name],
                       reason="cordon")

    def cut_walltime(self, name: str, now: float,
                     remaining: float) -> VirtualNode:
        """Facility-side lease revision (chaos walltime_cut, scontrol
        update): shorten the node's remaining walltime through the store
        so the delta reaches the lifecycle controller's deadline clock —
        mutating ``node.cut_walltime`` directly would leave event-driven
        subscribers believing the old expiry."""
        node = self.nodes[name]
        node.cut_walltime(now, remaining)
        self._emit(KIND_NODE, MODIFIED, name, node, reason="walltime")
        return node

    def schedulable_nodes(self, now: float) -> List[VirtualNode]:
        out = []
        for name, node in self.nodes.items():
            st = self.node_status.get(name)
            if st is None or not st.ready or not st.schedulable \
                    or not st.reachable:
                continue
            if node.draining(now):
                continue
            out.append(node)
        return out

    # ----------------------------------------------------------- sites
    def site_names(self) -> List[str]:
        return sorted({n.site for n in self.nodes.values()})

    def site_nodes(self, site: str) -> List[VirtualNode]:
        """One facility's node pool."""
        return [n for n in self.nodes.values() if n.site == site]

    def site_view(self, site: str, now: float) -> SiteView:
        """Aggregate the facility's capacity, walltime runway, and health."""
        view = SiteView(name=site)
        for node in self.site_nodes(site):
            st = self.node_status.get(node.name)
            view.nodes += 1
            view.total_chips += node.slice_spec.chips
            view.total_hbm += node.slice_spec.hbm_bytes
            view.pods += len(node.pods)
            age = max(st.heartbeat_age if st else 0.0,
                      now - node.last_heartbeat)
            view.max_heartbeat_age = max(view.max_heartbeat_age, age)
            left = node.alive_left(now)
            view.min_walltime = min(view.min_walltime, left)
            if node.draining(now):
                view.draining_nodes += 1
            if st is None or not st.ready:
                continue
            view.ready_nodes += 1
            view.free_chips += node.free_chips()
            view.free_hbm += node.free_hbm()
            if st.schedulable:
                view.remaining_walltime += max(left - node.drain_margin, 0.0)
        return view

    def site_views(self, now: float) -> Dict[str, SiteView]:
        return {s: self.site_view(s, now) for s in self.site_names()}

    # ------------------------------------------------------------- qos
    def apply_priority_class(self, pc: qos.PriorityClass,
                             now: float = 0.0) -> qos.PriorityClass:
        existing = self.priority_classes.get(pc.name)
        self.priority_classes[pc.name] = pc
        self._emit(KIND_PRIORITY_CLASS,
                   MODIFIED if existing else ADDED, pc.name, pc)
        self.record(now, KIND_PRIORITY_CLASS, pc.name, "Applied",
                    f"value={pc.value} preemptible={pc.preemptible}")
        return pc

    def apply_quota(self, quota: qos.Quota, now: float = 0.0) -> qos.Quota:
        existing = self.quotas.get(quota.key)
        self.quotas[quota.key] = quota
        self._emit(KIND_QUOTA, MODIFIED if existing else ADDED,
                   quota.owner, quota)
        self.record(now, KIND_QUOTA, quota.owner, "Applied",
                    f"site={quota.site or '-'} chips={quota.chips} "
                    f"hbm={quota.hbm_bytes} kv_pages={quota.kv_pages}")
        return quota

    def quota_for(self, owner: Optional[str],
                  site: Optional[str] = None) -> Optional[qos.Quota]:
        if owner is None:
            return None
        return self.quotas.get((owner, site))

    def resolve_priority(self, name: str) -> qos.PriorityClass:
        pc = self.priority_classes.get(name)
        if pc is None:
            raise ValueError(f"unknown priority class {name!r} "
                             f"(have {sorted(self.priority_classes)})")
        return pc

    # ------------------------------------------------------------ pods
    def submit(self, pod: Pod, now: float, *, owner: Optional[str] = None,
               priority: int = 0, priority_class: str = "",
               preemptible: Optional[bool] = None,
               request_kv_pages: int = 0,
               expected_duration: float = 0.0,
               site_selector: Tuple[str, ...] = (),
               site_anti_affinity: Tuple[str, ...] = (),
               data_stream: Optional[str] = None,
               restored_from: Optional[str] = None,
               restored_state: Optional[dict] = None) -> PodRecord:
        """Declare a pod. It enters the scheduler queue as Pending; nobody
        hand-picks a node here. A ``priority_class`` resolves to the
        class's numeric value and preemptible bit (the bare ``priority``
        int is the classless fallback)."""
        if pod.name in self.pods:
            raise ValueError(f"pod {pod.name} already exists")
        if priority_class:
            pc = self.resolve_priority(priority_class)
            priority = pc.value
            if preemptible is None:
                preemptible = pc.preemptible
        rec = PodRecord(pod=pod, owner=owner, priority=priority,
                        priority_class=priority_class,
                        preemptible=True if preemptible is None
                        else preemptible,
                        request_kv_pages=request_kv_pages,
                        expected_duration=expected_duration,
                        submitted_at=now, site_selector=tuple(site_selector),
                        site_anti_affinity=tuple(site_anti_affinity),
                        data_stream=data_stream, restored_from=restored_from,
                        restored_state=restored_state,
                        seq=next(self._pod_seq))
        self.pods[pod.name] = rec
        self._pending[pod.name] = rec
        if owner is not None:
            self._pods_by_owner.setdefault(owner, {})[pod.name] = rec
        self._emit(KIND_POD, ADDED, pod.name, rec)
        self.record(now, KIND_POD, pod.name, "Created",
                    f"owner={owner or '-'}")
        return rec

    def assign(self, pod_name: str, node_name: str, now: float) -> PodRecord:
        """Bind decision -> kubelet CreatePod on the chosen node."""
        rec = self.pods[pod_name]
        node = self.nodes[node_name]
        node.create_pod(rec.pod, now)
        self.binding_epoch += 1
        rec.binding_epoch = self.binding_epoch
        self._pending.pop(pod_name, None)
        self._pods_by_node.setdefault(node_name, {})[pod_name] = rec
        reason = "Rescheduled" if rec.restored_from else "Scheduled"
        self.record(now, KIND_POD, pod_name, reason,
                    f"node={node_name} epoch={rec.binding_epoch}")
        self._emit(KIND_POD, MODIFIED, pod_name, rec, reason="bind")
        return rec

    def evict(self, pod_name: str, now: float, reason: str = "Evicted",
              message: str = "") -> Optional[PodRecord]:
        """Graceful removal (SIGTERM analog): terminate containers through
        the public state-machine transition and delete the pod object."""
        rec = self.pods.pop(pod_name, None)
        if rec is None:
            return None
        self._pending.pop(pod_name, None)
        if rec.owner is not None:
            owned = self._pods_by_owner.get(rec.owner)
            if owned is not None:
                owned.pop(pod_name, None)
        if rec.pod.node is not None:
            on_node = self._pods_by_node.get(rec.pod.node)
            if on_node is not None:
                on_node.pop(pod_name, None)
            node = self.nodes.get(rec.pod.node)
            st = self.node_status.get(rec.pod.node)
            if node is not None:
                if st is not None and not st.reachable:
                    # partition: DeletePod can't reach the kubelet; the
                    # pod object stays orphaned node-side. Raise the fence
                    # floor so a rejoin discards it (no split-brain).
                    self.fence_epochs[rec.pod.node] = max(
                        self.fence_epochs.get(rec.pod.node, 0),
                        rec.binding_epoch)
                    message = (message or f"node={rec.pod.node}") + \
                        " [orphaned: node unreachable]"
                else:
                    node.delete_pod(pod_name, now)
        self.record(now, KIND_POD, pod_name, reason,
                    message or f"node={rec.pod.node or '-'}")
        self._emit(KIND_POD, DELETED, pod_name, rec)
        return rec

    # Index-backed reads. All three are O(result), not O(store): the
    # dicts are maintained at submit/assign/evict. Materializations sort
    # on PodRecord.seq where insertion order could differ from submission
    # order (pods_on inserts at bind time), so callers observe exactly
    # the ordering the old full scans produced.
    def note_pod_phase(self, pod_name: str, now: float) -> None:
        """Seam for pod-side phase transitions that happen without a
        store mutation (a container finishing on the kubelet): emits a
        Pod MODIFIED delta so event-driven subscribers (quota ledger,
        capacity index, deployment controller) observe the change."""
        rec = self.pods.get(pod_name)
        if rec is not None:
            self._emit(KIND_POD, MODIFIED, pod_name, rec, reason="phase")

    def pending_pods(self) -> List[PodRecord]:
        return list(self._pending.values())

    def pods_on(self, node_name: str) -> List[PodRecord]:
        return sorted(self._pods_by_node.get(node_name, {}).values(),
                      key=lambda r: r.seq)

    def pods_of(self, deployment: str, live_only: bool = True) -> List[PodRecord]:
        out = []
        for r in self._pods_by_owner.get(deployment, {}).values():
            if live_only and r.bound and r.pod.phase in (
                    PodPhase.SUCCEEDED, PodPhase.FAILED):
                continue
            out.append(r)
        return out

    # ----------------------------------------------------- deployments
    def apply_deployment(self, dep: Deployment, now: float = 0.0) -> Deployment:
        if dep.template.priority_class:
            # keep the numeric mirror in sync with the class, so
            # set_priority's raise-vs-demote comparison (and any reader
            # of template.priority) sees the resolved tier
            dep.template.priority = \
                self.resolve_priority(dep.template.priority_class).value
        existing = self.deployments.get(dep.name)
        self.deployments[dep.name] = dep
        self._emit(KIND_DEPLOYMENT, MODIFIED if existing else ADDED,
                   dep.name, dep)
        if existing is None:
            self.record(now, KIND_DEPLOYMENT, dep.name, "Created",
                        f"replicas={dep.replicas}")
        return dep

    def scale(self, name: str, replicas: int, now: float,
              source: str = "user") -> Deployment:
        """Desired-replica write — the only thing HPA / the twin do."""
        dep = self.deployments[name]
        if replicas != dep.replicas:
            self.record(now, KIND_DEPLOYMENT, name, "Scaled",
                        f"{dep.replicas}->{replicas} by {source}")
            dep.replicas = replicas
            self._emit(KIND_DEPLOYMENT, MODIFIED, name, dep, reason="spec")
        return dep

    def set_priority(self, name: str, priority_class: str, now: float,
                     source: str = "user") -> Deployment:
        """Desired-priority write, the second half of the twin/HPA action
        space: re-tier a Deployment's template AND its existing pods, so
        an escalation changes preemption order immediately (a pending
        scale-up replica submitted at ``standard`` becomes a
        ``latency-critical`` preemptor without being resubmitted)."""
        dep = self.deployments[name]
        if dep.template.priority_class == priority_class:
            return dep
        pc = self.resolve_priority(priority_class)
        old = dep.template.priority_class or str(dep.template.priority)
        raised = pc.value > dep.template.priority
        dep.template.priority_class = priority_class
        dep.template.priority = pc.value
        for rec in self.pods_of(name, live_only=False):
            rec.priority = pc.value
            rec.priority_class = priority_class
            rec.preemptible = pc.preemptible
            if raised and not rec.bound:
                # escalated pending pods re-enter scheduling immediately:
                # the backoff they accrued at the old tier is void
                rec.attempts = 0
                rec.next_retry = now
        self.record(now, KIND_DEPLOYMENT, name, "PriorityChanged",
                    f"{old}->{priority_class} by {source}")
        self._emit(KIND_DEPLOYMENT, MODIFIED, name, dep, reason="spec")
        return dep
